"""Multi-stage resource exit, live (paper §6.3 / Table 4): invoke, then
watch the ladder demote resources stage by stage; hit each stage with a new
request and see which setup phases it skips.

Run:  PYTHONPATH=src python examples/multistage_demo.py
"""
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core import SageRuntime
from repro.core.functions import make_model_function, make_request
from repro.core.profiles import PROFILES

TTL = 0.6  # compressed 30 s -> 0.6 s per stage for the demo


def mem(rt):
    u = rt.memory_usage()
    return f"device={u['device_used']/2**20:6.0f}MB ctx={u['context_bytes']/2**20:4.0f}MB host={u['host_used']/2**20:6.0f}MB"


def main():
    rt = SageRuntime("sage", time_scale=0.05, exit_ttl=TTL)
    rt.sage_init()
    fn = make_model_function(rt.db, "f", arch="qwen2.5-3b",
                             profile=PROFILES["resnet50"])
    rt.register_function(fn)

    print("cold invocation:")
    rt.sage_run(make_request(rt.db, fn, seed=0))
    r = rt.telemetry.records[-1]
    print(f"  e2e={r.e2e*1e3:7.1f}ms  {mem(rt)}")

    # each warm hit resets the ladder, so the wait before hit k must span
    # k-1 full stage TTLs to land in stage k
    for stage, wait in ((1, 0.5 * TTL), (2, 1.5 * TTL), (3, 2.5 * TTL),
                        (4, 3.5 * TTL)):
        time.sleep(wait)
        rt.engines["f"]._advance_ladders()
        print(f"after stage-{stage} window: {mem(rt)}")
        rt.sage_run(make_request(rt.db, fn, seed=stage))
        r = rt.telemetry.records[-1]
        print(f"  warm hit at stage {r.warm_stage}: e2e={r.e2e*1e3:7.1f}ms "
              f"(gpu_ctx={r.stages.get('gpu_ctx', 0)*1e3:.1f}ms "
              f"gpu_data={r.stages.get('gpu_data', 0)*1e3:.1f}ms)")
    rt.shutdown()


if __name__ == "__main__":
    main()
