"""Multi-stage resource exit, live (paper §6.3 / Table 4): invoke through
the gateway, then watch the ladder demote resources stage by stage; hit
each stage with a new request and see which setup phases it skips.

Run:  PYTHONPATH=src python examples/multistage_demo.py
"""
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.api import FunctionSpec, Gateway

TTL = 0.6  # compressed 30 s -> 0.6 s per stage for the demo


def mem(gw):
    u = gw.memory_usage()
    return f"device={u['device_used']/2**20:6.0f}MB ctx={u['context_bytes']/2**20:4.0f}MB host={u['host_used']/2**20:6.0f}MB"


def main():
    gw = Gateway(backend="runtime", policy="sage", time_scale=0.05,
                 exit_ttl=TTL)
    gw.register(FunctionSpec(name="f", arch="qwen2.5-3b", profile="resnet50"))

    print("cold invocation:")
    r = gw.invoke("f", seed=0)
    print(f"  e2e={r.e2e*1e3:7.1f}ms  {mem(gw)}")

    # each warm hit resets the ladder, so the wait before hit k must span
    # k-1 full stage TTLs to land in stage k (the ladder advance is a
    # mechanism-layer peek; load itself goes through the gateway)
    for stage, wait in ((1, 0.5 * TTL), (2, 1.5 * TTL), (3, 2.5 * TTL),
                        (4, 3.5 * TTL)):
        time.sleep(wait)
        gw.runtime.engines["f"]._advance_ladders()
        print(f"after stage-{stage} window: {mem(gw)}")
        r = gw.invoke("f", seed=stage)
        print(f"  warm hit at stage {r.warm_stage}: e2e={r.e2e*1e3:7.1f}ms "
              f"(gpu_ctx={r.stages.get('gpu_ctx', 0)*1e3:.1f}ms "
              f"gpu_data={r.stages.get('gpu_data', 0)*1e3:.1f}ms)")
    gw.shutdown()


if __name__ == "__main__":
    main()
