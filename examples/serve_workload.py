"""End-to-end serving driver: batched requests against real (reduced)
models through the SAGE runtime, comparing all systems under identical
open-loop load — the serving counterpart of the paper's §7.2.

Run:  PYTHONPATH=src python examples/serve_workload.py [--requests 24]
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.core import SageRuntime
from repro.core.functions import make_model_function, make_request
from repro.core.profiles import PROFILES


def drive(system: str, requests: int, rate: float, seed: int = 0):
    rt = SageRuntime(system, time_scale=0.05, exit_ttl=3.0)
    rt.sage_init()
    fns = []
    for arch, prof in (("qwen2.5-3b", "resnet50"), ("qwen3-8b", "bert"),
                       ("mamba2-780m", "seq2seq")):
        fn = make_model_function(rt.db, f"{arch}-fn", arch=arch,
                                 profile=PROFILES[prof])
        rt.register_function(fn)
        fns.append(fn)
    rng = np.random.default_rng(seed)
    futs = []
    t0 = time.monotonic()
    for i in range(requests):
        fn = fns[rng.integers(len(fns))]
        futs.append(rt.submit(make_request(rt.db, fn, seed=seed + i)))
        time.sleep(float(rng.exponential(1.0 / rate)))
    for f in futs:
        f.result(timeout=300)
    wall = time.monotonic() - t0
    tel = rt.telemetry
    print(f"{system:10s} {requests} reqs {wall:6.2f}s "
          f"({requests/wall:5.2f}/s) mean={tel.mean_e2e()*1e3:8.1f}ms "
          f"p99={tel.p99_e2e()*1e3:8.1f}ms warm%={tel.warm_fraction()*100:5.1f} "
          f"shared={rt.daemon.stats['shared_hits']:3d} "
          f"mem={rt.memory_usage()['device_used']/2**20:6.0f}MB")
    rt.shutdown()
    return tel.mean_e2e()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--rate", type=float, default=6.0)
    args = ap.parse_args()
    print("system     load                mean        p99      warm  sharing  memory")
    base = drive("fixedgsl", args.requests, args.rate)
    sage = drive("sage", args.requests, args.rate)
    print(f"\nSAGE speedup vs FixedGSL on this box: {base/sage:.1f}x")


if __name__ == "__main__":
    main()
