"""End-to-end serving driver: one Workload replayed against real (reduced)
models through the gateway, comparing all systems under identical open-loop
load — the serving counterpart of the paper's §7.2, with per-request SLO
deadlines recorded end-to-end.

Run:  PYTHONPATH=src python examples/serve_workload.py [--requests 24]
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.api import FunctionSpec, Gateway, PoissonWorkload

SPECS = [
    FunctionSpec(name="qwen2.5-3b-fn", arch="qwen2.5-3b", profile="resnet50",
                 deadline_s=2.0),
    FunctionSpec(name="qwen3-8b-fn", arch="qwen3-8b", profile="bert",
                 deadline_s=2.0),
    FunctionSpec(name="mamba2-780m-fn", arch="mamba2-780m", profile="seq2seq",
                 deadline_s=2.0),
]


def drive(system: str, requests: int, rate: float, seed: int = 0):
    gw = Gateway(backend="runtime", policy=system, time_scale=0.05,
                 exit_ttl=3.0)
    for spec in SPECS:
        gw.register(spec)
    # open-loop Poisson over the three functions, truncated at `requests`
    # (duration oversized so the count is always reached)
    workload = PoissonWorkload([s.name for s in SPECS], rate,
                               duration_s=4.0 * requests / rate, seed=seed,
                               max_events=requests)
    t0 = time.monotonic()
    tel = gw.replay(workload)
    wall = time.monotonic() - t0
    print(f"{system:10s} {len(workload)} reqs {wall:6.2f}s "
          f"({len(workload)/wall:5.2f}/s) mean={tel.mean_e2e()*1e3:8.1f}ms "
          f"p99={tel.p99_e2e()*1e3:8.1f}ms warm%={tel.warm_fraction()*100:5.1f} "
          f"slo_miss%={tel.slo_miss_rate()*100:5.1f} "
          f"shared={gw.runtime.daemon.stats['shared_hits']:3d} "
          f"mem={gw.memory_usage()['device_used']/2**20:6.0f}MB")
    mean = tel.mean_e2e()
    gw.shutdown()
    return mean


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--rate", type=float, default=6.0)
    args = ap.parse_args()
    print("system     load                mean        p99      warm   slo   sharing  memory")
    base = drive("fixedgsl", args.requests, args.rate)
    sage = drive("sage", args.requests, args.rate)
    print(f"\nSAGE speedup vs FixedGSL on this box: {base/sage:.1f}x")


if __name__ == "__main__":
    main()
