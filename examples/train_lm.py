"""End-to-end training driver: train a ~100M-param qwen-family model for a
few hundred steps with the full production stack — deterministic data
pipeline, AdamW, atomic checkpoints, auto-resume, straggler watchdog.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
(~100M params needs a few GB RAM; --tiny runs the smoke config.)
"""
import argparse
import dataclasses
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax

from repro.configs import get_arch
from repro.launch.train import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    if args.tiny:
        arch, smoke, gb, seq = "qwen2.5-3b", True, 8, 64
    else:
        # ~100M: qwen2.5 family geometry scaled down (12L x 512d x 8H)
        base = get_arch("qwen2.5-3b")
        cfg100m = dataclasses.replace(
            base, name="qwen2.5-100m", num_layers=12, d_model=512,
            num_heads=8, num_kv_heads=2, head_dim=64, d_ff=2048,
            vocab_size=32768, param_dtype="float32", compute_dtype="float32",
        )
        print(f"training {cfg100m.name}: {cfg100m.param_count()/1e6:.1f}M params")
        # register it so train_loop can resolve it by name
        from repro.configs import ARCHS

        ARCHS[cfg100m.name] = cfg100m
        arch, smoke, gb, seq = cfg100m.name, False, 8, 256

    state, losses, wd = train_loop(
        arch, smoke=smoke, steps=args.steps, global_batch=gb, seq_len=seq,
        ckpt_dir=args.ckpt_dir, ckpt_every=50, log_every=10, lr=3e-4,
    )
    print(f"\nfinal loss: {losses[-1]:.4f} (from {losses[0]:.4f}); "
          f"stragglers flagged: {len(wd.flagged)}")


if __name__ == "__main__":
    main()
