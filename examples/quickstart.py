"""Quickstart: declare a GPU function with the unified API and invoke it.

Shows the whole paper in 40 lines: one ``FunctionSpec`` describes the
function (the knowability property), the ``Gateway`` lowers it onto the
real runtime where the daemon preloads while the engine compiles (the
parallelized setup), and the second invocation hits shared read-only
weights and a live context (sharing-based memory management + multi-stage
exit). Swap ``backend="sim"`` to replay the same spec on the virtual-time
twin.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.api import FunctionSpec, Gateway


def main():
    # one gateway per node: SageInit + one memory daemon per device
    gw = Gateway(backend="runtime", policy="sage", time_scale=0.2,
                 exit_ttl=30.0)

    # a real (reduced) qwen2.5 model becomes a serverless GPU function;
    # declared sizes come from the paper's resnet50 profile (Table 2)
    gw.register(FunctionSpec(name="demo-llm", arch="qwen2.5-3b",
                             profile="resnet50"))

    print("cold invocation (compile + load in parallel)...")
    cold = gw.invoke("demo-llm", seed=0)
    print(f"  -> {cold.result}  e2e={cold.e2e*1e3:.1f}ms  stages="
          f"{ {k: round(v*1e3, 1) for k, v in cold.stages.items()} }")

    print("warm invocation (shared weights + live context)...")
    warm = gw.invoke("demo-llm", seed=1)
    print(f"  -> e2e={warm.e2e*1e3:.1f}ms  warm_stage={warm.warm_stage}")
    print(f"speedup: {cold.e2e/warm.e2e:.1f}x | shared hits: "
          f"{gw.runtime.daemon.stats['shared_hits']} | device mem: "
          f"{gw.memory_usage()['device_used']/2**20:.0f} MB")
    gw.shutdown()


if __name__ == "__main__":
    main()
