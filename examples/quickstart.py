"""Quickstart: register a GPU function with SAGE and invoke it.

Shows the whole paper in 40 lines: the request declares its data (the
knowability property), the daemon preloads while the engine compiles (the
parallelized setup), the second invocation hits shared read-only weights
and a live context (sharing-based memory management + multi-stage exit).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core import SageRuntime
from repro.core.functions import make_model_function, make_request
from repro.core.profiles import PROFILES


def main():
    # SageInit: one runtime per node, one memory daemon per device
    rt = SageRuntime("sage", time_scale=0.2, exit_ttl=30.0)
    rt.sage_init()

    # a real (reduced) qwen2.5 model becomes a serverless GPU function;
    # declared sizes come from the paper's resnet50 profile (Table 2)
    fn = make_model_function(rt.db, "demo-llm", arch="qwen2.5-3b",
                             profile=PROFILES["resnet50"])
    rt.register_function(fn)

    print("cold invocation (compile + load in parallel)...")
    out_key = rt.sage_run(make_request(rt.db, fn, seed=0))
    cold = rt.telemetry.records[-1]
    print(f"  -> {out_key}  e2e={cold.e2e*1e3:.1f}ms  stages="
          f"{ {k: round(v*1e3, 1) for k, v in cold.stages.items()} }")

    print("warm invocation (shared weights + live context)...")
    rt.sage_run(make_request(rt.db, fn, seed=1))
    warm = rt.telemetry.records[-1]
    print(f"  -> e2e={warm.e2e*1e3:.1f}ms  warm_stage={warm.warm_stage}")
    print(f"speedup: {cold.e2e/warm.e2e:.1f}x | shared hits: "
          f"{rt.daemon.stats['shared_hits']} | device mem: "
          f"{rt.memory_usage()['device_used']/2**20:.0f} MB")
    rt.shutdown()


if __name__ == "__main__":
    main()
