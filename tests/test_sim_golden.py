"""Kernel-equivalence suite: the layered simulator must replay the
captured golden traces record-for-record (scripts/capture_sim_golden.py).

The fixture was captured from the pre-kernel (closure-chain) simulator;
these tests prove the engine/domain/policy refactor preserved behavior
bit-for-bit — every request id, node assignment, warm stage, stage
duration (at nanosecond resolution), error flag, and preemption count.

If a PR *intends* to change simulator behavior, regenerate the fixture
with ``PYTHONPATH=src python scripts/capture_sim_golden.py`` and say so
in the PR.
"""
import json
import sys
from pathlib import Path

import pytest

# the capture script is the single source of truth for trace construction
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "scripts"))
import capture_sim_golden as cap  # noqa: E402

GOLDEN_PATH = Path(__file__).parent / "golden" / "sim_golden.json"


@pytest.fixture(scope="module")
def golden():
    with open(GOLDEN_PATH) as f:
        return json.load(f)


def _assert_rows_equal(got, want, trace_name):
    assert len(got) == len(want), (
        f"{trace_name}: {len(got)} records vs {len(want)} golden")
    for i, (g, w) in enumerate(zip(got, want)):
        assert g == w, (
            f"{trace_name}: first divergence at record {i}:\n"
            f"  golden: {w}\n  replay: {g}")


@pytest.mark.parametrize("system", ["sage", "sage-nr", "fixedgsl", "dgsf"])
def test_maf_trace_replays_identically(golden, system):
    """Seeded paper-§7.8-style MAF replay, one test per system policy."""
    want = golden["traces"][f"maf:{system}"]
    sim = cap.run_system(system)
    assert sim.completed == want["completed"]
    assert sim.failed == want["failed"]
    _assert_rows_equal(cap.record_rows(sim), want["records"], f"maf:{system}")


def test_knob_trace_replays_identically(golden):
    """EDF + locality dispatch + preemptive transfer, 4 nodes: the PR-3/4/5
    knob stack replays bit-identically, preemption counts included."""
    want = golden["traces"]["knobs:edf+locality+preemptive"]
    sim = cap.run_knobs()
    assert sim.completed == want["completed"]
    assert sim.failed == want["failed"]
    assert sim.preemption_count() == want["preemptions"]
    _assert_rows_equal(cap.record_rows(sim), want["records"], "knobs")


def test_knob_trace_exercises_preemption(golden):
    """The fixture is only a preemption guard if it actually preempts."""
    assert golden["traces"]["knobs:edf+locality+preemptive"]["preemptions"] > 0
