"""Preemptible chunked transfer engine (docs/dataplane.md, "Transfer
scheduling"): stream/arbiter units, preemptive strictly beating
run_to_completion for a tight-deadline load on BOTH drivers,
runtime<->simulator preemption parity, byte-exact accounting when a paused
stream is cancelled by release(), and a golden-trace guard that the default
``run_to_completion`` mode reproduces the pre-stream simulator bit-for-bit."""
import threading
import time

import pytest

from repro.api import FunctionSpec, Gateway
from repro.core.daemon import DataLoadError, MemoryDaemon
from repro.core.datapath import BandwidthBroker, DataPaths
from repro.core.profiles import PROFILES, FunctionProfile
from repro.core.request import Data, DataType, Request
from repro.core.simulator import SimFunction, Simulator
from repro.core.telemetry import InvocationRecord, Telemetry
from repro.core.transfer import (
    TRANSFER_MODES, LinkArbiter, TransferStream, key_prefix,
)
from repro.data.database import Database

MB = 1 << 20


# ---------------------------------------------------------------------------
# stream / arbiter units
# ---------------------------------------------------------------------------


def test_stream_chunked_progress_and_cancel_freeze_bytes():
    broker = BandwidthBroker(1e12, name="test")
    st = broker.open_stream(10 * MB)
    st.advance(4 * MB)
    assert st.moved == 4 * MB and st.remaining == 6 * MB and not st.done
    st.cancel()
    assert st.advance(4 * MB) == 0.0  # cancelled: advances are no-ops
    assert st.moved == 4 * MB and not st.done
    # the link was charged ONLY for the bytes actually moved
    assert broker.total_bytes == 4 * MB

    st2 = broker.open_stream(3 * MB)
    st2.advance()  # full-size advance == one blocking transfer
    assert st2.done and st2.remaining == 0.0
    assert broker.total_bytes == 7 * MB


def test_stream_pause_resume_accounting():
    broker = BandwidthBroker(1e12, name="test")
    st = broker.open_stream(8 * MB)
    st.advance(2 * MB)
    st.pause(10.0)
    st.pause(11.0)  # idempotent: one pause, one preemption
    assert st.preemptions == 1
    st.resume(12.5)
    assert st.stalled_s == pytest.approx(2.5)
    st.advance()
    assert st.done and st.moved == 8 * MB


def test_arbiter_yields_only_to_strictly_tighter_prefix():
    demand = {"head": None}
    arb = LinkArbiter("preemptive", demand=lambda: demand["head"])
    mine = (0, 50.0)  # prio 0, deadline 50
    assert not arb.should_yield(mine)          # no demand
    demand["head"] = (0, 50.0, 99)             # same class, later arrival
    assert not arb.should_yield(mine)          # seq must NOT preempt
    demand["head"] = (0, 10.0, 99)             # tighter deadline
    assert arb.should_yield(mine)
    demand["head"] = (-1, float("inf"), 99)    # higher priority
    assert arb.should_yield(mine)
    demand["head"] = (0, 0.0, 1)               # fifo keys: degenerate prefix
    assert not arb.should_yield((0, 0.0))
    arb.set_mode("run_to_completion")
    demand["head"] = (-5, 0.0, 0)
    assert not arb.should_yield(mine)          # mode gates everything
    with pytest.raises(ValueError):
        LinkArbiter("bogus")
    assert key_prefix(None) is None
    assert key_prefix((1, 2.0, 3)) == (1, 2.0)


# ---------------------------------------------------------------------------
# golden guard: default run_to_completion is bit-identical to the
# pre-stream simulator (captured from the seed implementation)
# ---------------------------------------------------------------------------

_GOLDEN = {
    ("sage", "fifo"): [0.3105, 1.919762113, 1.171215559, 1.199215586,
                       1.863762113, 1.344072957, 1.372072984, 1.891762113,
                       1.516930356],
    ("sage", "edf"): [0.3105, 1.919762113, 1.171215559, 1.199215586,
                      1.863762113, 1.24962996, 1.466515981, 1.891762113,
                      1.516930356],
    ("fixedgsl", "fifo"): [0.403762692, 4.567450713, 2.641303739,
                           1.055328784, 5.318721576, 3.56865155, 1.79106986,
                           5.408869439, 3.923614329],
    ("dgsf", "fifo"): [0.117662692, 4.281350713, 2.355203739, 0.769228784,
                       5.032621576, 3.28255155, 1.50496986, 5.122769439,
                       3.637514329],
}


@pytest.mark.parametrize("policy,scheduler", list(_GOLDEN))
def test_run_to_completion_bit_identical_to_seed(policy, scheduler):
    sim = Simulator(policy, loader_threads=2, scheduler=scheduler)
    assert sim.transfer == "run_to_completion"  # the default knob
    fns = []
    for p in ("resnet50", "bert", "vgg11"):
        f = SimFunction(PROFILES[p])
        sim.register(f)
        fns.append(f.name)
    for i in range(9):
        sim.submit(fns[i % 3], 0.15 * i, deadline_s=5.0 + i, priority=i % 2)
    sim.run(until=900.0)
    got = [round(r.end_t, 9) for r in
           sorted(sim.telemetry.records, key=lambda r: (r.arrival_t,
                                                        r.request_id))]
    assert got == _GOLDEN[(policy, scheduler)]
    # and nothing was preempted or stalled under the default mode
    assert sim.preemption_count() == 0
    assert sim.telemetry.transfer_wait() == 0.0


# ---------------------------------------------------------------------------
# simulator: preemptive strictly beats run_to_completion for the tight class
# ---------------------------------------------------------------------------


def _sim_two_class(transfer):
    sim = Simulator("sage", loader_threads=1, scheduler="edf",
                    transfer=transfer)
    sim.register(SimFunction(
        FunctionProfile("loose", "custom", 1.0, 0.0, 800.0, 5.0)))
    sim.register(SimFunction(
        FunctionProfile("tight", "custom", 1.0, 0.0, 24.0, 5.0)))
    sim.submit("loose", 0.0, deadline_s=60.0, priority=0)
    sim.submit("tight", 0.05, deadline_s=1.0, priority=1)  # mid-loose-stream
    sim.run(until=600.0)
    assert sim.completed == 2 and sim.failed == 0
    return sim, {r.function: r for r in sim.telemetry.records}


def test_sim_preemptive_tight_load_completes_sooner():
    _, rtc = _sim_two_class("run_to_completion")
    sim, pre = _sim_two_class("preemptive")
    # the tight load no longer waits out the loose 800 MB stream
    assert pre["tight"].e2e < rtc["tight"].e2e
    # under run_to_completion the tight load finishes AFTER the loose one;
    # preemption flips the completion order
    assert rtc["tight"].end_t > rtc["loose"].end_t
    assert pre["tight"].end_t < pre["loose"].end_t
    # exactly the loose in-flight stream was paused, then resumed to run
    # to completion without losing bytes
    assert pre["loose"].preemptions >= 1
    assert pre["tight"].preemptions == 0
    assert pre["loose"].stalled_s > 0.0
    assert sim.preemption_count() == pre["loose"].preemptions
    assert sim.nodes[0].bytes_loaded == (800 + 24) * MB
    assert sim.telemetry.transfer_wait() == pytest.approx(
        pre["loose"].stalled_s)


def test_sim_gpu_data_records_actual_contended_span():
    # two identical private loads in lockstep share the PCIe link: the
    # recorded gpu_data must be the ACTUAL ~2x-solo contended span, not the
    # solo estimate nbytes/pcie.bw the seed charged
    sim = Simulator("sage-nr", loader_threads=4)
    f = SimFunction(FunctionProfile("f", "custom", 1.0, 0.0, 512.0, 5.0))
    sim.register(f)
    sim.submit("f", 0.0)
    sim.submit("f", 0.0)
    sim.run(until=600.0)
    assert sim.completed == 2
    solo = f.w_bytes / sim.nodes[0].pcie.bw
    for r in sim.telemetry.records:
        assert r.stages["gpu_data"] > 1.5 * solo
        assert r.stages["gpu_data"] == pytest.approx(2 * solo, rel=0.1)

    # an uncontended load still records ~the solo time
    sim2 = Simulator("sage-nr", loader_threads=4)
    sim2.register(f)
    sim2.submit("f", 0.0)
    sim2.run(until=600.0)
    r = sim2.telemetry.records[0]
    assert r.stages["gpu_data"] == pytest.approx(solo, rel=0.05)


# ---------------------------------------------------------------------------
# threaded daemon: preemption + parity with the sim + byte-exact cancel
# ---------------------------------------------------------------------------


def _wreq(fn, mb, db, deadline_s=None, priority=0):
    req = Request(function_name=fn)
    key = f"{fn}/in/{req.uuid}"
    db.put(key, b"X", size=mb * MB)
    req.in_data = [Data(key=key, size=mb * MB, dtype=DataType.WRITABLE)]
    req.deadline_s, req.priority = deadline_s, priority
    return req


def _preempt_daemon(transfer, db=None, **kw):
    db = db or Database()
    paths = DataPaths.make(db_bw=2e9, pcie_bw=4e9)  # legs take real but
    # test-sized wall time (160 MB ~ 0.08 s db + 0.04 s pcie)
    kw.setdefault("chunk_bytes", 8 * MB)
    d = MemoryDaemon(paths, db, loader_threads=1, scheduler="edf",
                     transfer=transfer, **kw)
    return d, db


def _run_two_class_daemon(transfer):
    d, db = _preempt_daemon(transfer)
    ends = {}

    def waiter(name, h):
        h.wait(30)
        ends[name] = time.monotonic()

    loose = _wreq("loose", 160, db, deadline_s=60.0, priority=0)
    hl = d.prepare(loose)[loose.in_data[0].key]
    tl = threading.Thread(target=waiter, args=("loose", hl))
    tl.start()
    time.sleep(0.03)  # the loose stream is mid-db-leg
    tight = _wreq("tight", 8, db, deadline_s=0.5, priority=1)
    t0 = time.monotonic()
    ht = d.prepare(tight)[tight.in_data[0].key]
    tt = threading.Thread(target=waiter, args=("tight", ht))
    tt.start()
    for t in (tl, tt):
        t.join(timeout=30)
        assert not t.is_alive()
    tight_s = ends["tight"] - t0
    stats = dict(d.stats)
    out = {
        "tight_s": tight_s,
        "tight_first": ends["tight"] < ends["loose"],
        "loose_preempt": hl.entry.transfer_preemptions(),
        "tight_preempt": ht.entry.transfer_preemptions(),
        "loose_stall": hl.entry.transfer_stalled_s(),
        "preemptions": stats["preemptions"],
        "db_bytes": d.paths.db.total_bytes,
    }
    d.release(loose, {loose.in_data[0].key: hl})
    d.release(tight, {tight.in_data[0].key: ht})
    assert d.device_used == 0 and d.host_used == 0
    d.shutdown()
    return out


def test_runtime_preemptive_tight_load_completes_sooner():
    rtc = _run_two_class_daemon("run_to_completion")
    pre = _run_two_class_daemon("preemptive")
    assert rtc["preemptions"] == 0 and rtc["loose_preempt"] == 0
    assert pre["preemptions"] >= 1
    assert pre["tight_s"] < rtc["tight_s"]
    # full byte accounting: both streams moved everything they declared
    assert rtc["db_bytes"] == (160 + 8) * MB
    assert pre["db_bytes"] == (160 + 8) * MB


def test_runtime_sim_preemption_parity():
    """Same arrival pattern (tight small load arriving mid-way through a
    loose large stream, one loader worker, EDF keys) => the same stream is
    paused then resumed on BOTH drivers, and only under "preemptive"."""
    sim_pre = _sim_two_class("preemptive")[1]
    sim_rtc = _sim_two_class("run_to_completion")[1]
    rt_pre = _run_two_class_daemon("preemptive")
    rt_rtc = _run_two_class_daemon("run_to_completion")
    # loose paused >=1 then resumed to completion; tight never paused
    assert sim_pre["loose"].preemptions >= 1 and rt_pre["loose_preempt"] >= 1
    assert sim_pre["tight"].preemptions == 0 and rt_pre["tight_preempt"] == 0
    assert sim_pre["loose"].stalled_s > 0.0 and rt_pre["loose_stall"] > 0.0
    # the tight load overtakes the loose one only under "preemptive"
    assert sim_pre["tight"].end_t < sim_pre["loose"].end_t
    assert rt_pre["tight_first"]
    assert sim_rtc["tight"].end_t > sim_rtc["loose"].end_t
    assert not rt_rtc["tight_first"]
    assert sim_rtc["loose"].preemptions == 0 and rt_rtc["loose_preempt"] == 0


def test_release_of_paused_stream_cancels_byte_exact():
    """release() of a writable entry whose stream is PAUSED (preempted)
    cancels it at the next loader checkpoint; accounting is byte-exact:
    no device/host leak, and the links are charged only for chunks that
    actually moved."""
    d, db = _preempt_daemon("preemptive", chunk_bytes=4 * MB)
    loose = _wreq("loose", 80, db, deadline_s=60.0, priority=0)
    handles = d.prepare(loose)
    hl = handles[loose.in_data[0].key]
    time.sleep(0.01)  # loose mid-db-leg
    tight = _wreq("tight", 64, db, deadline_s=0.5, priority=1)
    ht = d.prepare(tight)[tight.in_data[0].key]
    # wait for the preemption, then cancel the paused loose stream while
    # the tight load still owns the single worker
    deadline = time.monotonic() + 5
    while d.stats["preemptions"] == 0 and time.monotonic() < deadline:
        time.sleep(0.001)
    assert d.stats["preemptions"] >= 1
    d.release(loose, handles)
    with pytest.raises(DataLoadError):
        hl.wait(10)
    ht.wait(10)
    deadline = time.monotonic() + 5
    while d.stats["load_cancellations"] == 0 and time.monotonic() < deadline:
        time.sleep(0.01)
    assert d.stats["load_cancellations"] == 1
    assert d.device_used == 64 * MB  # only the tight entry remains
    assert d.host_used == 64 * MB
    # link accounting is exact: tight's full size + exactly the loose
    # chunks that moved before the cancel — never the full loose stream
    loose_db = hl.entry.db_stream.moved
    loose_pcie = hl.entry.pcie_stream.moved if hl.entry.pcie_stream else 0.0
    assert d.paths.db.total_bytes == 64 * MB + loose_db
    assert d.paths.pcie.total_bytes == 64 * MB + loose_pcie
    assert loose_db + loose_pcie < 2 * 80 * MB  # the tail was never moved
    d.release(tight, {tight.in_data[0].key: ht})
    assert d.device_used == 0 and d.host_used == 0
    d.shutdown()


def test_transfer_attribution_claimed_once_across_sharers():
    """A pause on an entry is attributed to exactly ONE record: the claim
    API returns the not-yet-attributed delta and zero afterwards, so
    concurrent sharers cannot each report the same stall (runtime totals
    stay comparable to daemon.stats and the sim twin)."""
    d, db = _preempt_daemon("preemptive")
    loose = _wreq("loose", 160, db, deadline_s=60.0, priority=0)
    hl = d.prepare(loose)[loose.in_data[0].key]
    time.sleep(0.03)
    tight = _wreq("tight", 8, db, deadline_s=0.5, priority=1)
    ht = d.prepare(tight)[tight.in_data[0].key]
    hl.wait(30)
    ht.wait(30)
    assert d.stats["preemptions"] >= 1
    handles = {loose.in_data[0].key: hl}
    p1, s1 = d.claim_transfer_attribution(handles)
    assert p1 >= 1 and s1 > 0.0
    p2, s2 = d.claim_transfer_attribution(handles)
    assert p2 == 0 and s2 == 0.0
    d.release(loose, handles)
    d.release(tight, {tight.in_data[0].key: ht})
    d.shutdown()


# ---------------------------------------------------------------------------
# knob plumbing: validation, gateway adoption/conflict, runtime switch
# ---------------------------------------------------------------------------


def test_transfer_knob_validation():
    with pytest.raises(ValueError):
        FunctionSpec(name="f", transfer="bogus")
    with pytest.raises(ValueError):
        Simulator("sage", transfer="bogus")
    with pytest.raises(ValueError):
        MemoryDaemon(DataPaths.make(), Database(), transfer="bogus")
    with pytest.raises(ValueError):
        Gateway(backend="sim", transfer="bogus")
    assert set(TRANSFER_MODES) == {"run_to_completion", "preemptive"}


def test_gateway_adopts_spec_transfer_and_refuses_conflicts():
    gw = Gateway(backend="sim", policy="sage")
    assert gw.transfer == "run_to_completion"
    gw.register(FunctionSpec.from_profile("resnet50", name="a",
                                          transfer="preemptive"))
    assert gw.transfer == "preemptive"
    assert gw.sim.transfer == "preemptive"
    # a later spec declaring a DIFFERENT mode is refused
    with pytest.raises(ValueError, match="transfer"):
        gw.register(FunctionSpec.from_profile("resnet50", name="b",
                                              transfer="run_to_completion"))
    # a pinned gateway refuses a conflicting spec up front
    gw2 = Gateway(backend="sim", policy="sage", transfer="run_to_completion")
    with pytest.raises(ValueError, match="transfer"):
        gw2.register(FunctionSpec.from_profile("resnet50", name="a",
                                               transfer="preemptive"))


def test_set_transfer_switches_both_drivers():
    sim = Simulator("sage", n_nodes=2)
    sim.set_transfer("preemptive")
    assert all(n.arbiter.mode == "preemptive" for n in sim.nodes)
    with pytest.raises(ValueError):
        sim.set_transfer("bogus")

    from repro.core.runtime import ClusterRuntime
    cluster = ClusterRuntime(n_nodes=2, database=Database(),
                             serialize_compute=False)
    assert cluster.transfer == "run_to_completion"
    cluster.set_transfer("preemptive")
    assert all(n.daemon.transfer == "preemptive" for n in cluster.nodes)
    cluster.shutdown()


# ---------------------------------------------------------------------------
# telemetry: tail percentiles + transfer_wait
# ---------------------------------------------------------------------------


def test_telemetry_tail_percentiles_and_transfer_wait():
    tel = Telemetry()
    for i in range(100):
        r = InvocationRecord(request_id=f"r{i}", function="f", system="sage",
                             start_t=0.0, end_t=float(i + 1))
        r.stalled_s = 0.25
        r.preemptions = 2
        tel.add(r)
    assert tel.p50_duration() == 51.0
    assert tel.p95_duration() == 96.0
    assert tel.p99_duration() == 100.0
    assert tel.p99_duration("other") == 0.0
    assert tel.transfer_wait() == pytest.approx(25.0)
    assert tel.preemption_count() == 200
    assert tel.transfer_wait("other") == 0.0


# ---------------------------------------------------------------------------
# hedged redispatch: loser cancellation stays byte-exact on the sim driver
# ---------------------------------------------------------------------------


def test_sim_hedge_loser_cancel_byte_exact():
    """Gray-failure hedging on the virtual-time driver: a SlowNode drags
    one node, the straggling invocations launch speculative twins, and
    every cancelled loser unwinds byte-exactly — no node leaks device or
    host bytes, no loader slot stays claimed, and each request produces
    exactly one outcome (the loser's record is ``dropped``/``hedged``,
    never a second completion)."""
    from repro.core.faults import FaultPlan, SlowNode
    from repro.core.profiles import FunctionProfile

    duration = 30.0
    sim = Simulator(
        "sage", n_nodes=3, seed=7,
        faults=FaultPlan([SlowNode("gpu1", at_s=3.0, factor=12.0)], seed=7),
        eviction=True, dispatch="random",
        hedging=dict(min_samples=6, hedge_quantile=0.9), quarantine=False,
    )
    sim.register(SimFunction(FunctionProfile(
        "f", "tail", context_mb=64.0, read_only_mb=24.0, writable_mb=4.0,
        compute_ms=15.0)))
    rng_t = 0.0
    for i in range(240):
        rng_t += duration / 240.0
        sim.submit("f", rng_t, deadline_s=0.5, request_id=f"h{i}")
    sim.run(duration + 120.0)

    recs = sim.telemetry.snapshot()
    losers = [r for r in recs if r.dropped and r.error_class == "hedged"]
    stats = sim.resilience_stats()
    assert stats["hedges_launched"] > 0, "the fault never provoked a hedge"
    # a launched hedge resolves exactly one way: the loser is dropped
    # (win) or the hedge itself was wasted — and every loser is a drop
    assert len(losers) == stats["hedges_won"] + stats["hedges_wasted"] \
        == stats["hedges_launched"]
    for r in losers:
        assert r.error and "Hedged" in r.error
        assert r.end_t > 0.0  # the loser finalized, not abandoned
    # exactly one outcome per submitted request id
    kept = [r for r in recs if not r.dropped]
    assert len({r.request_id for r in kept}) == len(kept) == 240
    # byte-exact books after every loser unwound
    for n in sim.nodes:
        assert 0 <= n.used <= n.capacity, f"{n.name}: used={n.used}"
        assert n.host_used >= 0, f"{n.name}: host_used={n.host_used}"
        assert n.inflight_loads == 0, f"{n.name} leaked loader slots"
