"""Fault-injection plane + resilience control layer (docs/resilience.md).

Covers the FaultPlan/draw determinism contract, the circuit-breaker state
machine, priority-aware shedding, crash/evict/re-dispatch on both drivers,
typed rejection errors, and the chaos-benchmark headline (hardened config
holds >= 2x naive goodput under the identical seeded fault schedule).
"""
import pytest

from repro.api.gateway import Gateway
from repro.api.spec import FunctionSpec
from repro.api.workload import ChaosWorkload
from repro.core.faults import (
    BreakerConfig,
    BreakerOpenError,
    CircuitBreaker,
    DbFlap,
    FaultPlan,
    LinkDegradation,
    LoaderFault,
    NodeCrash,
    ShedError,
    SheddingConfig,
    node_pressure,
)
from repro.core.profiles import FunctionProfile
from repro.core.simulator import SimFunction, Simulator
from repro.core.slowness import HEDGE_STAT_KEYS


def _fn(name="f", ro_mb=64.0, w_mb=8.0, ctx_mb=414.0, compute_ms=10.0):
    return SimFunction(FunctionProfile(name, "test", context_mb=ctx_mb,
                                       read_only_mb=ro_mb, writable_mb=w_mb,
                                       compute_ms=compute_ms))


# ----------------------------------------------------------------------
# plan + draws
# ----------------------------------------------------------------------
def test_fault_plan_events_sorted_and_paired():
    plan = FaultPlan([
        NodeCrash("gpu1", at_s=5.0, restart_after_s=10.0),
        LinkDegradation(at_s=2.0, duration_s=3.0, factor=0.5),
        DbFlap(at_s=1.0, duration_s=2.0),
    ])
    ev = plan.events()
    assert [t for t, _, _ in ev] == sorted(t for t, _, _ in ev)
    kinds = [k for _, k, _ in ev]
    assert kinds.count("crash") == 1 and kinds.count("restart") == 1
    assert kinds.count("degrade_on") == kinds.count("degrade_off") == 1
    assert kinds.count("db_down") == kinds.count("db_up") == 1


def test_fault_plan_validation():
    with pytest.raises(ValueError):
        NodeCrash("gpu0", at_s=-1.0)
    with pytest.raises(ValueError):
        LinkDegradation(at_s=0.0, duration_s=1.0, factor=1.5)
    with pytest.raises(ValueError):
        LoaderFault("f", probability=2.0)
    with pytest.raises(TypeError):
        FaultPlan(["not a spec"])


def test_draws_deterministic_and_independent():
    plan = FaultPlan([LoaderFault("a", 0.5), LoaderFault("b", 0.5)], seed=9)
    d1, d2 = plan.make_draws(), plan.make_draws()
    seq1 = [(d1.draw("a", t), d1.draw("b", t)) for t in range(50)]
    seq2 = [(d2.draw("a", t), d2.draw("b", t)) for t in range(50)]
    assert seq1 == seq2  # same seed -> identical stream on both backends
    assert any(a for a, _ in seq1) and any(not a for a, _ in seq1)
    # functions without specs never draw (no stream perturbation)
    assert d1.draw("other", 0.0) is False


def test_draw_advances_outside_window():
    """The stream advances once per arrival regardless of the fault
    window, so window membership can't drift the draw sequence."""
    windowed = FaultPlan([LoaderFault("f", 1.0, start_s=10.0, end_s=20.0)],
                         seed=4).make_draws()
    always = FaultPlan([LoaderFault("f", 1.0)], seed=4).make_draws()
    assert windowed.draw("f", 0.0) is False   # outside window: no fault...
    assert always.draw("f", 0.0) is True
    assert windowed.draw("f", 15.0) is True   # ...but the stream advanced


# ----------------------------------------------------------------------
# circuit breaker state machine
# ----------------------------------------------------------------------
def test_breaker_trips_cools_and_recloses():
    now = [0.0]
    cfg = BreakerConfig(failure_threshold=0.5, window=10, min_requests=4,
                        cooldown_s=5.0, half_open_probes=2)
    br = CircuitBreaker(cfg, lambda: now[0])
    assert br.state == "closed"
    for _ in range(4):
        assert br.allow()
        br.record(False)
    assert br.state == "open"
    assert not br.allow()  # still cooling
    now[0] = 6.0
    assert br.allow()      # first half-open probe
    assert br.allow()      # second probe (half_open_probes=2)
    assert not br.allow()  # probe slots exhausted
    br.record(True)
    br.record(True)
    assert br.state == "closed"


def test_breaker_probe_failure_reopens():
    now = [0.0]
    cfg = BreakerConfig(window=4, min_requests=2, cooldown_s=1.0,
                        half_open_probes=1)
    br = CircuitBreaker(cfg, lambda: now[0])
    br.record(False)
    br.record(False)
    assert br.state == "open"
    now[0] = 2.0
    assert br.allow()
    br.record(False)
    assert br.state == "open"  # failed probe -> straight back to open
    assert not br.allow()


def test_breaker_below_min_requests_stays_closed():
    br = CircuitBreaker(BreakerConfig(min_requests=5, window=10),
                        lambda: 0.0)
    for _ in range(4):
        br.record(False)
    assert br.state == "closed"


# ----------------------------------------------------------------------
# shedding policy
# ----------------------------------------------------------------------
def test_shedding_watermarks():
    cfg = SheddingConfig(watermark=0.5, hard_watermark=0.9,
                         loose_priority_max=0)
    assert not cfg.should_shed(0.4, priority=0)
    assert cfg.should_shed(0.5, priority=0)       # loose class at watermark
    assert not cfg.should_shed(0.5, priority=1)   # tight class passes
    assert cfg.should_shed(0.95, priority=5)      # hard watermark sheds all
    with pytest.raises(ValueError):
        SheddingConfig(watermark=0.9, hard_watermark=0.5)


def test_node_pressure_normalized():
    assert node_pressure(0, 0, 4, 8.0) == 0.0
    assert node_pressure(100, 100, 4, 8.0) == 1.0
    assert 0.0 < node_pressure(8, 8, 4, 8.0) < 1.0


# ----------------------------------------------------------------------
# defaults off: bit-identical to the seed (golden tests hold the full
# trace contract; this is the cheap structural check)
# ----------------------------------------------------------------------
def test_defaults_off_no_resilience_state():
    sim = Simulator("sage", n_nodes=4, seed=0)
    sim.register(_fn())
    assert sim.dispatchable_nodes() is sim.nodes  # same list object: the
    # seeded rng.choice stream is untouched with the control layer off
    for i in range(20):
        sim.submit("f", 0.1 * i, request_id=f"r{i}")
    sim.run(60.0)
    assert sim.telemetry.error_counts() == {}
    stats = sim.resilience_stats()
    assert stats["shed"] == stats["breaker_rejected"] == 0
    assert stats["node_lost"] == stats["redispatches"] == 0


def test_resilience_stats_backend_key_parity():
    """Both backends report the SAME counter key set (docs/resilience.md
    promises dashboard code never needs a backend switch), including the
    drain counter the placement plane added (docs/planner.md)."""
    expected = {"shed", "breaker_rejected", "node_lost", "redispatches",
                "node_crashes", "node_drains", "breaker_states",
                *HEDGE_STAT_KEYS}
    gw_sim = Gateway(backend="sim", policy="sage", n_nodes=2)
    with Gateway(backend="runtime", policy="sage", n_nodes=2,
                 time_scale=0.02) as gw_rt:
        s, r = gw_sim.resilience_stats(), gw_rt.resilience_stats()
        assert set(s) == set(r) == expected
        # the drain counter moves identically on both drivers
        gw_sim.drain_node("gpu0")
        gw_rt.drain_node("gpu0")
        assert gw_sim.resilience_stats()["node_drains"] == 1
        assert gw_rt.resilience_stats()["node_drains"] == 1


# ----------------------------------------------------------------------
# sim driver: crash, eviction, re-dispatch, retry budget
# ----------------------------------------------------------------------
def _crash_sim(eviction, max_retries=None, dispatch="random"):
    plan = FaultPlan([NodeCrash("gpu1", at_s=2.0)], seed=1)
    sim = Simulator("sage", n_nodes=2, seed=1, dispatch=dispatch,
                    faults=plan, eviction=eviction)
    sim.register(_fn(compute_ms=50.0))
    for i in range(100):
        sim.submit("f", 0.1 * i, deadline_s=60.0,
                   request_id=f"r{i}", max_retries=max_retries)
    sim.run(200.0)
    return sim


@pytest.mark.parametrize("dispatch", ["random", "locality", "least_loaded"])
def test_sim_eviction_rescues_crash(dispatch):
    naive = _crash_sim(False, dispatch=dispatch)
    hardened = _crash_sim(True, dispatch=dispatch)
    n_ok = sum(1 for r in naive.telemetry.snapshot()
               if not r.dropped and r.error is None)
    h_ok = sum(1 for r in hardened.telemetry.snapshot()
               if not r.dropped and r.error is None)
    assert h_ok == 100  # every request lands on the healthy node
    if dispatch != "locality":
        # random keeps feeding the dead node; least_loaded actively
        # prefers it (a crashed node looks idle). locality dodges it by
        # accident — no residency survives the crash — so only the
        # hardened == 100 guarantee holds there.
        assert n_ok < 70
        assert naive.telemetry.error_counts().get("node_lost", 0) > 0
    assert hardened.telemetry.error_counts() == {}
    # accounting is exact after the crash on both configs
    for sim in (naive, hardened):
        for n in sim.nodes:
            assert 0 <= n.used <= n.capacity
            assert n.host_used >= 0
            assert n.inflight_loads == 0


def test_sim_retry_budget_zero_fails_fast():
    sim = _crash_sim(True, max_retries=0)
    stats = sim.resilience_stats()
    assert stats["redispatches"] == 0
    lost = [r for r in sim.telemetry.snapshot()
            if not r.dropped and r.error_class == "node_lost"]
    # in-flight invocations on gpu1 at the crash fail typed, fast
    for r in lost:
        assert "NodeLostError" in r.error
        assert r.redispatches == 0


def test_sim_crash_zeroes_node_accounting():
    sim = _crash_sim(False)
    dead = next(n for n in sim.nodes if n.name == "gpu1")
    assert not dead.healthy
    assert dead.used == 0 and dead.host_used == 0
    assert dead.inflight_loads == 0
    assert not dead.active


def test_sim_restart_rejoins_cold():
    plan = FaultPlan([NodeCrash("gpu1", at_s=2.0, restart_after_s=3.0)],
                     seed=1)
    sim = Simulator("sage", n_nodes=2, seed=1, faults=plan, eviction=True)
    sim.register(_fn())
    for i in range(60):
        sim.submit("f", 0.2 * i, deadline_s=60.0, request_id=f"r{i}")
    sim.run(200.0)
    node = next(n for n in sim.nodes if n.name == "gpu1")
    assert node.healthy and node.crashes == 1
    ok = sum(1 for r in sim.telemetry.snapshot()
             if not r.dropped and r.error is None)
    assert ok == 60  # arrivals after the restart land on gpu1 again


# ----------------------------------------------------------------------
# sim driver: breaker + shedding gates
# ----------------------------------------------------------------------
def test_sim_breaker_opens_on_poisoned_function():
    plan = FaultPlan([LoaderFault("f", probability=1.0)], seed=2)
    cfg = BreakerConfig(failure_threshold=0.5, window=8, min_requests=4,
                        cooldown_s=30.0, half_open_probes=1)
    sim = Simulator("sage", n_nodes=1, seed=2, faults=plan, breaker=cfg)
    sim.register(_fn())
    for i in range(30):
        sim.submit("f", 1.0 * i, request_id=f"r{i}")
    sim.run(120.0)
    stats = sim.resilience_stats()
    assert stats["breaker_states"]["f"] in ("open", "half_open")
    assert stats["breaker_rejected"] > 0
    counts = sim.telemetry.error_counts()
    assert counts["data_load"] >= 4      # the failures that tripped it
    assert counts["breaker"] == stats["breaker_rejected"]
    # breaker rejections resolve instantly and carry no node accounting
    rej = [r for r in sim.telemetry.snapshot()
           if not r.dropped and r.error_class == "breaker"]
    assert all(r.e2e == 0.0 and r.node_id == "" for r in rej)


def test_sim_shedding_protects_tight_class():
    # saturation sized so the soft watermark trips early but the queue of
    # protected tight-class loads never reaches the shed-everything hard
    # watermark (<= ~40 queued of 64 slots)
    shed = SheddingConfig(watermark=0.1, hard_watermark=0.99,
                          loose_priority_max=0, saturation=64.0)
    sim = Simulator("sage", n_nodes=1, seed=3, loader_threads=1,
                    shedding=shed)
    for i in range(12):
        sim.register(_fn(f"f{i}", ro_mb=2048.0))  # slow cold loads
    rid = 0
    for wave in range(6):
        for i in range(12):
            pr = 1 if i % 2 == 0 else 0
            sim.submit(f"f{i}", 0.5 * wave + 0.01 * i, deadline_s=300.0,
                       priority=pr, request_id=f"r{rid}")
            rid += 1
    sim.run(2000.0)
    stats = sim.resilience_stats()
    assert stats["shed"] > 0
    slo = sim.telemetry.slo_by_priority()
    # loose (priority 0) is sacrificed first: strictly worse attainment
    assert slo[1]["attainment"] > slo[0]["attainment"]
    shed_recs = [r for r in sim.telemetry.snapshot()
                 if not r.dropped and r.error_class == "shed"]
    assert shed_recs and all(r.priority == 0 for r in shed_recs)


# ----------------------------------------------------------------------
# gateway API: typed errors + knob plumbing on both backends
# ----------------------------------------------------------------------
def test_gateway_sim_breaker_raises_typed():
    plan = FaultPlan([LoaderFault("f", probability=1.0)], seed=5)
    cfg = BreakerConfig(window=4, min_requests=2, cooldown_s=60.0)
    gw = Gateway(backend="sim", faults=plan, breaker=cfg)
    gw.register(FunctionSpec(name="f", profile="seq2seq"))
    seen = set()
    for i in range(10):
        try:
            gw.invoke("f", at=float(i))
        except BreakerOpenError:
            seen.add("breaker")
        except RuntimeError:
            seen.add("load")
    assert seen == {"load", "breaker"}


def test_gateway_sim_shed_raises_typed():
    shed = SheddingConfig(watermark=0.01, hard_watermark=0.02,
                          loose_priority_max=0, saturation=1.0)
    gw = Gateway(backend="sim", shedding=shed, loader_threads=1)
    gw.register(FunctionSpec(name="f", profile="bert"))
    gw.invoke_async("f", at=0.0)
    with pytest.raises(ShedError):
        # second arrival sees the first one's queued load -> pressure > 0
        gw.invoke("f", at=0.001)


def test_spec_breaker_override_validated():
    with pytest.raises(TypeError):
        FunctionSpec(name="f", breaker="not a config")
    cfg = BreakerConfig(window=4, min_requests=2)
    spec = FunctionSpec(name="f", profile="seq2seq", breaker=cfg)
    gw = Gateway(backend="sim")
    gw.register(spec)
    assert gw.sim._breaker_overrides["f"] is cfg


# ----------------------------------------------------------------------
# cross-driver headline: hardened >= 2x naive goodput, same fault seed
# ----------------------------------------------------------------------
def test_chaos_sim_hardened_2x_naive():
    from benchmarks.chaos import run_sim

    naive = run_sim(False, quick=True)
    hardened = run_sim(True, quick=True)
    assert naive["goodput"] > 0
    assert hardened["goodput"] >= 2.0 * naive["goodput"]
    # the tight class never does worse than the loose class when hardened
    slo = hardened["slo_by_priority"]
    assert slo[2] >= slo[0]


@pytest.mark.slow
def test_chaos_runtime_hardened_2x_naive():
    from benchmarks.chaos import run_runtime

    naive = run_runtime(False, quick=True)
    hardened = run_runtime(True, quick=True)
    assert naive["goodput"] > 0
    assert hardened["goodput"] >= 2.0 * naive["goodput"]
    assert hardened["resilience"]["node_crashes"] == 3
