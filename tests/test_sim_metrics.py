"""Domain-layer aggregates (repro.core.sim.metrics), the Telemetry
sorted-view cache, the new workload generators, and the deprecated trace
aliases — the streaming-telemetry half of the kernel refactor."""
import random
import warnings

import pytest

from repro.api.workload import (
    DiurnalWorkload, FlashCrowdWorkload, MixWorkload, MultiRegionWorkload,
    PoissonWorkload,
)
from repro.core.sim.metrics import AggregateTelemetry, P2Quantile, Reservoir
from repro.core.telemetry import InvocationRecord, Telemetry


# ----------------------------------------------------------------------
# P² quantile sketch
# ----------------------------------------------------------------------
def test_p2_exact_below_five_observations():
    sk = P2Quantile(0.5)
    assert sk.value() == 0.0
    for x in (5.0, 1.0, 3.0):
        sk.add(x)
    assert sk.value() == 3.0  # exact median of {1,3,5}


@pytest.mark.parametrize("p", [0.5, 0.99])
def test_p2_tracks_sorted_quantile_on_random_streams(p):
    rng = random.Random(7)
    sk = P2Quantile(p)
    xs = [rng.expovariate(1.0) for _ in range(20000)]
    for x in xs:
        sk.add(x)
    xs.sort()
    exact = xs[min(int(p * len(xs)), len(xs) - 1)]
    assert sk.count == len(xs)
    # P² is an estimate: accept 5% relative error on a smooth distribution
    assert abs(sk.value() - exact) <= 0.05 * exact


def test_p2_rejects_degenerate_quantiles():
    for bad in (0.0, 1.0, -0.1):
        with pytest.raises(ValueError):
            P2Quantile(bad)


# ----------------------------------------------------------------------
# reservoir
# ----------------------------------------------------------------------
def test_reservoir_keeps_everything_until_capacity():
    r = Reservoir(k=10, rng=random.Random(0))
    for i in range(10):
        r.add(float(i))
    assert sorted(r.sample) == [float(i) for i in range(10)]
    assert r.quantile(0.5) == 5.0


def test_reservoir_is_bounded_and_deterministic():
    def fill(seed):
        r = Reservoir(k=64, rng=random.Random(seed))
        for i in range(5000):
            r.add(float(i))
        return list(r.sample)

    assert len(fill(3)) == 64
    assert fill(3) == fill(3)          # same seed -> same sample
    assert fill(3) != fill(4)          # stream position actually used
    # a uniform sample of 0..4999 should not be the first 64 items
    assert max(fill(3)) > 1000


# ----------------------------------------------------------------------
# AggregateTelemetry vs record-retaining Telemetry
# ----------------------------------------------------------------------
def _records(n=400, seed=5):
    rng = random.Random(seed)
    out = []
    for i in range(n):
        t0 = i * 0.01
        dur = rng.expovariate(20.0)
        rec = InvocationRecord(
            request_id=f"r{i}", function="f", system="sage",
            arrival_t=t0, start_t=t0, end_t=t0 + dur,
            warm_stage=1 if rng.random() < 0.7 else None,
            deadline_s=0.15, priority=0)
        if rng.random() < 0.05:
            rec.error = "DataLoadError: f: boom"
        out.append(rec)
    return out


def test_aggregate_matches_full_telemetry_tallies():
    recs = _records()
    agg = AggregateTelemetry(seed=0)
    full = Telemetry()
    for r in recs:
        agg.add(r)
        full.add(r)
    ok = [r for r in recs if r.error is None]
    assert agg.count == len(recs)
    assert agg.failures == len(recs) - len(ok)
    assert agg.completed == len(ok)
    assert agg.warm_fraction() == pytest.approx(
        sum(1 for r in ok if r.warm_stage is not None) / len(ok))
    assert agg.mean_e2e() == pytest.approx(
        sum(r.e2e for r in ok) / len(ok))
    # goodput counts failed deadline-carrying requests as misses
    met = sum(1 for r in ok if r.e2e <= r.deadline_s)
    assert agg.goodput() == pytest.approx(met / len(recs))
    # sketch percentiles land near the exact full-record ones
    assert agg.e2e_p50.value() == pytest.approx(
        full._quantile_attr(0.5, "e2e"), rel=0.15)
    snap = agg.snapshot()
    for key in ("count", "p50_e2e_s", "p99_e2e_s", "goodput",
                "warm_fraction", "preemptions"):
        assert key in snap


def test_aggregate_goodput_defaults_without_deadlines():
    agg = AggregateTelemetry()
    assert agg.goodput() == 1.0
    rec = InvocationRecord(request_id="x", function="f", system="sage",
                           arrival_t=0.0, start_t=0.0, end_t=1.0)
    agg.add(rec)
    bad = InvocationRecord(request_id="y", function="f", system="sage",
                           arrival_t=0.0, start_t=0.0, end_t=1.0)
    bad.error = "DataLoadError: f: boom"
    agg.add(bad)
    assert agg.goodput() == pytest.approx(0.5)  # completion ratio fallback


# ----------------------------------------------------------------------
# Telemetry sorted-view cache (satellite: no full re-sort per pXX call)
# ----------------------------------------------------------------------
def test_quantile_cache_reuses_sorted_view_until_append():
    tel = Telemetry()
    for r in _records(200):
        tel.add(r)
    calls = {"n": 0}
    orig = sorted

    p99_first = tel.p99_duration()
    # repeated calls between appends hit the cache: the cached entry for
    # ("duration", None) must be identical object across calls
    cached = tel._sorted_cache[("duration", None)]
    assert tel.p99_duration() == p99_first
    assert tel._sorted_cache[("duration", None)] is cached
    assert cached[1] == sorted(cached[1])

    # an append invalidates: the next call recomputes and sees the new row
    late = InvocationRecord(request_id="slow", function="f", system="sage",
                            arrival_t=0.0, start_t=0.0, end_t=999.0)
    tel.add(late)
    tel.p99_duration()  # recomputes: cache entry must be a fresh object
    assert tel._sorted_vals("duration", None)[-1] == 999.0
    assert tel._sorted_cache[("duration", None)] is not cached
    assert tel.p50_duration() <= tel.p95_duration() <= tel.p99_duration()
    assert calls["n"] == 0 and orig is sorted  # guard against typo edits


def test_quantile_cache_is_per_function_and_attr():
    tel = Telemetry()
    for i, fn in enumerate(["a", "b", "a", "b"]):
        tel.add(InvocationRecord(request_id=f"r{i}", function=fn,
                                 system="sage", arrival_t=0.0, start_t=0.0,
                                 end_t=float(i + 1)))
    assert tel.p99_duration("a") == 3.0
    assert tel.p99_duration("b") == 4.0
    assert tel.p99_e2e() == 4.0
    assert ("duration", "a") in tel._sorted_cache
    assert ("e2e", None) in tel._sorted_cache


# ----------------------------------------------------------------------
# workloads: lazy streams + new generators
# ----------------------------------------------------------------------
def test_stream_equals_events_for_mix_workload():
    wl = MixWorkload({"a": 5.0, "b": 2.0}, 50.0, seed=9)
    streamed = [(a.t, a.function) for a in wl.stream()]
    batch = sorted((a.t, a.function) for a in wl.events())
    assert streamed == batch
    ts = [t for t, _ in streamed]
    assert ts == sorted(ts)  # merged stream is time-ordered


def test_stream_is_lazy_for_huge_workloads():
    wl = PoissonWorkload("f", 1000.0, 1e6, seed=1)  # ~1e9 events if realized
    it = wl.stream()
    first = [next(it) for _ in range(5)]
    assert all(a.function == "f" for a in first)
    assert [a.t for a in first] == sorted(a.t for a in first)


def test_diurnal_rate_swings_with_phase():
    wl = DiurnalWorkload("f", 10.0, 400.0, amplitude=0.8, period_s=400.0,
                         seed=2)
    assert wl.rate_at(100.0) == pytest.approx(18.0)   # sin peak
    assert wl.rate_at(300.0) == pytest.approx(2.0)    # sin trough
    events = wl.events()
    peak = sum(1 for a in events if 50 <= a.t < 150)
    trough = sum(1 for a in events if 250 <= a.t < 350)
    assert peak > 2.5 * trough
    with pytest.raises(ValueError):
        DiurnalWorkload("f", 10.0, 10.0, amplitude=1.5)


def test_flash_crowd_spikes_then_decays():
    wl = FlashCrowdWorkload("f", 5.0, 300.0, spike_times_s=(100.0,),
                            spike_factor=10.0, decay_s=10.0, seed=3)
    assert wl.rate_at(50.0) == pytest.approx(5.0)
    assert wl.rate_at(100.0) == pytest.approx(50.0)
    assert wl.rate_at(110.0) < wl.rate_at(101.0)  # exponential decay
    events = wl.events()
    spike = sum(1 for a in events if 100 <= a.t < 120)
    calm = sum(1 for a in events if 60 <= a.t < 80)
    assert spike > 2 * calm


def test_multi_region_offsets_and_merge_order():
    base = {
        "us": PoissonWorkload("f", 4.0, 60.0, seed=4),
        "eu": PoissonWorkload("g", 4.0, 60.0, seed=5),
    }
    wl = MultiRegionWorkload(base, offsets_s={"us": 0.0, "eu": 30.0})
    events = list(wl.stream())
    assert [a.t for a in events] == sorted(a.t for a in events)
    assert min(a.t for a in events if a.function == "g") >= 30.0
    assert wl.duration_s >= 90.0  # eu shifted past its 60 s duration


# ----------------------------------------------------------------------
# deprecated aliases (satellite: one seeded arrival path)
# ----------------------------------------------------------------------
def test_simulator_trace_aliases_warn_and_match_canonical():
    from repro.api import workload as W
    from repro.core import simulator as S

    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        old = S.poisson_arrivals(10.0, 20.0, random.Random(0))
        old_maf = S.maf_like_trace(["a", "b"], duration_s=60.0, seed=1)
    assert any(issubclass(w.category, DeprecationWarning) for w in caught)
    assert old == W.poisson_arrivals(10.0, 20.0, random.Random(0))
    assert old_maf == W.maf_like_trace(["a", "b"], duration_s=60.0, seed=1)
