"""Placement control plane (docs/planner.md): the planner's residency
map, the EWMA forecast + hysteresis autoscaler, node-seconds accounting,
work stealing, exact add/drain teardown on BOTH drivers,
degradation-adaptive transfer pacing, the autoscale spec knob, and the
strictly-beats acceptance headline (planned+autoscale vs locality pool).
"""
import sys
import time
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))  # benchmarks/

from repro.api import FunctionSpec, Gateway
from repro.core.datapath import BandwidthBroker
from repro.core.faults import FaultPlan, LinkDegradation
from repro.core.placement import (
    AutoscaleConfig,
    Autoscaler,
    NodeSnapshot,
    PlacementControl,
    PlacementPlanner,
    PlannerConfig,
    RateForecast,
    resolve_autoscale,
)
from repro.core.profiles import FunctionProfile
from repro.core.request import Request
from repro.core.runtime import ClusterRuntime
from repro.core.sim.kernel import EventKind
from repro.core.simulator import SimFunction, Simulator
from repro.core.transfer import (
    DEFAULT_CHUNK_BYTES, MIN_CHUNK_BYTES, LinkArbiter,
)
from repro.data.database import Database

MB = 1 << 20
GB = 1 << 30


def _fn(name="f", ro_mb=64.0, w_mb=8.0, ctx_mb=414.0, compute_ms=10.0):
    return SimFunction(FunctionProfile(name, "test", context_mb=ctx_mb,
                                       read_only_mb=ro_mb, writable_mb=w_mb,
                                       compute_ms=compute_ms))


def _snap(node_id="gpu0", tier="none", free=40 * GB, cap=40 * GB,
          pending=0, queue=0, workers=4, healthy=True):
    return NodeSnapshot(node_id=node_id, ro_tier=tier, ro_bytes=0,
                        device_free=free, device_capacity=cap,
                        pending_admissions=pending, loader_queue=queue,
                        loader_threads=workers, healthy=healthy)


# ---------------------------------------------------------------------------
# planner: deterministic bin-packing + pick + repair triggers
# ---------------------------------------------------------------------------

def test_planner_bin_packing_deterministic_heaviest_first():
    def build():
        p = PlacementPlanner()
        p.set_nodes(["gpu0", "gpu1"])
        p.register_function("big", 100 * MB)
        p.register_function("mid", 60 * MB)
        p.register_function("small", 10 * MB)
        return p

    p = build()
    # heaviest lands first on the least-loaded node (ties by id): big
    # takes gpu0, mid the emptier gpu1, small joins the lighter bin
    assert p.plan == {"big": ("gpu0",), "mid": ("gpu1",),
                      "small": ("gpu1",)}
    # byte-identical across rebuilds (both drivers share this planner)
    assert build().plan == p.plan


def test_planner_replicas_scale_with_forecast_rate():
    p = PlacementPlanner()  # replica_rate = 8 arrivals/s per extra home
    p.set_nodes(["gpu0", "gpu1", "gpu2"])
    p.register_function("hot", 64 * MB)
    assert p.plan["hot"] == ("gpu0",)
    p.set_rate("hot", 20.0)  # 1 + int(20/8) = 3 homes
    p.replan()
    assert len(p.plan["hot"]) == 3
    p.set_rate("hot", 100.0)  # capped at the node count
    p.replan()
    assert len(p.plan["hot"]) == 3


def test_planner_pick_home_hit_spill_and_health():
    p = PlacementPlanner()
    p.set_nodes(["gpu0", "gpu1"])
    p.register_function("f", MB)
    assert p.plan["f"] == ("gpu0",)
    idx, hit = p.pick("f", [_snap("gpu0"), _snap("gpu1")])
    assert (idx, hit) == (0, True)
    # saturated home (queue_pressure >= spill_pressure 4): spill = miss
    busy = _snap("gpu0", queue=20, workers=4)
    idx, hit = p.pick("f", [busy, _snap("gpu1")])
    assert (idx, hit) == (1, False)
    # a crashed home is never a planned hit: the pick spills (the spill
    # scoring itself is health-agnostic — the drivers drop dead nodes
    # from the snapshot list upstream, via eviction/dispatchable sets)
    dead = _snap("gpu0", healthy=False)
    _, hit = p.pick("f", [dead, _snap("gpu1")])
    assert hit is False
    assert p.planned_hits == 1 and p.planned_misses == 2
    assert p.hit_rate() == pytest.approx(1 / 3)


def test_planner_sustained_misses_force_replan():
    p = PlacementPlanner(PlannerConfig(miss_window=8, replan_miss_rate=0.5))
    p.set_nodes(["gpu0", "gpu1"])
    p.register_function("f", MB)
    r0 = p.replans
    busy = _snap("gpu0", queue=40, workers=4)
    for _ in range(8):  # 8 straight misses > 0.5 * 8 -> repair
        p.pick("f", [busy, _snap("gpu1")])
    assert p.replans == r0 + 1
    assert len(p._window) == 0  # replan clears the evaluation window


def test_planner_drain_candidate_carries_least_weight():
    p = PlacementPlanner()
    p.set_nodes(["gpu0", "gpu1"])
    p.register_function("big", 100 * MB)
    p.register_function("small", MB)
    # big homes on gpu0, small on gpu1: gpu1 is the cheap node to drain
    assert p.drain_candidate() == "gpu1"
    p.retire_function("small")
    assert "small" not in p.plan
    assert p.drain_candidate() == "gpu1"  # now carries nothing


# ---------------------------------------------------------------------------
# forecast + autoscaler
# ---------------------------------------------------------------------------

def test_rate_forecast_ewma_folds_per_tick_counts():
    f = RateForecast(alpha=0.5)
    for _ in range(10):
        f.note_arrival("a")
    assert f.tick(5.0)["a"] == 2.0  # first observation seeds the EWMA
    f.note_arrival("a")
    assert f.tick(1.0)["a"] == pytest.approx(1.5)  # 0.5*1 + 0.5*2
    assert f.tick(1.0)["a"] == pytest.approx(0.75)  # silence decays it
    assert f.total() == pytest.approx(0.75)
    assert f.tick(0.0)["a"] == pytest.approx(0.75)  # dt<=0 is a no-op


def test_autoscaler_hysteresis_streaks_and_clamps():
    scaler = Autoscaler(AutoscaleConfig(
        min_nodes=1, max_nodes=4, node_rate_per_s=10.0, tick_s=1.0,
        ewma_alpha=0.5, headroom=1.0, up_ticks=2, down_ticks=2))
    # up needs a 2-tick streak
    assert scaler.decide(35.0, 1) == (0, [])
    assert scaler.decide(35.0, 1) == (3, []) and scaler.scale_ups == 1
    # target clamps at max_nodes
    assert scaler.decide(1000.0, 4) == (0, []) and scaler.last_target == 4
    # down needs its own streak, then drains ONE node per decision
    assert scaler.decide(0.0, 4) == (0, [])
    assert scaler.decide(0.0, 4) == (0, ["drain"])
    assert scaler.scale_downs == 1
    # never drains below min_nodes
    assert scaler.decide(0.0, 1) == (0, [])
    # an up-tick resets the down streak
    scaler.decide(0.0, 4)
    scaler.decide(50.0, 4)
    assert scaler.decide(0.0, 4) == (0, [])  # streak restarted


def test_autoscale_config_validation_and_resolve_forms():
    with pytest.raises(ValueError):
        AutoscaleConfig(min_nodes=0)
    with pytest.raises(ValueError):
        AutoscaleConfig(min_nodes=4, max_nodes=2)
    with pytest.raises(ValueError):
        AutoscaleConfig(tick_s=0.0)
    with pytest.raises(ValueError):
        AutoscaleConfig(ewma_alpha=0.0)
    assert resolve_autoscale(None) is None
    cfg = AutoscaleConfig(min_nodes=2, max_nodes=4)
    assert resolve_autoscale(cfg) is cfg
    assert resolve_autoscale({"min_nodes": 2, "max_nodes": 4}) == cfg
    with pytest.raises(ValueError, match="autoscale"):
        resolve_autoscale(5)


# ---------------------------------------------------------------------------
# placement control: node-seconds integral, timeline, board/steal decisions
# ---------------------------------------------------------------------------

def test_control_node_seconds_integral_and_timeline():
    c = PlacementControl(["gpu0", "gpu1"], now=0.0)
    assert c.node_seconds(10.0) == pytest.approx(20.0)
    c.node_provisioned("gpu2", 10.0)
    assert c.node_seconds(20.0) == pytest.approx(50.0)
    c.node_draining("gpu2")  # off the placement set, still costing
    assert c.active_nodes() == ["gpu0", "gpu1"]
    assert c.node_seconds(30.0) == pytest.approx(80.0)
    c.node_retired("gpu2", 30.0)
    assert c.node_seconds(40.0) == pytest.approx(100.0)
    st = c.stats(40.0)
    assert st["node_timeline"] == [(0.0, 2), (10.0, 3), (30.0, 2)]
    assert st["provisioned_nodes"] == 2 and st["active_nodes"] == 2
    assert st["node_seconds"] == pytest.approx(100.0)


def test_control_route_boards_above_watermark_and_reroute_steals():
    c = PlacementControl(["gpu0", "gpu1"], now=0.0)
    c.register_function("f", MB)
    calm = [_snap("gpu0"), _snap("gpu1")]
    assert c.route("f", calm) == ("start", 0, True)
    # every candidate above steal_watermark 6: the arrival boards
    storm = [_snap("gpu0", queue=28, workers=4),
             _snap("gpu1", queue=28, workers=4)]
    decision = c.route("f", storm)
    assert decision[0] == "board" and c.boards == 1
    # the stealer can be told not to board (the re-route itself)
    assert c.route("f", storm, allow_board=False)[0] == "start"
    # landing back home is not a steal; landing elsewhere is
    idx, stole = c.reroute("f", calm, "gpu0")
    assert (idx, stole) == (0, False)
    idx, stole = c.reroute(
        "f", [_snap("gpu0", queue=40, workers=4), _snap("gpu1")], "gpu0")
    assert (idx, stole) == (1, True) and c.steals == 1


# ---------------------------------------------------------------------------
# sim driver: dynamic pool, exact drain teardown, stealing under pressure
# ---------------------------------------------------------------------------

def test_sim_add_node_then_drain_releases_exactly():
    sim = Simulator("sage", n_nodes=2, seed=1, dispatch="planned")
    sim.register(_fn("a"))
    node = sim.add_node()
    assert node.name == "gpu2" and len(sim.nodes) == 3
    assert "a" in node.instances  # joiner got every registered function
    sim.submit("a", 0.0)
    sim.run(until=60.0)
    assert sim.completed == 1
    home = sim.telemetry.snapshot()[0].node_id
    sim.drain_node(home)
    drained = next(n for n in sim.nodes if n.name == home)
    # idle at drain time: teardown is immediate and byte-exact
    assert drained.draining and drained.retired
    assert drained.used == 0 and drained.host_used == 0
    sim.drain_node(home)  # idempotent
    # post-drain arrivals never target the retired node
    sim.submit("a", sim.clock.now() + 1.0)
    sim.run(until=sim.clock.now() + 120.0)
    assert sim.completed == 2
    assert sim.telemetry.snapshot()[-1].node_id != home
    st = sim.placement_stats()
    assert st["provisioned_nodes"] == 2 and st["active_nodes"] == 2
    assert sim.resilience_stats()["node_drains"] == 1


def test_sim_manual_drain_waits_for_untracked_inflight_work():
    """Without faults/control the sim never maintains per-node active
    sets — a zero-payload invocation mid-context-build is invisible to
    ``is_idle()``. A manual drain must still never tear the node down
    under it: finalize waits for whole-sim quiescence (``inflight``)."""
    sim = Simulator("sage", n_nodes=1)
    sim.register(_fn("a", ro_mb=0.0, w_mb=0.0, compute_ms=50.0))
    sim.submit("a", 0.0)
    # drain fires mid ctx build (CPU+GPU ctx ~= 0.33 virtual s)
    sim.clock.schedule_at(0.1, sim.drain_node, "gpu0", kind=EventKind.TIMER)
    sim.run(until=0.2)
    node = sim.nodes[0]
    assert node.draining and not node.retired  # deferred, not torn down
    assert sim.inflight == 1
    sim.run(until=60.0)
    # the invocation completed and the drain finalized at that boundary
    assert sim.completed == 1 and sim.failed == 0
    assert sim.inflight == 0
    assert node.retired and node.used == 0


def test_sim_planned_boarding_under_loader_pressure():
    sim = Simulator("sage", n_nodes=2, seed=0, dispatch="planned",
                    loader_threads=1)
    sim.register(_fn("hot", ro_mb=256.0, w_mb=32.0, compute_ms=50.0))
    for i in range(40):
        sim.submit("hot", 0.001 * i)
    sim.run(until=900.0)
    assert sim.completed == 40 and sim.failed == 0
    st = sim.placement_stats()
    # the burst drove every candidate above the steal watermark: arrivals
    # parked on the board, and each boarded arrival still completed
    assert st["boards"] > 0
    assert st["planned_hits"] + st["planned_misses"] + st["boards"] >= 40


# ---------------------------------------------------------------------------
# runtime driver: dynamic pool add/drain with exact teardown
# ---------------------------------------------------------------------------

def _gpu_fn(name):
    from repro.core.engine import GPUFunction

    return GPUFunction(name=name, handler=lambda s, r: None,
                       context_builder=lambda: object(),
                       context_bytes=1 * MB, container_s=0.0, cpu_ctx_s=0.0)


def test_runtime_add_node_then_drain_releases_exactly():
    cluster = ClusterRuntime(n_nodes=2, seed=0, database=Database(),
                             dispatch="planned", serialize_compute=False)
    cluster.sage_init()
    cluster.register_function(lambda i: _gpu_fn("f"))
    node = cluster.add_node()
    assert node.node_id == "gpu2" and len(cluster.nodes) == 3
    req = Request(function_name="f")
    cluster.submit(req).result(timeout=30)
    home = cluster.telemetry.find(req.uuid).node_id
    cluster.drain_node(home)
    drained = next(n for n in cluster.nodes if n.node_id == home)
    deadline = time.monotonic() + 10
    while not drained.retired and time.monotonic() < deadline:
        cluster.placement_stats()  # finalize rides the stats poll too
        time.sleep(0.02)
    assert drained.retired and drained.daemon.device_used == 0
    assert drained.daemon.host_used == 0
    # the drained node's engines were destroyed by the exact teardown
    assert all(not e.instances for e in drained.engines.values())
    req2 = Request(function_name="f")
    cluster.submit(req2).result(timeout=30)
    assert cluster.telemetry.find(req2.uuid).node_id != home
    st = cluster.placement_stats()
    assert st["provisioned_nodes"] == 2 and st["active_nodes"] == 2
    cluster.shutdown()


# ---------------------------------------------------------------------------
# degradation-adaptive transfer pacing (docs/planner.md "Degraded links")
# ---------------------------------------------------------------------------

def test_broker_degradation_composes_and_restores_exactly():
    b = BandwidthBroker(8e9)
    b.apply_degradation(0.5)
    b.apply_degradation(0.5)  # overlapping fault windows compose
    assert b.degradation == pytest.approx(0.25)
    assert b.bw == pytest.approx(2e9)
    b.clear_degradation(0.5)
    b.clear_degradation(0.5)
    assert b.degradation == 1.0 and b.bw == 8e9  # exact snap, no drift
    with pytest.raises(ValueError):
        b.apply_degradation(0.0)
    b.apply_degradation(0.3)
    b.clear_degradation()  # factor=None: unconditional full restore
    assert b.degradation == 1.0 and b.bw == 8e9


def test_chunk_hint_scales_with_link_degradation():
    arb = LinkArbiter("preemptive")
    b = BandwidthBroker(8e9)
    assert arb.chunk_hint(b) == DEFAULT_CHUNK_BYTES
    b.apply_degradation(0.25)  # 4x slower link -> 4x smaller chunks
    assert arb.chunk_hint(b) == DEFAULT_CHUNK_BYTES // 4
    b.apply_degradation(1e-9)  # floor: bookkeeping must not dominate
    assert arb.chunk_hint(b) == MIN_CHUNK_BYTES
    assert arb.chunk_hint(None) == DEFAULT_CHUNK_BYTES
    assert LinkArbiter("run_to_completion").chunk_hint(b) is None


def test_sim_degradation_window_restores_bandwidth_exactly():
    plan = FaultPlan([LinkDegradation(at_s=0.5, duration_s=5.0,
                                      factor=0.25, link="pcie")])
    sim = Simulator("sage", faults=plan)
    sim.register(_fn("a"))
    sim.submit("a", 1.0)  # loads inside the degraded window
    sim.run(until=120.0)
    node = sim.nodes[0]
    assert sim.completed == 1
    assert node.pcie.degradation == 1.0
    assert node.pcie.bw == node.pcie.base_bw


# ---------------------------------------------------------------------------
# gateway knob: autoscale spec adoption / conflict (same rules as dispatch)
# ---------------------------------------------------------------------------

def test_gateway_autoscale_spec_adoption_and_conflict():
    with pytest.raises(ValueError, match="autoscale"):
        FunctionSpec(name="x", autoscale=5)
    cfg = AutoscaleConfig(min_nodes=1, max_nodes=4)
    # the ergonomic dict literal normalizes to the frozen config
    spec = FunctionSpec.from_profile(
        "resnet50", autoscale={"min_nodes": 1, "max_nodes": 4})
    assert spec.autoscale == cfg
    gw = Gateway(backend="sim", policy="sage", n_nodes=2)
    gw.register(spec)
    assert gw.autoscale == cfg and gw.sim.autoscale == cfg
    with pytest.raises(ValueError, match="autoscale"):
        gw.register(FunctionSpec.from_profile(
            "bert", autoscale=AutoscaleConfig(min_nodes=2, max_nodes=8)))
    gw.register(FunctionSpec.from_profile("vgg11", autoscale=cfg))  # agrees
    # an explicit constructor choice is not overridable by a spec
    gw2 = Gateway(backend="sim", policy="sage", n_nodes=2, autoscale=cfg)
    with pytest.raises(ValueError, match="autoscale"):
        gw2.register(FunctionSpec.from_profile(
            "resnet50", autoscale=AutoscaleConfig(min_nodes=2, max_nodes=8)))


def test_gateway_sim_autoscaler_follows_load_end_to_end():
    gw = Gateway(backend="sim", policy="sage", n_nodes=2, dispatch="planned",
                 autoscale=AutoscaleConfig(
                     min_nodes=2, max_nodes=6, node_rate_per_s=2.0,
                     tick_s=2.0, ewma_alpha=0.5, headroom=1.2,
                     up_ticks=1, down_ticks=2))
    gw.register(FunctionSpec.from_profile("resnet50", name="a"))
    # a sustained 10/s burst then silence: the pool grows, then drains
    for i in range(200):
        gw.invoke_async("a", at=0.1 * i)
    for i in range(20):
        gw.invoke_async("a", at=30.0 + 2.5 * i)
    gw.sim.run()  # drain virtual time
    st = gw.placement_stats()
    assert st["scale_ups"] >= 1 and st["scale_downs"] >= 1
    peak = max(n for _, n in st["node_timeline"])
    assert peak > 2  # grew past the floor...
    assert st["provisioned_nodes"] < peak  # ...and shrank back down
    assert gw.report().error_count() == 0


# ---------------------------------------------------------------------------
# acceptance: planned+autoscale strictly beats the locality pool
# ---------------------------------------------------------------------------

def test_planned_strictly_beats_locality_pool_sim():
    from benchmarks import planner as bench

    baseline = bench.run_sim(False, quick=True)
    planned = bench.run_sim(True, quick=True)
    # equal-or-better per-class SLO attainment at strictly lower
    # node-seconds (the BENCH artifact's `planner.beats` gate)
    assert planned["node_seconds"] < baseline["node_seconds"]
    for pri, att in baseline["slo"].items():
        assert planned["slo"][pri] >= att
    assert planned["placement"]["hit_rate"] > 0.8
    assert planned["placement"]["scale_ups"] >= 1


def test_planned_strictly_beats_locality_pool_runtime():
    from benchmarks import planner as bench

    baseline = bench.run_runtime(False, quick=True)
    planned = bench.run_runtime(True, quick=True)
    assert planned["node_seconds"] < baseline["node_seconds"]
    for pri, att in baseline["slo"].items():
        assert planned["slo"][pri] >= att
    assert planned["placement"]["hit_rate"] > 0.8
