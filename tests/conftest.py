import os
import sys
from pathlib import Path

# tests see ONE device (the dry-run alone forces 512 — per assignment).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: threaded-runtime scenario tests (~10s wall each)")
