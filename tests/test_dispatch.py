"""Sharing-aware cluster dispatch (docs/cluster.md): policy scoring, the
residency/pressure snapshot contract, random-dispatch seed regression,
runtime/sim parity of locality assignments, per-request retry budgets, and
the locality-strictly-beats-random acceptance bar on BOTH backends."""
import random
import sys
import threading
import time
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))  # benchmarks/

from repro.api import Arrival, FunctionSpec, Gateway, TraceWorkload
from repro.core.daemon import DataLoadError, MemoryDaemon, OutOfDeviceMemory
from repro.core.datapath import DataPaths
from repro.core.dispatch import (
    DISPATCH_POLICIES, NodeSnapshot, choose_node, locality_score,
)
from repro.core.profiles import PROFILES
from repro.core.request import Data, DataType, Request
from repro.core.runtime import ClusterRuntime
from repro.core.simulator import SimFunction, Simulator
from repro.core.telemetry import STAGES, InvocationRecord, Telemetry
from repro.data.database import Database

MB = 1 << 20
GB = 1 << 30


def _snap(node_id="gpu0", tier="none", free=40 * GB, cap=40 * GB,
          pending=0, queue=0, workers=4):
    return NodeSnapshot(node_id=node_id, ro_tier=tier, ro_bytes=0,
                        device_free=free, device_capacity=cap,
                        pending_admissions=pending, loader_queue=queue,
                        loader_threads=workers)


def _wreq(fn="f", w_mb=8, db=None, **kw):
    req = Request(function_name=fn, **kw)
    key = f"{fn}/in/{req.uuid}"
    if db is not None:
        db.put(key, b"X", size=w_mb * MB)
    req.in_data = [Data(key=key, size=w_mb * MB, dtype=DataType.WRITABLE)]
    return req


def _daemon(cap_mb=1024, db=None, **kw):
    db = db or Database()
    paths = DataPaths.make(db_bw=1e12, pcie_bw=1e12)
    return MemoryDaemon(paths, db, device_capacity=cap_mb * MB, **kw), db


# ---------------------------------------------------------------------------
# policy scoring (pure units)
# ---------------------------------------------------------------------------

def test_locality_prefers_residency_tier_order():
    snaps = [_snap("gpu0", "none"), _snap("gpu1", "host"),
             _snap("gpu2", "device"), _snap("gpu3", "loading")]
    assert choose_node("locality", snaps) == 2  # device wins, index breaks
    # loading counts as much as device (attach to the in-flight stream)
    assert locality_score(snaps[2]) == locality_score(snaps[3])
    assert choose_node("locality", snaps[:2]) == 1  # host beats cold


def test_locality_spills_off_a_saturated_hot_node():
    hot = _snap("gpu0", "device", free=2 * GB, pending=6, queue=12, workers=2)
    cold = _snap("gpu1", "none")
    assert choose_node("locality", [hot, cold]) == 1  # spill-and-warm
    warm_ok = _snap("gpu0", "device", free=30 * GB, queue=1, workers=2)
    assert choose_node("locality", [warm_ok, cold]) == 0  # mild load sticks


def test_locality_cold_functions_spread_by_memory_pressure():
    # no residency anywhere: the emptier node wins, so cold functions
    # spread instead of piling onto node 0
    a = _snap("gpu0", "none", free=20 * GB)
    b = _snap("gpu1", "none", free=39 * GB)
    assert choose_node("locality", [a, b]) == 1


def test_least_loaded_and_tie_breaks_deterministic():
    assert choose_node("least_loaded",
                       [_snap(queue=4), _snap(queue=1), _snap(queue=2)]) == 1
    # full tie: lowest index (stable across both drivers)
    assert choose_node("locality", [_snap(), _snap(), _snap()]) == 0
    assert choose_node("least_loaded", [_snap(), _snap()]) == 0
    # EDF-compatible tie-break: equal score, fewer parked waiters wins
    assert choose_node("locality",
                       [_snap("a", pending=3), _snap("b", pending=0)]) == 1
    with pytest.raises(ValueError):
        choose_node("round_robin", [_snap()])


# ---------------------------------------------------------------------------
# residency/pressure snapshot contract (daemon + sim twin)
# ---------------------------------------------------------------------------

class SlowDB(Database):
    def __init__(self, delay=0.4):
        super().__init__()
        self.delay = delay

    def fetch(self, key, broker=None, *, scale: float = 1.0):
        time.sleep(self.delay)
        return super().fetch(key, broker, scale=scale)


def test_daemon_residency_walks_tiers_and_never_blocks_on_inflight_loads():
    db = SlowDB(delay=0.4)
    d, _ = _daemon(db=db)
    req = Request(function_name="f")
    db.put("f/w", b"W", size=8 * MB)
    req.in_data = [Data(key="f/w", size=8 * MB, dtype=DataType.READ_ONLY)]
    assert d.residency("f") == ("none", 0)
    h = d.prepare(req)["f/w"]
    # the loader is parked inside the slow fetch: the snapshot must return
    # immediately (lock is only held at loader checkpoints)
    t0 = time.monotonic()
    tier, nbytes = d.residency("f")
    p = d.pressure()
    assert time.monotonic() - t0 < 0.2
    assert tier == "loading" and nbytes == 8 * MB
    assert p["loader_queue"] >= 1
    assert p["device_capacity"] == 1024 * MB
    h.wait(5)
    assert d.residency("f")[0] == "device"
    assert d.pressure()["device_free"] == (1024 - 8) * MB
    d.release(req, {"f/w": h})
    d.demote_to_host("f")
    assert d.residency("f") == ("host", 8 * MB)
    d.drop_host("f")
    assert d.residency("f") == ("none", 0)
    d.shutdown()


def test_daemon_function_entries_rides_per_function_index():
    db = Database()
    d, _ = _daemon(db=db)
    reqs = {}
    for fn in ("a", "b"):
        db.put(f"{fn}/w", b"W", size=4 * MB)
        req = Request(function_name=fn)
        req.in_data = [Data(key=f"{fn}/w", size=4 * MB,
                            dtype=DataType.READ_ONLY)]
        d.prepare(req)[f"{fn}/w"].wait(5)
        reqs[fn] = req
    assert {e.key for e in d.function_entries("a")} == {"a/w"}
    assert {e.key for e in d.function_entries("b")} == {"b/w"}
    assert d.function_entries("nope") == []
    # exit-ladder actions ride the index (same semantics as the old scan)
    d.release(reqs["a"], {})
    d.demote_to_host("a")
    assert len(d.evictable_entries("a")) == 0  # host tier, not device
    d.drop_host("a")
    # re-preparing a dropped key REPLACES the entry in both maps
    req2 = Request(function_name="a")
    req2.in_data = [Data(key="a/w", size=4 * MB, dtype=DataType.READ_ONLY)]
    d.prepare(req2)["a/w"].wait(5)
    assert len(d.function_entries("a")) == 1
    assert d.function_entries("a")[0].tier.value == "device"
    d.shutdown()


def test_sim_node_snapshot_mirrors_daemon_contract():
    sim = Simulator("sage")
    f = SimFunction(PROFILES["resnet50"])
    sim.register(f)
    node = sim.nodes[0]
    assert node.residency("resnet50") == ("none", 0)
    sim.submit("resnet50", 0.0)
    sim.run(until=0.05)  # mid-load: db/pcie legs still in flight
    assert node.residency("resnet50")[0] == "loading"
    sim.run(until=600.0)
    tier, nbytes = node.residency("resnet50")
    assert tier == "device" and nbytes == f.ro_bytes
    snap = node.dispatch_snapshot("resnet50")
    assert snap.node_id == "gpu0" and snap.ro_tier == "device"
    assert snap.device_free == node.capacity - node.used


# ---------------------------------------------------------------------------
# random dispatch: seeded paper §7.8 behavior is bit-identical
# ---------------------------------------------------------------------------

def test_sim_random_dispatch_reproduces_seeded_stream():
    sim = Simulator("sage", n_nodes=4, seed=3)  # dispatch defaults to random
    assert sim.dispatch == "random"
    sim.register(SimFunction(PROFILES["resnet50"]))
    for i in range(12):
        sim.submit("resnet50", 0.5 * i)
    sim.run(until=600.0)
    got = [r.node_id for r in
           sorted(sim.telemetry.records, key=lambda r: r.arrival_t)]
    rng = random.Random(3)  # the seed's rng.choice(nodes) stream
    assert got == [f"gpu{rng.randrange(4)}" for _ in range(12)]


def test_cluster_random_dispatch_reproduces_seeded_stream():
    from repro.core.engine import GPUFunction

    def mk(name):
        return GPUFunction(name=name, handler=lambda s, r: None,
                           context_builder=lambda: object(),
                           context_bytes=1 * MB, container_s=0.0,
                           cpu_ctx_s=0.0)

    cluster = ClusterRuntime(n_nodes=4, seed=7, database=Database(),
                             serialize_compute=False)
    assert cluster.dispatch == "random"
    cluster.sage_init()
    cluster.register_function(lambda i: mk("f"))
    reqs = [Request(function_name="f") for _ in range(12)]
    futs = [cluster.submit(r) for r in reqs]
    for f in futs:
        f.result(timeout=60)
    tel = cluster.telemetry
    rng = random.Random(7)
    expect = [f"gpu{rng.randrange(4)}" for _ in range(12)]
    got = [tel.find(r.uuid).node_id for r in reqs]
    assert got == expect
    cluster.shutdown()


# ---------------------------------------------------------------------------
# runtime/sim parity: locality yields the same per-node assignments
# ---------------------------------------------------------------------------

def _assignment_counts(tel):
    out = {}
    for r in tel.snapshot():
        out.setdefault(r.function, {}).setdefault(r.node_id, 0)
        out[r.function][r.node_id] += 1
    return out


def test_locality_parity_runtime_vs_sim():
    """One trace + dispatch="locality" on both backends: same per-node
    assignment counts (within tolerance) and identical record schema."""
    specs = [FunctionSpec(name="a", arch="qwen2.5-3b", profile="seq2seq"),
             FunctionSpec(name="b", arch="qwen2.5-3b", profile="seq2seq")]
    trace = TraceWorkload([(0.0, "a"), (0.8, "b"), (1.6, "a"),
                           (2.4, "b"), (3.2, "a"), (4.0, "b")])

    gw_sim = Gateway(backend="sim", policy="sage", n_nodes=2,
                     dispatch="locality")
    for s in specs:
        gw_sim.register(s)
    tel_sim = gw_sim.replay(trace, until_pad=60.0)
    with Gateway(backend="runtime", policy="sage", n_nodes=2,
                 dispatch="locality", time_scale=0.05) as gw_rt:
        for s in specs:
            gw_rt.register(s)
        tel_rt = gw_rt.replay(trace)

    for tel in (tel_sim, tel_rt):
        recs = tel.snapshot()
        assert len(recs) == 6 and all(r.error is None for r in recs)
        # record schema: canonical stages + per-node attribution on every
        # record of BOTH backends
        assert all(set(r.stages) == set(STAGES) for r in recs)
        assert all(r.node_id in ("gpu0", "gpu1") for r in recs)
        assert all(r.dispatch_tier in ("none", "host", "loading", "device")
                   for r in recs)
    counts_sim = _assignment_counts(tel_sim)
    counts_rt = _assignment_counts(tel_rt)
    # same assignments within tolerance: the drivers differ in timing, so
    # allow one invocation per (function, node) cell to disagree
    for fn in ("a", "b"):
        for node in ("gpu0", "gpu1"):
            assert abs(counts_sim[fn].get(node, 0)
                       - counts_rt[fn].get(node, 0)) <= 1, (counts_sim,
                                                            counts_rt)
    # and each function concentrates on ONE node (the locality win)
    for counts in (counts_sim, counts_rt):
        for fn in ("a", "b"):
            assert max(counts[fn].values()) >= 2
    assert tel_sim.dispatch_hit_rate() > 0.5
    assert tel_rt.dispatch_hit_rate() > 0.5


# ---------------------------------------------------------------------------
# per-request retry budget (Request.max_retries)
# ---------------------------------------------------------------------------

def test_daemon_retry_budget_zero_fails_fast():
    d, db = _daemon(cap_mb=10, load_timeout_s=10.0)
    hold = _wreq(fn="hold", w_mb=8, db=db)
    hh = d.prepare(hold)[hold.in_data[0].key]
    hh.wait(5)
    req = _wreq(fn="ff", w_mb=8, db=db, max_retries=0)
    t0 = time.monotonic()
    with pytest.raises(DataLoadError):
        d.prepare(req)[req.in_data[0].key].wait(10)
    # failed typed on the FIRST OOM, long before the 10 s flat deadline
    assert time.monotonic() - t0 < 2.0
    assert d.stats["load_failures"] == 1
    # the holder is untouched and accounting is exact
    d.release(hold, {hold.in_data[0].key: hh})
    assert d.device_used == 0 and d.host_used == 0
    d.shutdown()


def test_daemon_retry_budget_generous_still_admits_after_release():
    d, db = _daemon(cap_mb=10, load_timeout_s=10.0)
    hold = _wreq(fn="hold", w_mb=8, db=db)
    hh = d.prepare(hold)[hold.in_data[0].key]
    hh.wait(5)
    threading.Timer(
        0.25, lambda: d.release(hold, {hold.in_data[0].key: hh})).start()
    req = _wreq(fn="ok", w_mb=8, db=db, max_retries=1000)
    assert d.prepare(req)[req.in_data[0].key].wait(10) is not None
    assert d.stats["oom_retries"] >= 1
    d.release(req, {req.in_data[0].key: hh})
    d.shutdown()


def test_reserve_slot_honors_retry_budget():
    d, db = _daemon(cap_mb=10, load_timeout_s=10.0)
    hold = _wreq(fn="hold", w_mb=8, db=db)
    hh = d.prepare(hold)[hold.in_data[0].key]
    hh.wait(5)
    t0 = time.monotonic()
    with pytest.raises(OutOfDeviceMemory):
        d.reserve_slot(8 * MB, max_retries=0)
    assert time.monotonic() - t0 < 2.0
    d.release(hold, {hold.in_data[0].key: hh})
    assert d.device_used == 0
    d.shutdown()


def test_sim_retry_budget_mirrors_daemon():
    # capacity fits one working set; the default (None) waits out the
    # backpressure and completes — budget 0 fails typed instead
    def run(max_retries):
        sim = Simulator("sage-nr", capacity=2 << 30, exit_ttl=0.5,
                        load_timeout_s=300.0)
        sim.register(SimFunction(PROFILES["bert"]))
        sim.submit("bert", 0.0)
        sim.submit("bert", 0.01, max_retries=max_retries)
        sim.run(until=900.0)
        return sim

    flat = run(None)  # default: unchanged flat-deadline behavior
    assert flat.completed == 2 and flat.failed == 0
    fast = run(0)
    assert fast.completed == 1 and fast.failed == 1
    err = fast.telemetry.errors()[0]
    assert "DataLoadError" in err.error and err.max_retries == 0
    generous = run(500)
    assert generous.completed == 2 and generous.failed == 0


def test_runtime_request_retry_budget_end_to_end():
    """Engine layer: Request.max_retries rides prepare() into the daemon
    and the typed failure lands in telemetry."""
    from repro.core.runtime import SageRuntime

    rt = SageRuntime("sage", device_capacity=10 * MB, load_timeout_s=10.0,
                     serialize_compute=False)
    rt.sage_init()
    from repro.core.engine import GPUFunction

    def handler(shim, request):
        for dd in request.in_data:
            shim.sage_load_to_gpu(dd.key).wait(30)

    fn = GPUFunction(name="f", handler=handler,
                     context_builder=lambda: object(),
                     context_bytes=1 * MB, container_s=0.0, cpu_ctx_s=0.0)
    rt.register_function(fn)
    block = threading.Event()

    def slow_handler(shim, request):
        for dd in request.in_data:
            shim.sage_load_to_gpu(dd.key).wait(30)
        block.wait(20)

    hold_fn = GPUFunction(name="hold", handler=slow_handler,
                          context_builder=lambda: object(),
                          context_bytes=1 * MB, container_s=0.0,
                          cpu_ctx_s=0.0)
    rt.register_function(hold_fn)
    hold = _wreq(fn="hold", w_mb=7, db=rt.db)
    fut_hold = rt.submit(hold)
    deadline = time.monotonic() + 5
    while rt.daemon.device_used < 7 * MB and time.monotonic() < deadline:
        time.sleep(0.01)  # holder's bytes are on device, handler parked
    req = _wreq(fn="f", w_mb=7, db=rt.db, max_retries=0)
    t0 = time.monotonic()
    fut = rt.submit(req)
    with pytest.raises(DataLoadError):
        fut.result(timeout=30)
    assert time.monotonic() - t0 < 5.0
    rec = rt.telemetry.find(req.uuid)
    assert rec.max_retries == 0 and "DataLoadError" in rec.error
    block.set()
    fut_hold.result(timeout=30)
    rt.shutdown()


def test_retry_budget_zero_fails_fast_even_behind_other_waiters():
    """Budget 0 charges the FIRST failed opportunity even when the request
    is queued behind an earlier waiter (non-head) — parity with the sim,
    which fails a budget-0 reservation at its inline reserve() attempt."""
    d, db = _daemon(cap_mb=10, load_timeout_s=10.0)
    hold = _wreq(fn="hold", w_mb=8, db=db)
    hh = d.prepare(hold)[hold.in_data[0].key]
    hh.wait(5)
    head_done = threading.Event()

    def head():  # parks at the head of the waiter heap, budget-less
        try:
            d.reserve_slot(8 * MB, timeout=10.0)
            d.release_slot(8 * MB)
        finally:
            head_done.set()

    threading.Thread(target=head).start()
    time.sleep(0.15)
    t0 = time.monotonic()
    with pytest.raises(OutOfDeviceMemory):
        d.reserve_slot(8 * MB, max_retries=0)  # non-head: still fail-fast
    assert time.monotonic() - t0 < 2.0
    d.release(hold, {hold.in_data[0].key: hh})
    assert head_done.wait(10)
    assert d.device_used == 0
    d.shutdown()


def test_daemon_retry_budget_counts_memory_events_not_poll_slices():
    """A small budget must survive a holder that releases later: only
    admission attempts that follow a memory event consume the budget, not
    the daemon's 50 ms poll wakes (parity with the sim's per-kick count)."""
    d, db = _daemon(cap_mb=10, load_timeout_s=10.0)
    hold = _wreq(fn="hold", w_mb=8, db=db)
    hh = d.prepare(hold)[hold.in_data[0].key]
    hh.wait(5)
    # ~0.6 s of waiting = ~12 poll slices; budget 2 must NOT be consumed
    threading.Timer(
        0.6, lambda: d.release(hold, {hold.in_data[0].key: hh})).start()
    req = _wreq(fn="ok", w_mb=8, db=db, max_retries=2)
    assert d.prepare(req)[req.in_data[0].key].wait(10) is not None
    d.release(req, {req.in_data[0].key: hh})
    d.shutdown()


def test_shared_entry_budget_widened_by_late_attacher():
    """A sharer attaching mid-wait widens the entry's budget and the
    in-flight admission wait must honor it (re-read, not a stale copy)."""
    db = Database()
    d, _ = _daemon(cap_mb=10, db=db, load_timeout_s=10.0)
    hold = _wreq(fn="hold", w_mb=8, db=db)
    hh = d.prepare(hold)[hold.in_data[0].key]
    hh.wait(5)
    db.put("f/w", b"W", size=8 * MB)

    def ro_req(budget):
        r = Request(function_name="f", max_retries=budget)
        r.in_data = [Data(key="f/w", size=8 * MB, dtype=DataType.READ_ONLY)]
        return r

    tight = ro_req(1)  # one post-memory-event re-admission allowed
    ht = d.prepare(tight)["f/w"]
    time.sleep(0.2)  # loader is parked on the admission wait
    generous = ro_req(None)  # attaches: entry budget widens to None
    hg = d.prepare(generous)["f/w"]
    assert ht.entry is hg.entry and ht.entry.max_retries is None
    time.sleep(0.3)
    d.release(hold, {hold.in_data[0].key: hh})
    # with the stale budget=1 snapshot this failed typed; widened it admits
    assert hg.wait(10) is not None
    d.shutdown()


def test_sim_kick_charges_blocked_head_once_per_memory_event():
    """Backfilling several small waiters in ONE kick must charge the
    blocked head's retry budget once, not once per loop iteration."""
    from repro.core.baselines import get_system
    from repro.core.clock import VirtualClock
    from repro.core.simulator import GPUNode

    node = GPUNode(get_system("sage"), VirtualClock(), capacity=100 * MB)
    node.used = 100 * MB  # full: everything below queues
    state = {"head": None, "smalls": 0}
    node.reserve(50 * MB, lambda: state.__setitem__("head", "ok"),
                 on_fail=lambda: state.__setitem__("head", "failed"),
                 max_retries=2)
    for _ in range(3):
        node.reserve(2 * MB,
                     lambda: state.__setitem__("smalls", state["smalls"] + 1),
                     on_fail=lambda: None)
    head = node.pending_mem[0][1]
    assert head.nbytes == 50 * MB and head.attempts == 1
    node.release(10 * MB)  # one memory event: kick backfills all 3 smalls
    assert state["smalls"] == 3
    assert state["head"] is None and head.attempts == 2  # charged ONCE
    node.release(60 * MB)  # now the head fits and is granted
    assert state["head"] == "ok"


# ---------------------------------------------------------------------------
# gateway knob plumbing + spec adoption/conflict (same rules as scheduler)
# ---------------------------------------------------------------------------

def test_gateway_dispatch_knob_plumbs_to_both_backends():
    gw = Gateway(backend="sim", policy="sage", n_nodes=2, dispatch="locality")
    assert gw.dispatch == "locality" and gw.sim.dispatch == "locality"
    with pytest.raises(ValueError):
        Gateway(backend="sim", dispatch="round_robin")
    with Gateway(backend="runtime", policy="sage", n_nodes=2,
                 dispatch="least_loaded", time_scale=0.02) as gw_rt:
        assert gw_rt.runtime.dispatch == "least_loaded"


def test_spec_dispatch_adoption_and_conflict():
    with pytest.raises(ValueError):
        FunctionSpec(name="x", dispatch="everywhere")
    # an undecided gateway adopts the first spec's declared dispatch
    gw = Gateway(backend="sim", policy="sage", n_nodes=2)
    gw.register(FunctionSpec.from_profile("resnet50", dispatch="locality"))
    assert gw.dispatch == "locality" and gw.sim.dispatch == "locality"
    with pytest.raises(ValueError, match="dispatch"):
        gw.register(FunctionSpec.from_profile("bert", dispatch="random"))
    # an explicit constructor choice is not overridable by a spec
    gw2 = Gateway(backend="sim", policy="sage", n_nodes=2, dispatch="random")
    with pytest.raises(ValueError, match="dispatch"):
        gw2.register(FunctionSpec.from_profile("resnet50", dispatch="locality"))
    # agreement is fine and pins the knob
    gw2.register(FunctionSpec.from_profile("resnet50", dispatch="random"))


# ---------------------------------------------------------------------------
# dynamic node pool (docs/planner.md): deterministic dispatch across
# join/drain churn, identical on both drivers; draining nodes leave the
# candidate set of every policy
# ---------------------------------------------------------------------------

def _churn_sequence(gw):
    """One blocking invoke sequence across a node join and a drain; every
    dispatch decision happens on an idle pool, so the chosen node ids are
    a pure function of the shared scoring + residency state."""
    seq = [gw.invoke("a").node_id, gw.invoke("b").node_id,
           gw.invoke("a").node_id]
    gw.add_node()  # cold joiner enters the candidate set immediately
    seq.append(gw.invoke("c").node_id)
    gw.drain_node(seq[0])  # a's warm home leaves the pool mid-trace
    seq.append(gw.invoke("a").node_id)
    seq.append(gw.invoke("b").node_id)
    return seq


def test_dynamic_pool_dispatch_identical_runtime_vs_sim():
    specs = [
        FunctionSpec(name="a", read_only_bytes=64 * MB,
                     writable_bytes=8 * MB, context_bytes=16 * MB),
        FunctionSpec(name="b", read_only_bytes=64 * MB,
                     writable_bytes=8 * MB, context_bytes=16 * MB),
        FunctionSpec(name="c", read_only_bytes=8 * MB,
                     writable_bytes=8 * MB, context_bytes=16 * MB),
    ]
    gw_sim = Gateway(backend="sim", policy="sage", n_nodes=2,
                     dispatch="locality")
    for s in specs:
        gw_sim.register(s)
    seq_sim = _churn_sequence(gw_sim)
    with Gateway(backend="runtime", policy="sage", n_nodes=2,
                 dispatch="locality", time_scale=0.02) as gw_rt:
        for s in specs:
            gw_rt.register(s)
        seq_rt = _churn_sequence(gw_rt)
    # record-for-record identical dispatch across join + drain churn
    assert seq_sim == seq_rt, (seq_sim, seq_rt)
    drained = seq_sim[0]
    # the drained node never serves again; its warm function re-homed
    assert drained not in seq_sim[4:]
    assert seq_sim[3] == "gpu2"  # the cold joiner won the cold function


def test_sim_policies_never_select_a_draining_node():
    # least_loaded: gpu0 wins the all-idle tie — unless it is draining
    sim = Simulator("sage", n_nodes=2, seed=0, dispatch="least_loaded")
    sim.register(SimFunction(PROFILES["resnet50"]))
    sim.drain_node("gpu0")
    sim.submit("resnet50", 0.0)
    sim.run(until=300.0)
    assert [r.node_id for r in sim.telemetry.snapshot()] == ["gpu1"]
    # locality: the residency holder drains mid-trace; device-tier
    # residency must not pull traffic back onto it
    sim2 = Simulator("sage", n_nodes=2, seed=0, dispatch="locality")
    sim2.register(SimFunction(PROFILES["resnet50"]))
    sim2.submit("resnet50", 0.0)
    sim2.run(until=300.0)
    warm = sim2.telemetry.snapshot()[0].node_id
    assert sim2.nodes[0].residency("resnet50")[0] == "device"
    sim2.drain_node(warm)
    sim2.submit("resnet50", sim2.clock.now() + 1.0)
    sim2.run(until=sim2.clock.now() + 300.0)
    recs = sorted(sim2.telemetry.snapshot(), key=lambda r: r.arrival_t)
    assert recs[-1].node_id != warm and recs[-1].error is None


def test_runtime_policies_never_select_a_draining_node():
    from repro.core.engine import GPUFunction

    def mk(name):
        return GPUFunction(name=name, handler=lambda s, r: None,
                           context_builder=lambda: object(),
                           context_bytes=1 * MB, container_s=0.0,
                           cpu_ctx_s=0.0)

    for policy in ("least_loaded", "locality"):
        cluster = ClusterRuntime(n_nodes=2, seed=0, database=Database(),
                                 dispatch=policy, serialize_compute=False)
        cluster.sage_init()
        cluster.register_function(lambda i: mk("f"))
        cluster.drain_node("gpu0")  # idle: retires immediately
        assert cluster.nodes[0].retired
        for _ in range(3):
            idx, _tier = cluster.select_node("f")
            assert idx == 1
        cluster.shutdown()


# ---------------------------------------------------------------------------
# telemetry attribution
# ---------------------------------------------------------------------------

def test_telemetry_per_node_attribution_and_public_snapshot():
    tel = Telemetry()
    for i, (node, tier) in enumerate([("gpu0", "device"), ("gpu0", "none"),
                                      ("gpu1", "host"), ("gpu1", None)]):
        tel.add(InvocationRecord(request_id=f"r{i}", function="f",
                                 system="sage", node_id=node,
                                 dispatch_tier=tier))
    assert isinstance(tel.snapshot(), list) and len(tel.snapshot()) == 4
    assert tel.node_counts() == {"gpu0": 2, "gpu1": 2}
    assert set(tel.by_node()) == {"gpu0", "gpu1"}
    # hit rate over cluster-dispatched records only (tier None excluded)
    assert tel.dispatch_hit_rate() == pytest.approx(2 / 3)
    by_node = tel.dispatch_by_node()
    assert by_node["gpu0"] == {"requests": 2, "hits": 1, "hit_rate": 0.5}
    assert by_node["gpu1"] == {"requests": 1, "hits": 1, "hit_rate": 1.0}
    assert Telemetry().dispatch_hit_rate() == 0.0


# ---------------------------------------------------------------------------
# acceptance: locality strictly beats random on p50 AND bytes_loaded, on
# BOTH backends (the benchmark helpers are the single source of truth)
# ---------------------------------------------------------------------------

def test_locality_strictly_beats_random_sim():
    from benchmarks.scaleout import dispatch_comparison_sim

    rnd = dispatch_comparison_sim("random")
    loc = dispatch_comparison_sim("locality")
    assert loc["p50_duration"] < rnd["p50_duration"]
    assert loc["bytes_loaded"] < rnd["bytes_loaded"]
    assert loc["hit_rate"] > rnd["hit_rate"]


def test_locality_strictly_beats_random_runtime():
    from benchmarks.scaleout import dispatch_comparison_runtime

    rnd = dispatch_comparison_runtime("random")
    loc = dispatch_comparison_runtime("locality")
    assert loc["p50_duration"] < rnd["p50_duration"]
    assert loc["bytes_loaded"] < rnd["bytes_loaded"]
    assert loc["hit_rate"] > rnd["hit_rate"]
