"""Sharding rules: every production-mesh PartitionSpec must divide the
tensor dims it shards, for every arch x mode, on the abstract 16x16 and
2x16x16 meshes (no devices needed)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS
from repro.distributed import rules
from repro.distributed.compat import abstract_mesh
from repro.models import init_params
from repro.serving.engine import cache_shapes

MESHES = {
    "16x16": abstract_mesh((16, 16), ("data", "model")),
    "2x16x16": abstract_mesh((2, 16, 16), ("pod", "data", "model")),
}


def _check_divisibility(mesh, spec_tree, shape_tree, tag):
    def one(path, spec, leaf):
        assert isinstance(spec, P), (tag, path)
        assert len(spec) <= len(leaf.shape), (tag, path, spec, leaf.shape)
        for dim, ax in zip(leaf.shape, spec):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            n = int(np.prod([mesh.shape[a] for a in axes]))
            assert dim % n == 0, (tag, rules.path_str(path), leaf.shape, spec)

    jax.tree_util.tree_map_with_path(
        lambda p, s, l: one(p, s, l), spec_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


@pytest.mark.parametrize("mesh_name", list(MESHES))
@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_param_specs_divide(mesh_name, arch):
    mesh = MESHES[mesh_name]
    cfg = ARCHS[arch]
    shapes = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    for mode in ("train", "decode"):
        specs = rules.tree_param_specs(cfg, mesh, shapes, mode=mode)
        _check_divisibility(mesh, specs, shapes, f"{arch}/{mode}")


@pytest.mark.parametrize("arch", ["qwen3-8b", "jamba-1.5-large-398b",
                                  "whisper-small", "mamba2-780m"])
def test_cache_specs_divide(arch):
    mesh = MESHES["16x16"]
    cfg = ARCHS[arch]
    cs = cache_shapes(cfg, 128, 32768, enc_len=16384 if cfg.is_encoder_decoder else 0)
    specs = rules.tree_cache_specs(cfg, mesh, cs)
    _check_divisibility(mesh, specs, cs, f"{arch}/cache")


def test_zero_decode_only_for_giants():
    mesh = MESHES["16x16"]
    assert rules.needs_zero_decode(ARCHS["llama4-maverick-400b-a17b"], mesh)
    assert rules.needs_zero_decode(ARCHS["jamba-1.5-large-398b"], mesh)
    assert not rules.needs_zero_decode(ARCHS["qwen3-8b"], mesh)
    assert not rules.needs_zero_decode(ARCHS["qwen3-32b"], mesh)


def test_kv_replicated_when_heads_indivisible():
    mesh = MESHES["16x16"]
    cfg = ARCHS["qwen3-8b"]  # kv=8 < 16 shards
    spec = rules.param_spec(cfg, mesh, "layers/sub0/mixer/wk",
                            (36, cfg.d_model, 8 * 128), mode="train")
    assert spec[-1] is None  # replicated over model (Megatron GQA fallback)
    cfg2 = ARCHS["olmoe-1b-7b"]  # kv=16 == 16 shards
    spec2 = rules.param_spec(cfg2, mesh, "layers/sub0/mixer/wk",
                             (16, cfg2.d_model, 16 * 128), mode="train")
    assert spec2[-1] == "model"


def test_moe_experts_shard_over_model():
    mesh = MESHES["16x16"]
    cfg = ARCHS["llama4-maverick-400b-a17b"]
    spec = rules.param_spec(cfg, mesh, "layers/sub1/ffn/wg",
                            (24, 128, cfg.d_model, cfg.moe_d_ff), mode="train")
    assert spec[1] == "model"  # expert axis -> EP


def test_batch_specs_handle_batch_one():
    mesh = MESHES["16x16"]
    cfg = ARCHS["mamba2-780m"]

    class L:  # tiny shape carrier
        shape = (1, 1)

    # batch of 1 cannot shard -> replicated
    specs = rules.batch_specs(cfg, mesh, {"tokens": L()}, mode="decode")
    assert specs["tokens"][0] is None
