"""End-to-end behaviour tests for the paper's system, driven entirely
through the unified serving API (`repro.api`): the real runtime serving
actual models, the trace simulator reproducing the paper's ordering, and
training e2e."""
import numpy as np

from repro.api import FunctionSpec, Gateway, MAFWorkload
from repro.core.profiles import PROFILES


def test_end_to_end_sage_beats_fixedgsl_cold_latency():
    """COLD invocation through the REAL runtime (actual compile, actual
    device put): SAGE overlaps a ~1.2 s modeled data load with the real jit
    compile, FixedGSL serializes them — cold e2e must be visibly shorter.
    Declared weights are large (2 GiB) so the data term dominates noise."""
    results = {}
    for system in ("sage", "fixedgsl"):
        with Gateway(backend="runtime", policy=system, time_scale=1.0,
                     exit_ttl=30.0) as gw:
            gw.register(FunctionSpec(name="f", arch="qwen2.5-3b",
                                     read_only_bytes=2 << 30))
            rec = gw.invoke("f", seed=0, input_bytes=1 << 20)
            results[system] = rec.e2e
    assert results["sage"] < 0.9 * results["fixedgsl"], results


def test_trace_replay_reproduces_paper_ordering():
    """On an MAF-like workload the system ordering must match the paper:
    latency sage < dgsf < fixedgsl; memory sage < dgsf, sage < fixedgsl.
    One Workload object drives every system."""
    workload = MAFWorkload(list(PROFILES), 240.0, seed=3, mean_rpm=20)
    stats = {}
    for system in ("sage", "dgsf", "fixedgsl"):
        gw = Gateway(backend="sim", policy=system, seed=1)
        for n in PROFILES:
            gw.register(FunctionSpec.from_profile(n))
        tel = gw.replay(workload, until=2400.0)
        stats[system] = (tel.mean_e2e(), gw.mean_memory_bytes())
    assert stats["sage"][0] < stats["dgsf"][0] < stats["fixedgsl"][0]
    assert stats["sage"][1] < stats["fixedgsl"][1]
    assert stats["sage"][1] < stats["dgsf"][1]


def test_training_e2e_loss_decreases(tmp_path):
    from repro.launch.train import train_loop

    _, losses, _ = train_loop(
        "qwen3-8b", steps=12, ckpt_dir=str(tmp_path / "c"), ckpt_every=6,
        global_batch=4, seq_len=32, log_every=100,
    )
    assert losses[-1] < losses[0]
    assert all(np.isfinite(losses))
