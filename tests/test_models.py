"""Per-architecture smoke tests (REQUIRED: reduced same-family config, one
forward/train step on CPU, output shapes + no NaNs) and the
decode-with-cache == full-forward consistency property."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, shape_applicable
from repro.models import decode_step, forward, init_cache, init_params, prefill
from repro.training.optimizer import OptimizerConfig
from repro.training.steps import init_train_state, make_train_step

ALL_ARCHS = sorted(ARCHS)


def _smoke_batch(cfg, B=2, S=16, key=jax.random.PRNGKey(7)):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.is_encoder_decoder:
        batch["encoder_embeds"] = jax.random.normal(key, (B, S // 2, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward(arch):
    cfg = ARCHS[arch].reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 16
    logits, aux = forward(cfg, params, _smoke_batch(cfg, B, S))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits))), f"{arch}: NaNs in logits"
    assert jnp.isfinite(aux)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_train_step(arch):
    cfg = ARCHS[arch].reduced()
    opt = OptimizerConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    state = init_train_state(cfg, opt, jax.random.PRNGKey(0))
    step = make_train_step(cfg, opt)
    batch = _smoke_batch(cfg)
    state, metrics = step(state, batch)
    assert jnp.isfinite(metrics["loss"]), arch
    assert float(metrics["grad_norm"]) > 0
    for leaf in jax.tree_util.tree_leaves(state["params"]):
        assert not bool(jnp.any(jnp.isnan(leaf))), arch


@pytest.mark.parametrize(
    "arch",
    ["qwen3-8b", "qwen2.5-3b", "mamba2-780m", "jamba-1.5-large-398b",
     "olmoe-1b-7b", "whisper-small", "qwen2-vl-72b"],
)
def test_decode_matches_forward(arch):
    """prefill + token-by-token decode reproduces the full-sequence logits."""
    cfg = ARCHS[arch].reduced()
    if cfg.num_experts:
        cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0)  # drop-free
    params = init_params(cfg, jax.random.PRNGKey(1))
    B, S, Sp = 2, 24, 16
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.is_encoder_decoder:
        batch["encoder_embeds"] = jax.random.normal(jax.random.PRNGKey(3),
                                                    (B, 12, cfg.d_model))
    full, _ = forward(cfg, params, batch)
    cache = init_cache(cfg, B, S + 4,
                       enc_len=12 if cfg.is_encoder_decoder else 0)
    pb = dict(batch)
    pb["tokens"] = toks[:, :Sp]
    lg, cache, _ = prefill(cfg, params, pb, cache)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, Sp - 1]),
                               atol=2e-3, rtol=1e-3)
    for t in range(Sp, S):
        lg, cache = decode_step(cfg, params, toks[:, t:t + 1],
                                jnp.full((B,), t, jnp.int32), cache)
        np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, t]),
                                   atol=2e-3, rtol=1e-3)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_param_count_analytic_matches_actual(arch):
    """The analytic counter (used for roofline MODEL_FLOPS and the daemon's
    memory accounting) must track the real pytree at full scale ratios."""
    cfg = ARCHS[arch].reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    actual = sum(x.size for x in jax.tree_util.tree_leaves(params))
    predicted = cfg.param_count()
    assert abs(actual - predicted) / actual < 0.05, (arch, actual, predicted)


def test_long_500k_applicability_rules():
    runs = {a for a in ALL_ARCHS
            if shape_applicable(ARCHS[a], SHAPES["long_500k"])[0]}
    assert runs == {"mamba2-780m", "jamba-1.5-large-398b"}


def test_arch_configs_exact():
    """The registry holds the exact assigned numbers."""
    c = ARCHS["qwen2-vl-72b"]
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
            c.d_ff, c.vocab_size) == (80, 8192, 64, 8, 29568, 152064)
    c = ARCHS["mamba2-780m"]
    assert (c.num_layers, c.d_model, c.d_ff, c.vocab_size, c.ssm_state) == \
        (48, 1536, 0, 50280, 128)
    c = ARCHS["olmoe-1b-7b"]
    assert (c.num_experts, c.experts_per_token, c.d_ff) == (64, 8, 1024)
    c = ARCHS["llama4-maverick-400b-a17b"]
    assert (c.num_experts, c.experts_per_token, c.vocab_size) == (128, 1, 202048)
    c = ARCHS["jamba-1.5-large-398b"]
    assert (c.attn_every, c.num_experts, c.experts_per_token) == (8, 16, 2)
    assert c.num_attn_layers == 9 and c.num_mamba_layers == 63
    c = ARCHS["qwen3-32b"]
    assert (c.num_layers, c.d_model, c.head_dim, c.qk_norm) == (64, 5120, 128, True)
    c = ARCHS["qwen2.5-3b"]
    assert (c.num_kv_heads, c.qkv_bias, c.d_ff) == (2, True, 11008)
    c = ARCHS["qwen3-8b"]
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads) == (36, 4096, 32, 8)
    c = ARCHS["phi4-mini-3.8b"]
    assert (c.num_layers, c.d_model, c.vocab_size) == (32, 3072, 200064)
    c = ARCHS["whisper-small"]
    assert (c.encoder_layers, c.num_layers, c.d_model, c.vocab_size) == \
        (12, 12, 768, 51865)


def test_moe_capacity_drops_are_bounded():
    """With capacity factor 1.0 and uniform routing, most tokens survive."""
    from repro.models.layers import init_moe, moe_forward

    cfg = ARCHS["olmoe-1b-7b"].reduced()
    cfg = dataclasses.replace(cfg, moe_capacity_factor=1.0)
    p = init_moe(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))
    y, aux = moe_forward(cfg, p, x)
    assert y.shape == x.shape
    # aux loss near 1.0 indicates balanced routing (Switch normalization)
    assert 0.5 < float(aux) < 4.0
