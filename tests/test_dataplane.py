"""Hardened async data plane: bounded loader pool, failure propagation,
OOM backpressure, cancellation (no accounting leaks) — on the threaded
daemon/runtime AND the virtual-time simulator twin (docs/dataplane.md)."""
import threading
import time

import pytest

from repro.core.clock import RealClock
from repro.core.daemon import DataLoadError, MemoryDaemon, Tier
from repro.core.datapath import DataPaths
from repro.core.request import Data, DataType, Request
from repro.core.simulator import SimFunction, Simulator
from repro.core.profiles import PROFILES
from repro.data.database import Database

MB = 1 << 20


def _daemon(cap_mb=1024, db=None, **kw):
    db = db or Database()
    paths = DataPaths.make(db_bw=1e12, pcie_bw=1e12)  # near-instant for tests
    return MemoryDaemon(paths, db, device_capacity=cap_mb * MB, **kw), db


def _wreq(fn="f", w_mb=8, db=None):
    """Request with one writable datum (freed fully on release)."""
    req = Request(function_name=fn)
    key = f"{fn}/in/{req.uuid}"
    if db is not None:
        db.put(key, b"X", size=w_mb * MB)
    req.in_data = [Data(key=key, size=w_mb * MB, dtype=DataType.WRITABLE)]
    return req


class FaultyDB(Database):
    """Database whose fetch always faults."""

    def fetch(self, key, broker=None, *, scale: float = 1.0):
        raise IOError(f"simulated database fault for {key}")


class SlowCountingDB(Database):
    """Database that tracks concurrent fetches (the db-path instrumentation
    for the loader-concurrency bound)."""

    def __init__(self, delay: float = 0.05):
        super().__init__()
        self.delay = delay
        self._c = threading.Lock()
        self.cur = 0
        self.max_concurrent = 0

    def fetch(self, key, broker=None, *, scale: float = 1.0):
        with self._c:
            self.cur += 1
            self.max_concurrent = max(self.max_concurrent, self.cur)
        try:
            time.sleep(self.delay)
            return super().fetch(key, broker, scale=scale)
        finally:
            with self._c:
                self.cur -= 1


# ---------------------------------------------------------------------------
# failure propagation
# ---------------------------------------------------------------------------


def test_db_fault_propagates_as_dataloaderror():
    d, _ = _daemon(db=FaultyDB())
    req = _wreq(db=None)
    h = d.prepare(req)[req.in_data[0].key]
    with pytest.raises(DataLoadError) as ei:
        h.wait(5)  # seed behavior: hung forever here
    assert isinstance(ei.value.cause, IOError)
    assert d.stats["load_failures"] == 1
    assert d.device_used == 0 and d.host_used == 0


def test_oom_past_deadline_fails_instead_of_hanging():
    d, db = _daemon(cap_mb=4, load_timeout_s=0.3)
    req = _wreq(w_mb=8, db=db)  # 8 MB datum can never fit in 4 MB
    h = d.prepare(req)[req.in_data[0].key]
    t0 = time.monotonic()
    with pytest.raises(DataLoadError):
        h.wait(10)
    assert time.monotonic() - t0 < 5.0
    assert d.stats["load_failures"] == 1
    assert d.device_used == 0 and d.host_used == 0
    # the failed entry is not resurrected as a shared hit
    assert h.entry.tier is Tier.FAILED


def test_failed_handle_is_not_ready():
    d, _ = _daemon(db=FaultyDB())
    req = _wreq()
    h = d.prepare(req)[req.in_data[0].key]
    h.entry.ready.wait(5)
    assert not h.is_ready()


# ---------------------------------------------------------------------------
# OOM backpressure: waiting loads are admitted when memory frees up
# ---------------------------------------------------------------------------


def test_load_blocked_on_oom_admitted_after_release():
    d, db = _daemon(cap_mb=10, load_timeout_s=5.0)
    ra = _wreq(fn="a", w_mb=8, db=db)
    ha = d.prepare(ra)[ra.in_data[0].key]
    ha.wait(5)
    assert d.device_used == 8 * MB

    rb = _wreq(fn="b", w_mb=8, db=db)
    hb = d.prepare(rb)[rb.in_data[0].key]
    # b cannot be admitted while a holds the device
    threading.Timer(0.25, lambda: d.release(ra, {ra.in_data[0].key: ha})).start()
    assert hb.wait(10) is not None  # admitted after a's release
    assert d.stats["oom_retries"] >= 1
    d.release(rb, {rb.in_data[0].key: hb})
    assert d.device_used == 0 and d.host_used == 0


# ---------------------------------------------------------------------------
# cancellation: release() of a still-loading writable entry
# ---------------------------------------------------------------------------


def test_release_while_loading_cancels_without_leak():
    db = SlowCountingDB(delay=0.2)
    d, _ = _daemon(db=db)
    req = _wreq(db=db)
    handles = d.prepare(req)
    # release immediately: the loader is still in the db fetch
    d.release(req, handles)
    h = handles[req.in_data[0].key]
    with pytest.raises(DataLoadError):
        h.wait(5)
    deadline = time.monotonic() + 5
    while (d.device_used or d.host_used) and time.monotonic() < deadline:
        time.sleep(0.01)
    assert d.device_used == 0 and d.host_used == 0
    assert d.stats["load_cancellations"] == 1


# ---------------------------------------------------------------------------
# bounded loader concurrency (db/PCIe path instrumentation)
# ---------------------------------------------------------------------------


def test_prepare_after_shutdown_resolves_synchronously():
    d, db = _daemon()
    d.shutdown()
    req = _wreq(db=db)
    h = d.prepare(req)[req.in_data[0].key]
    assert h.wait(5) is not None  # degraded to inline load, never parked


def test_unpooled_daemon_still_propagates_failures():
    # baseline platforms run with pooled=False (per-load threads); the
    # failure/cancellation contract is identical
    d, _ = _daemon(db=FaultyDB(), pooled=False)
    req = _wreq()
    h = d.prepare(req)[req.in_data[0].key]
    with pytest.raises(DataLoadError):
        h.wait(5)
    assert d.device_used == 0 and d.host_used == 0


def test_loader_concurrency_never_exceeds_pool_size():
    db = SlowCountingDB(delay=0.05)
    d, _ = _daemon(db=db, loader_threads=3)
    reqs = [_wreq(fn=f"f{i}", w_mb=1, db=db) for i in range(10)]
    handles = [d.prepare(r)[r.in_data[0].key] for r in reqs]
    for h in handles:
        h.wait(10)
    assert db.max_concurrent <= 3
    assert d.max_inflight_loads <= 3
    assert d.max_inflight_loads >= 2  # the pool actually ran concurrently


# ---------------------------------------------------------------------------
# burst stress: capacity below the working set, N concurrent submits —
# every future resolves (success after backpressure/eviction OR
# DataLoadError); accounting returns to the pre-burst baseline
# ---------------------------------------------------------------------------


def test_burst_under_capacity_no_hang_no_leak():
    db = Database()
    d, _ = _daemon(cap_mb=20, db=db, loader_threads=4, load_timeout_s=3.0)
    base_dev, base_host = d.device_used, d.host_used
    n = 12
    reqs = [_wreq(fn=f"f{i}", w_mb=8, db=db) for i in range(n)]  # 96 MB >> 20
    results = [None] * n

    def run(i):
        req = reqs[i]
        handles = d.prepare(req)
        try:
            handles[req.in_data[0].key].wait(15)
            results[i] = "ok"
        except DataLoadError:
            results[i] = "failed"
        finally:
            d.release(req, handles)

    threads = [threading.Thread(target=run, args=(i,)) for i in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=30)
        assert not t.is_alive(), "a Handle.wait() hung past its timeout"
    assert all(r in ("ok", "failed") for r in results)
    assert results.count("ok") >= 2  # backpressure admitted at least the 2 that fit
    # cancellation/rollback may lag release by one loader checkpoint
    deadline = time.monotonic() + 10
    while (d.device_used != base_dev or d.host_used != base_host) \
            and time.monotonic() < deadline:
        time.sleep(0.02)
    assert d.device_used == base_dev
    assert d.host_used == base_host


def test_runtime_burst_errors_surface_in_telemetry():
    """Engine layer: loader failures land in InvocationRecord.error and the
    future raises — the runtime pool never deadlocks on a dead loader."""
    from repro.core.runtime import SageRuntime
    from repro.core.functions import make_model_function, make_request

    rt = SageRuntime("sage", time_scale=0.0, exit_ttl=30.0,
                     device_capacity=2048 * MB, load_timeout_s=2.0)
    rt.sage_init()
    # declared working set far above device capacity -> admission can never
    # succeed; the invocation must FAIL (typed), not hang
    fn = make_model_function(rt.db, "big", arch="qwen2.5-3b",
                             declared_ro_bytes=8192 * MB)
    rt.register_function(fn)
    fut = rt.submit(make_request(rt.db, fn))
    with pytest.raises(DataLoadError):
        fut.result(timeout=60)
    assert rt.telemetry.error_count() == 1
    assert "DataLoadError" in rt.telemetry.errors()[0].error
    rt.shutdown()


# ---------------------------------------------------------------------------
# virtual-time twin: same bound, same failure semantics
# ---------------------------------------------------------------------------


def test_simulator_loader_bound_enforced():
    sim = Simulator("sage-nr", loader_threads=2)  # NR: every load is private
    f = SimFunction(PROFILES["resnet50"])
    sim.register(f)
    for i in range(12):
        sim.submit(f.name, 0.001 * i)
    sim.run(until=600.0)
    node = sim.nodes[0]
    assert sim.completed == 12
    assert node.max_inflight_loads <= 2
    assert node.max_inflight_loads >= 2  # the gate actually saturated


def test_simulator_failure_semantics_mirror_daemon():
    # capacity below one invocation's working set: the twin must resolve
    # every arrival as completed-or-failed (error recorded), never stuck
    sim = Simulator("fixedgsl", capacity=256 << 20, load_timeout_s=1.0)
    f = SimFunction(PROFILES["bert"])  # ~1.7 GB slot >> 256 MB
    sim.register(f)
    for i in range(4):
        sim.submit(f.name, 0.001 * i)
    sim.run(until=600.0)
    assert sim.failed == 4 and sim.completed == 0
    errs = sim.telemetry.errors()
    assert len(errs) == 4
    assert all("DataLoadError" in r.error for r in errs)
    assert all(r.end_t is not None for r in errs)
    node = sim.nodes[0]
    assert node.used == 0  # failed reservations hold nothing


def test_simulator_backpressure_admits_when_memory_frees():
    # two invocations with PRIVATE working sets (NR mode), device fits one:
    # the second waits for the first's release, then completes — no failure
    sim = Simulator("sage-nr", capacity=2 << 30, exit_ttl=0.5, load_timeout_s=300.0)
    f = SimFunction(PROFILES["bert"])
    sim.register(f)
    sim.submit(f.name, 0.0)
    sim.submit(f.name, 0.01)
    sim.run(until=900.0)
    assert sim.completed == 2 and sim.failed == 0


# ---------------------------------------------------------------------------
# host-tier admission: host_capacity is enforced, not advisory
# ---------------------------------------------------------------------------


def test_host_overcommit_fails_typed_no_leak():
    d, db = _daemon(host_capacity=4 * MB)
    req = _wreq(w_mb=8, db=db)  # 8 MB can never fit the 4 MB host tier
    h = d.prepare(req)[req.in_data[0].key]
    with pytest.raises(DataLoadError, match="host admission"):
        h.wait(5)
    assert d.stats["load_failures"] == 1
    assert d.host_used == 0 and d.device_used == 0
    assert h.entry.tier is Tier.FAILED


def test_host_admission_evicts_refcount0_host_entries():
    db = Database()
    d, _ = _daemon(db=db, host_capacity=12 * MB)
    # fn a: 8 MB read-only entry, demoted to the HOST tier (refcount 0)
    ra = Request(function_name="a")
    db.put("a/w", b"W", size=8 * MB)
    ra.in_data = [Data(key="a/w", size=8 * MB, dtype=DataType.READ_ONLY)]
    ha = d.prepare(ra)["a/w"]
    ha.wait(5)
    d.release(ra, {"a/w": ha})
    d.demote_to_host("a")
    assert ha.entry.tier is Tier.HOST and d.host_used == 8 * MB
    # fn b needs 8 MB of host: a's idle host copy must be evicted
    rb = _wreq(fn="b", w_mb=8, db=db)
    hb = d.prepare(rb)[rb.in_data[0].key]
    assert hb.wait(5) is not None
    assert d.stats["host_evictions"] == 1
    assert ha.entry.tier is Tier.DROPPED
    assert d.host_used == 8 * MB  # only b's bytes remain
    d.release(rb, {rb.in_data[0].key: hb})
    assert d.host_used == 0 and d.device_used == 0


def test_simulator_host_admission_mirrors_daemon():
    # the twin enforces the same host ceiling on the db->host leg: a
    # working set above host_capacity fails typed, and an idle host-state
    # shared-RO copy is evicted to make room for a new load
    sim = Simulator("sage", host_capacity=1 << 30, load_timeout_s=5.0)
    f = SimFunction(PROFILES["bert"])  # 1282 MB RO > 1 GiB host tier
    sim.register(f)
    sim.submit(f.name, 0.0)
    sim.run(until=600.0)
    assert sim.failed == 1
    assert "DataLoadError" in sim.telemetry.errors()[0].error
    assert sim.nodes[0].host_used == 0
    sim.nodes[0]._advance_ladders()  # walk the warm ctx off the exit ladder
    assert sim.nodes[0].used == 0

    # eviction: resnet50's host copy (demoted at stage 2) is dropped when
    # bert needs the room (bert peak host ~1343 MB + resnet's 98 MB > 1400)
    sim2 = Simulator("sage", host_capacity=1400 << 20, load_timeout_s=60.0)
    small = SimFunction(PROFILES["resnet50"])  # ~98 MB RO
    big = SimFunction(PROFILES["bert"])        # ~1282 MB RO
    sim2.register(small)
    sim2.register(big)
    sim2.submit(small.name, 0.0)
    sim2.submit(big.name, 40.0)  # small's RO is host-demoted (stage 2) by then
    sim2.run(until=700.0)
    node = sim2.nodes[0]
    assert sim2.completed == 2 and sim2.failed == 0
    assert node.host_evictions == 1
    assert node.ro_state[small.name] == "none"  # host copy was evicted


# ---------------------------------------------------------------------------
# alloc(): shim cudaMalloc rides the same backpressure admission path
# ---------------------------------------------------------------------------


def test_alloc_waits_with_backpressure_instead_of_raising():
    from repro.core.daemon import OutOfDeviceMemory

    d, db = _daemon(cap_mb=10, load_timeout_s=5.0)
    ra = _wreq(fn="a", w_mb=8, db=db)
    ha = d.prepare(ra)[ra.in_data[0].key]
    ha.wait(5)
    # device full: a shim cudaMalloc under transient pressure must WAIT for
    # the release (seed behavior: immediate OutOfDeviceMemory)
    threading.Timer(0.25, lambda: d.release(ra, {ra.in_data[0].key: ha})).start()
    rb = Request(function_name="b")
    hb = d.alloc(rb, "b/scratch", 8 * MB)
    assert hb.is_ready() and d.device_used == 8 * MB
    assert d.stats["oom_retries"] >= 1
    d.release(rb, {"b/scratch": hb})
    assert d.device_used == 0

    # past the deadline it still fails typed (OutOfDeviceMemory), promptly
    d2, _ = _daemon(cap_mb=4, load_timeout_s=0.3)
    t0 = time.monotonic()
    with pytest.raises(OutOfDeviceMemory):
        d2.alloc(Request(function_name="c"), "c/scratch", 8 * MB)
    assert time.monotonic() - t0 < 5.0
    assert d2.device_used == 0


# ---------------------------------------------------------------------------
# stats: loads/bytes_loaded are counted on COMPLETION, not at submit
# ---------------------------------------------------------------------------


def test_bytes_loaded_counted_on_completion_only():
    # failed load: nothing counted
    d, _ = _daemon(db=FaultyDB())
    req = _wreq()
    with pytest.raises(DataLoadError):
        d.prepare(req)[req.in_data[0].key].wait(5)
    assert d.stats["loads"] == 0 and d.stats["bytes_loaded"] == 0

    # cancelled load: nothing counted
    db = SlowCountingDB(delay=0.2)
    d2, _ = _daemon(db=db)
    req2 = _wreq(db=db)
    handles = d2.prepare(req2)
    d2.release(req2, handles)  # cancel while the loader is mid-fetch
    with pytest.raises(DataLoadError):
        handles[req2.in_data[0].key].wait(5)
    deadline = time.monotonic() + 5
    while d2.stats["load_cancellations"] == 0 and time.monotonic() < deadline:
        time.sleep(0.01)
    assert d2.stats["loads"] == 0 and d2.stats["bytes_loaded"] == 0

    # successful load: counted exactly once, even across host re-promotion
    db3 = Database()
    d3, _ = _daemon(db=db3)
    r3 = Request(function_name="f")
    db3.put("f/w", b"W", size=8 * MB)
    r3.in_data = [Data(key="f/w", size=8 * MB, dtype=DataType.READ_ONLY)]
    h3 = d3.prepare(r3)["f/w"]
    h3.wait(5)
    assert d3.stats["loads"] == 1 and d3.stats["bytes_loaded"] == 8 * MB
    d3.release(r3, {"f/w": h3})
    d3.demote_to_host("f")
    r4 = Request(function_name="f")
    r4.in_data = list(r3.in_data)
    h4 = d3.prepare(r4)["f/w"]
    h4.wait(5)  # host -> device promotion: no second count
    assert d3.stats["loads"] == 1 and d3.stats["bytes_loaded"] == 8 * MB
    assert d3.stats["host_promotions"] == 1


# ---------------------------------------------------------------------------
# SLO-aware scheduling: EDF orders the loader queue and the OOM-admission
# wait by (priority, deadline slack, arrival) — on BOTH drivers
# ---------------------------------------------------------------------------


def _slo_req(fn, w_mb, db, deadline_s=None, priority=0):
    req = _wreq(fn=fn, w_mb=w_mb, db=db)
    req.deadline_s = deadline_s
    req.priority = priority
    return req


def test_edf_admission_prefers_tightest_slack_waiter():
    for sched, expect in (("fifo", ["loose", "tight"]),
                          ("edf", ["tight", "loose"])):
        d, db = _daemon(cap_mb=10, load_timeout_s=10.0, scheduler=sched)
        hold = _wreq(fn="hold", w_mb=8, db=db)
        hh = d.prepare(hold)[hold.in_data[0].key]
        hh.wait(5)
        order = []

        def waiter(name, deadline_at, delay):
            def run():
                d.reserve_slot(8 * MB, deadline_at=deadline_at)
                order.append(name)
                d.release_slot(8 * MB)
            t = threading.Thread(target=run)
            threading.Timer(delay, t.start).start()
            return t

        now = time.monotonic()
        # loose-deadline waiter arrives FIRST, tight-deadline second
        threads = [waiter("loose", now + 60.0, 0.0),
                   waiter("tight", now + 1.0, 0.15)]
        time.sleep(0.4)  # both parked on the admission wait
        d.release(hold, {hold.in_data[0].key: hh})
        for t in threads:
            t.join(timeout=10)
            assert not t.is_alive()
        assert order == expect, f"{sched}: admitted in {order}"
        assert d.device_used == 0


def test_small_waiter_backfills_behind_blocked_big_head():
    # a huge parked head must not make a small request time out while the
    # memory it cannot use sits free: the small waiter backfills (without
    # eviction) under BOTH schedulers
    for sched in ("fifo", "edf"):
        d, db = _daemon(cap_mb=20, load_timeout_s=1.0, scheduler=sched)
        hold = _wreq(fn="hold", w_mb=10, db=db)
        hh = d.prepare(hold)[hold.in_data[0].key]
        hh.wait(5)  # 10 MB free remain
        # big head: needs 16 MB, can only ever fit after hold releases
        big_done = threading.Event()

        def big():
            try:
                d.reserve_slot(16 * MB, timeout=5.0)
                d.release_slot(16 * MB)
            finally:
                big_done.set()

        threading.Thread(target=big).start()
        time.sleep(0.15)  # big is parked at the head of the waiter heap
        t0 = time.monotonic()
        d.reserve_slot(8 * MB)  # fits in the free 10 MB: backfills now
        assert time.monotonic() - t0 < 0.5, f"{sched}: backfill was blocked"
        d.release_slot(8 * MB)
        d.release(hold, {hold.in_data[0].key: hh})
        assert big_done.wait(10)
        assert d.device_used == 0


def test_edf_loader_queue_orders_by_deadline():
    class OrderDB(Database):
        def __init__(self):
            super().__init__()
            self.order = []

        def fetch(self, key, broker=None, *, scale: float = 1.0):
            self.order.append(key.split("/")[0])
            time.sleep(0.15)
            return super().fetch(key, broker, scale=scale)

    for sched, expect in (("fifo", ["loose", "tight"]),
                          ("edf", ["tight", "loose"])):
        db = OrderDB()
        d, _ = _daemon(db=db, loader_threads=1, scheduler=sched)
        first = _slo_req("first", 1, db)  # occupies the single worker
        d.prepare(first)
        time.sleep(0.05)
        loose = _slo_req("loose", 1, db, deadline_s=60.0)
        tight = _slo_req("tight", 1, db, deadline_s=1.0)
        hl = d.prepare(loose)[loose.in_data[0].key]  # queued first
        ht = d.prepare(tight)[tight.in_data[0].key]  # queued second
        hl.wait(10)
        ht.wait(10)
        assert db.order[0] == "first"
        assert db.order[1:] == expect, f"{sched}: ran in {db.order}"
        d.shutdown()


def _mk_gpu_fn(name):
    from repro.core.engine import GPUFunction

    def handler(shim, request):
        for dd in request.in_data:
            shim.sage_load_to_gpu(dd.key).wait(30)

    return GPUFunction(name=name, handler=handler,
                       context_builder=lambda: object(),
                       context_bytes=1 * MB, container_s=0.0, cpu_ctx_s=0.0)


def _runtime_slo_replay(scheduler):
    """Contended mixed-deadline trace on the REAL runtime: one loader
    thread, four loose-deadline 500 MB loads queued ahead of one
    tight-deadline 16 MB load."""
    from repro.core.runtime import SageRuntime

    rt = SageRuntime("sage", loader_threads=1, scheduler=scheduler,
                     serialize_compute=False)
    rt.sage_init()
    for i in range(4):
        rt.register_function(_mk_gpu_fn(f"batch{i}"))
    rt.register_function(_mk_gpu_fn("crit"))
    futs = [rt.submit(_slo_req(f"batch{i}", 500, rt.db, deadline_s=30.0))
            for i in range(4)]
    time.sleep(0.1)  # batches are queued on the single loader worker
    futs.append(rt.submit(_slo_req("crit", 16, rt.db,
                                   deadline_s=1.2, priority=1)))
    for f in futs:
        f.result(timeout=60)
    rate = rt.telemetry.slo_miss_rate()
    assert rt.daemon.max_inflight_loads <= 1  # pool bound holds under EDF too
    # zero leakage after drain: writable bytes all returned; only the live
    # instances' contexts remain on device
    deadline = time.monotonic() + 5
    while (rt.daemon.device_used != rt.daemon.context_bytes_used
           or rt.daemon.host_used != 0) and time.monotonic() < deadline:
        time.sleep(0.02)
    assert rt.daemon.device_used == rt.daemon.context_bytes_used
    assert rt.daemon.host_used == 0
    rt.shutdown()
    return rate


def test_runtime_edf_strictly_beats_fifo_on_mixed_deadlines():
    fifo = _runtime_slo_replay("fifo")
    edf = _runtime_slo_replay("edf")
    assert fifo > 0.0   # FIFO makes the tight request wait out its deadline
    assert edf < fifo   # EDF admits it first: strictly fewer misses


def _sim_slo_replay(scheduler):
    """The same contended mixed-deadline shape on the virtual-time twin."""
    from repro.core.profiles import FunctionProfile

    sim = Simulator("sage", loader_threads=1, scheduler=scheduler)
    names = []
    for i in range(4):
        p = FunctionProfile(f"batch{i}", "custom", 1.0, 0.0, 500.0, 5.0)
        sim.register(SimFunction(p))
        names.append(p.name)
    sim.register(SimFunction(FunctionProfile("crit", "custom", 1.0, 0.0, 16.0, 5.0)))
    for i, n in enumerate(names):
        sim.submit(n, 0.001 * i, deadline_s=30.0, priority=0)
    sim.submit("crit", 0.05, deadline_s=1.2, priority=1)
    sim.run(until=600.0)
    node = sim.nodes[0]
    assert sim.completed == 5 and sim.failed == 0
    assert node.max_inflight_loads <= 1
    assert node.host_used == 0  # private bytes left the host tier at finish
    node._advance_ladders()  # walk idle instances off the exit ladder
    return sim.telemetry.slo_miss_rate()


def test_simulator_edf_strictly_beats_fifo_on_mixed_deadlines():
    fifo = _sim_slo_replay("fifo")
    edf = _sim_slo_replay("edf")
    assert fifo > 0.0
    assert edf < fifo


# ----------------------------------------------------------------------
# retry budget under node eviction: a request whose node dies mid-load
# either lands on a healthy node within its remaining budget or fails
# with the typed error — with exact device/host accounting either way,
# on BOTH drivers (docs/resilience.md)
# ----------------------------------------------------------------------
def _sim_crash_mid_load(max_retries):
    """Single cold request, 2 nodes; the node it lands on (determined by
    a fault-free probe run with the same seed) crashes 0.1s in — squarely
    inside the ~0.6s db leg of the 1 GB read-only load."""
    from repro.core.faults import FaultPlan, NodeCrash
    from repro.core.profiles import FunctionProfile

    def build(faults=None):
        sim = Simulator("sage", n_nodes=2, seed=11, faults=faults,
                        eviction=faults is not None)
        sim.register(SimFunction(
            FunctionProfile("f", "custom", 16.0, 1024.0, 8.0, 50.0)))
        sim.submit("f", 0.0, request_id="r0", max_retries=max_retries)
        return sim

    probe = build()
    probe.run(120.0)
    victim = next(r.node_id for r in probe.telemetry.snapshot()
                  if r.request_id == "r0")
    sim = build(FaultPlan([NodeCrash(victim, at_s=0.1)], seed=11))
    sim.run(120.0)
    rec = next(r for r in sim.telemetry.snapshot()
               if r.request_id == "r0" and not r.dropped)
    dead = next(n for n in sim.nodes if n.name == victim)
    healthy = next(n for n in sim.nodes if n.name != victim)
    assert not dead.healthy
    assert dead.used == 0 and dead.host_used == 0  # exact: nothing leaks
    assert dead.inflight_loads == 0
    return rec, victim, healthy


def test_sim_retry_budget_lands_on_healthy_node():
    rec, victim, healthy = _sim_crash_mid_load(max_retries=1)
    assert rec.error is None
    assert rec.redispatches == 1
    assert rec.node_id != victim
    # exact accounting on the rescuer: ctx + ro on device, the ro host
    # copy retained, the 8 MB writable payload fully drained
    assert healthy.used == (16 + 1024) * MB
    assert healthy.host_used == 1024 * MB


def test_sim_retry_budget_exhausted_fails_typed():
    rec, _, healthy = _sim_crash_mid_load(max_retries=0)
    assert rec.error_class == "node_lost"
    assert "NodeLostError" in rec.error
    assert rec.redispatches == 0
    # fail-fast: the request never reached the healthy node
    assert healthy.used == 0 and healthy.host_used == 0


def _runtime_crash_mid_load(max_retries):
    """The same shape on the threaded runtime: crash the node the gateway
    picked while its 512 MB read-only load is on the db leg (~0.3s)."""
    from repro.api.gateway import Gateway
    from repro.api.spec import FunctionSpec
    from repro.core.daemon import NodeLostError

    gw = Gateway(backend="runtime", n_nodes=2, seed=0, eviction=True)
    try:
        gw.register(FunctionSpec(
            name="f", read_only_bytes=512 * MB, writable_bytes=8 * MB,
            context_bytes=16 * MB, compute_ms=20.0))
        h = gw.invoke_async("f", max_retries=max_retries)
        victim = gw._nodes[h._node_idx]
        time.sleep(0.1)  # let the load reach the db leg
        assert not h._done.is_set()  # still in flight when the node dies
        victim.crash()
        if max_retries == 0:
            with pytest.raises(NodeLostError):
                h.wait(timeout=60)
            rec = h.wait(timeout=60, strict=False)
            assert rec.error_class == "node_lost"
            assert "NodeLostError" in rec.error
            assert rec.redispatches == 0
            assert gw.resilience_stats()["redispatches"] == 0
        else:
            rec = h.wait(timeout=60)
            assert rec.error is None
            assert rec.redispatches == 1
            assert rec.node_id != victim.node_id
        # exact accounting: the dead node holds nothing; on success the
        # rescuer holds ctx + ro on device and the ro host copy, with the
        # writable payload fully drained — on fail-fast it holds nothing
        mu = victim.memory_usage()
        assert mu["device_used"] == 0 and mu["host_used"] == 0
        other = next(n for n in gw._nodes if n is not victim)
        want_dev = 0 if max_retries == 0 else (
            other.daemon.context_bytes_used + 512 * MB)
        want_host = 0 if max_retries == 0 else 512 * MB
        deadline = time.monotonic() + 5
        while (other.daemon.device_used != want_dev
               or other.daemon.host_used != want_host) \
                and time.monotonic() < deadline:
            want_dev = 0 if max_retries == 0 else (
                other.daemon.context_bytes_used + 512 * MB)
            time.sleep(0.02)
        assert other.daemon.device_used == want_dev
        assert other.daemon.host_used == want_host
    finally:
        gw.shutdown()


def test_runtime_retry_budget_lands_on_healthy_node():
    _runtime_crash_mid_load(max_retries=1)


def test_runtime_retry_budget_exhausted_fails_typed():
    _runtime_crash_mid_load(max_retries=0)


# ----------------------------------------------------------------------
# hedge-loser cancellation (docs/resilience.md, "Gray failures"): the
# cancelled twin unwinds byte-exactly through the same release chain a
# failed load uses — nothing held, nothing double-counted on the link
# ----------------------------------------------------------------------
def _hedge_cancel_gateway():
    from repro.api.gateway import Gateway
    from repro.api.spec import FunctionSpec

    gw = Gateway(backend="runtime", n_nodes=1, seed=0)
    # context ~0.3s and writable ~0.45s on the default link: the pre-kernel
    # cancel checkpoint fires while the writable leg is still streaming
    gw.register(FunctionSpec(
        name="f", read_only_bytes=64 * MB, writable_bytes=768 * MB,
        context_bytes=512 * MB, compute_ms=20.0))
    return gw


def test_runtime_hedge_cancel_mid_load_byte_exact():
    """Cancelled mid-load, the loser leaves EXACTLY the residency a
    successful invocation leaves (zero delta on device/host), holds no
    loader slot, and the link counted only the loads that completed."""
    from repro.core.slowness import HedgedError

    ctl = _hedge_cancel_gateway()  # control: same spec run to completion
    try:
        ctl.invoke("f", seed=0)
        want = ctl._nodes[0].memory_usage()
        ctl_bytes = ctl._nodes[0].daemon.stats["bytes_loaded"]
    finally:
        ctl.shutdown()
    assert want["device_used"] > 0

    gw = _hedge_cancel_gateway()
    try:
        node = gw._nodes[0]
        from repro.api.gateway import DEFAULT_INPUT_BYTES
        req = gw._build_request("f", 0, seed=0,
                                input_bytes=DEFAULT_INPUT_BYTES,
                                deadline_s=None, priority=0)
        req.hedge_cancel = threading.Event()
        fut = node.submit(req)
        time.sleep(0.1)  # context load in flight (~0.3s)
        req.hedge_cancel.set()
        with pytest.raises(HedgedError):
            fut.result(timeout=60)
        rec = node.telemetry.find(req.uuid)
        assert rec is not None and rec.error.startswith("HedgedError")
        assert rec.end_t > 0.0  # finalized, never left half-open
        # zero delta vs the success path: ctx + ro resident, writable and
        # input fully drained, loader slots free
        deadline = time.monotonic() + 5
        while (node.memory_usage() != want
               or node.daemon._pool.in_flight != 0) \
                and time.monotonic() < deadline:
            time.sleep(0.02)
        assert node.memory_usage() == want
        assert node.daemon._pool.in_flight == 0
        # exact link accounting: the db legs that completed (read-only
        # share + input payload) are counted once, the cancelled context
        # leg never lands in the books (completion-only contract), and
        # the totals match the success path byte for byte
        assert node.daemon.stats["bytes_loaded"] == ctl_bytes
        assert ctl_bytes == 64 * MB + DEFAULT_INPUT_BYTES
    finally:
        gw.shutdown()


def test_runtime_hedge_cancel_before_load_loads_nothing():
    """A cancel token already set before the engine starts aborts ahead
    of the instance claim: no slot, no load, no context — every book on
    the node reads exactly zero and the link moved no bytes."""
    from repro.core.slowness import HedgedError

    gw = _hedge_cancel_gateway()
    try:
        node = gw._nodes[0]
        req = gw._build_request("f", 0, seed=0, input_bytes=MB,
                                deadline_s=None, priority=0)
        req.hedge_cancel = threading.Event()
        req.hedge_cancel.set()  # loser before it even started
        fut = node.submit(req)
        with pytest.raises(HedgedError):
            fut.result(timeout=60)
        deadline = time.monotonic() + 5
        while node.daemon._pool.in_flight != 0 \
                and time.monotonic() < deadline:
            time.sleep(0.02)
        mu = node.memory_usage()
        assert mu["device_used"] == 0 and mu["host_used"] == 0
        assert mu["context_bytes"] == 0  # the ensure never ran
        assert node.daemon._pool.in_flight == 0
        assert node.daemon.stats["bytes_loaded"] == 0
    finally:
        gw.shutdown()


# ----------------------------------------------------------------------
# release during batching (docs/compute.md): a member cancelled while
# parked in the batch collector unwinds through the SAME release chain a
# hedge loser uses — the surviving member launches, nothing leaks
# ----------------------------------------------------------------------
def test_runtime_hedge_cancel_while_parked_in_batch_no_leak():
    from repro.api.gateway import DEFAULT_INPUT_BYTES, Gateway
    from repro.api.spec import FunctionSpec
    from repro.core.slowness import HedgedError

    def make_gw():
        gw = Gateway(backend="runtime", n_nodes=1, seed=0,
                     compute={"max_batch": 4, "batch_window_s": 1.0})
        gw.register(FunctionSpec(
            name="f", read_only_bytes=8 * MB, writable_bytes=8 * MB,
            context_bytes=8 * MB, compute_ms=20.0))
        return gw

    def pair(gw, cancel_second):
        """Two concurrent members; optionally cancel the second while it
        is parked in the open batch. Returns (results, memory, stats)."""
        node = gw._nodes[0]
        reqs, futs = [], []
        for _ in range(2):
            req = gw._build_request("f", 0, seed=0,
                                    input_bytes=DEFAULT_INPUT_BYTES,
                                    deadline_s=None, priority=0)
            req.hedge_cancel = threading.Event()
            reqs.append(req)
            futs.append(node.submit(req))
        if cancel_second:
            # wait until both are parked in the collector (batch open
            # with 2 members), then cancel one mid-park
            plane = node._plane
            deadline = time.monotonic() + 10
            while time.monotonic() < deadline:
                with plane._cond:
                    b = plane._open.get("f")
                    if b is not None and len(b.requests) == 2:
                        break
                time.sleep(0.005)
            reqs[1].hedge_cancel.set()
        outcomes = []
        for fut in futs:
            try:
                fut.result(timeout=60)
                outcomes.append("ok")
            except HedgedError:
                outcomes.append("hedged")
        return outcomes, node.memory_usage(), node

    ctl = make_gw()  # control: the same pair, both run to completion
    try:
        outcomes, want, _ = pair(ctl, cancel_second=False)
        assert outcomes == ["ok", "ok"]
    finally:
        ctl.shutdown()
    assert want["device_used"] > 0

    gw = make_gw()
    try:
        outcomes, mem, node = pair(gw, cancel_second=True)
        assert outcomes == ["ok", "hedged"]
        # the survivor launched solo: its record carries no batch peers
        recs = [r for r in node.telemetry.snapshot() if r.error is None]
        assert len(recs) == 1 and recs[0].batch_size == 1
        # zero delta vs the success path: the cancelled member's claim
        # unwound byte-exactly (no leaked device_used), and the plane
        # holds no slices and no open batch
        deadline = time.monotonic() + 5
        while (node.memory_usage() != want
               or node.daemon._pool.in_flight != 0) \
                and time.monotonic() < deadline:
            time.sleep(0.02)
        assert node.memory_usage() == want
        assert node.daemon._pool.in_flight == 0
        plane = node._plane
        with plane._cond:
            assert plane._free == plane.cfg.slices
            assert not plane._open
    finally:
        gw.shutdown()
