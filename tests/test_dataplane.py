"""Hardened async data plane: bounded loader pool, failure propagation,
OOM backpressure, cancellation (no accounting leaks) — on the threaded
daemon/runtime AND the virtual-time simulator twin (docs/dataplane.md)."""
import threading
import time

import pytest

from repro.core.clock import RealClock
from repro.core.daemon import DataLoadError, MemoryDaemon, Tier
from repro.core.datapath import DataPaths
from repro.core.request import Data, DataType, Request
from repro.core.simulator import SimFunction, Simulator
from repro.core.profiles import PROFILES
from repro.data.database import Database

MB = 1 << 20


def _daemon(cap_mb=1024, db=None, **kw):
    db = db or Database()
    paths = DataPaths.make(db_bw=1e12, pcie_bw=1e12)  # near-instant for tests
    return MemoryDaemon(paths, db, device_capacity=cap_mb * MB, **kw), db


def _wreq(fn="f", w_mb=8, db=None):
    """Request with one writable datum (freed fully on release)."""
    req = Request(function_name=fn)
    key = f"{fn}/in/{req.uuid}"
    if db is not None:
        db.put(key, b"X", size=w_mb * MB)
    req.in_data = [Data(key=key, size=w_mb * MB, dtype=DataType.WRITABLE)]
    return req


class FaultyDB(Database):
    """Database whose fetch always faults."""

    def fetch(self, key, broker=None, *, scale: float = 1.0):
        raise IOError(f"simulated database fault for {key}")


class SlowCountingDB(Database):
    """Database that tracks concurrent fetches (the db-path instrumentation
    for the loader-concurrency bound)."""

    def __init__(self, delay: float = 0.05):
        super().__init__()
        self.delay = delay
        self._c = threading.Lock()
        self.cur = 0
        self.max_concurrent = 0

    def fetch(self, key, broker=None, *, scale: float = 1.0):
        with self._c:
            self.cur += 1
            self.max_concurrent = max(self.max_concurrent, self.cur)
        try:
            time.sleep(self.delay)
            return super().fetch(key, broker, scale=scale)
        finally:
            with self._c:
                self.cur -= 1


# ---------------------------------------------------------------------------
# failure propagation
# ---------------------------------------------------------------------------


def test_db_fault_propagates_as_dataloaderror():
    d, _ = _daemon(db=FaultyDB())
    req = _wreq(db=None)
    h = d.prepare(req)[req.in_data[0].key]
    with pytest.raises(DataLoadError) as ei:
        h.wait(5)  # seed behavior: hung forever here
    assert isinstance(ei.value.cause, IOError)
    assert d.stats["load_failures"] == 1
    assert d.device_used == 0 and d.host_used == 0


def test_oom_past_deadline_fails_instead_of_hanging():
    d, db = _daemon(cap_mb=4, load_timeout_s=0.3)
    req = _wreq(w_mb=8, db=db)  # 8 MB datum can never fit in 4 MB
    h = d.prepare(req)[req.in_data[0].key]
    t0 = time.monotonic()
    with pytest.raises(DataLoadError):
        h.wait(10)
    assert time.monotonic() - t0 < 5.0
    assert d.stats["load_failures"] == 1
    assert d.device_used == 0 and d.host_used == 0
    # the failed entry is not resurrected as a shared hit
    assert h.entry.tier is Tier.FAILED


def test_failed_handle_is_not_ready():
    d, _ = _daemon(db=FaultyDB())
    req = _wreq()
    h = d.prepare(req)[req.in_data[0].key]
    h.entry.ready.wait(5)
    assert not h.is_ready()


# ---------------------------------------------------------------------------
# OOM backpressure: waiting loads are admitted when memory frees up
# ---------------------------------------------------------------------------


def test_load_blocked_on_oom_admitted_after_release():
    d, db = _daemon(cap_mb=10, load_timeout_s=5.0)
    ra = _wreq(fn="a", w_mb=8, db=db)
    ha = d.prepare(ra)[ra.in_data[0].key]
    ha.wait(5)
    assert d.device_used == 8 * MB

    rb = _wreq(fn="b", w_mb=8, db=db)
    hb = d.prepare(rb)[rb.in_data[0].key]
    # b cannot be admitted while a holds the device
    threading.Timer(0.25, lambda: d.release(ra, {ra.in_data[0].key: ha})).start()
    assert hb.wait(10) is not None  # admitted after a's release
    assert d.stats["oom_retries"] >= 1
    d.release(rb, {rb.in_data[0].key: hb})
    assert d.device_used == 0 and d.host_used == 0


# ---------------------------------------------------------------------------
# cancellation: release() of a still-loading writable entry
# ---------------------------------------------------------------------------


def test_release_while_loading_cancels_without_leak():
    db = SlowCountingDB(delay=0.2)
    d, _ = _daemon(db=db)
    req = _wreq(db=db)
    handles = d.prepare(req)
    # release immediately: the loader is still in the db fetch
    d.release(req, handles)
    h = handles[req.in_data[0].key]
    with pytest.raises(DataLoadError):
        h.wait(5)
    deadline = time.monotonic() + 5
    while (d.device_used or d.host_used) and time.monotonic() < deadline:
        time.sleep(0.01)
    assert d.device_used == 0 and d.host_used == 0
    assert d.stats["load_cancellations"] == 1


# ---------------------------------------------------------------------------
# bounded loader concurrency (db/PCIe path instrumentation)
# ---------------------------------------------------------------------------


def test_prepare_after_shutdown_resolves_synchronously():
    d, db = _daemon()
    d.shutdown()
    req = _wreq(db=db)
    h = d.prepare(req)[req.in_data[0].key]
    assert h.wait(5) is not None  # degraded to inline load, never parked


def test_unpooled_daemon_still_propagates_failures():
    # baseline platforms run with pooled=False (per-load threads); the
    # failure/cancellation contract is identical
    d, _ = _daemon(db=FaultyDB(), pooled=False)
    req = _wreq()
    h = d.prepare(req)[req.in_data[0].key]
    with pytest.raises(DataLoadError):
        h.wait(5)
    assert d.device_used == 0 and d.host_used == 0


def test_loader_concurrency_never_exceeds_pool_size():
    db = SlowCountingDB(delay=0.05)
    d, _ = _daemon(db=db, loader_threads=3)
    reqs = [_wreq(fn=f"f{i}", w_mb=1, db=db) for i in range(10)]
    handles = [d.prepare(r)[r.in_data[0].key] for r in reqs]
    for h in handles:
        h.wait(10)
    assert db.max_concurrent <= 3
    assert d.max_inflight_loads <= 3
    assert d.max_inflight_loads >= 2  # the pool actually ran concurrently


# ---------------------------------------------------------------------------
# burst stress: capacity below the working set, N concurrent submits —
# every future resolves (success after backpressure/eviction OR
# DataLoadError); accounting returns to the pre-burst baseline
# ---------------------------------------------------------------------------


def test_burst_under_capacity_no_hang_no_leak():
    db = Database()
    d, _ = _daemon(cap_mb=20, db=db, loader_threads=4, load_timeout_s=3.0)
    base_dev, base_host = d.device_used, d.host_used
    n = 12
    reqs = [_wreq(fn=f"f{i}", w_mb=8, db=db) for i in range(n)]  # 96 MB >> 20
    results = [None] * n

    def run(i):
        req = reqs[i]
        handles = d.prepare(req)
        try:
            handles[req.in_data[0].key].wait(15)
            results[i] = "ok"
        except DataLoadError:
            results[i] = "failed"
        finally:
            d.release(req, handles)

    threads = [threading.Thread(target=run, args=(i,)) for i in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=30)
        assert not t.is_alive(), "a Handle.wait() hung past its timeout"
    assert all(r in ("ok", "failed") for r in results)
    assert results.count("ok") >= 2  # backpressure admitted at least the 2 that fit
    # cancellation/rollback may lag release by one loader checkpoint
    deadline = time.monotonic() + 10
    while (d.device_used != base_dev or d.host_used != base_host) \
            and time.monotonic() < deadline:
        time.sleep(0.02)
    assert d.device_used == base_dev
    assert d.host_used == base_host


def test_runtime_burst_errors_surface_in_telemetry():
    """Engine layer: loader failures land in InvocationRecord.error and the
    future raises — the runtime pool never deadlocks on a dead loader."""
    from repro.core.runtime import SageRuntime
    from repro.core.functions import make_model_function, make_request

    rt = SageRuntime("sage", time_scale=0.0, exit_ttl=30.0,
                     device_capacity=2048 * MB, load_timeout_s=2.0)
    rt.sage_init()
    # declared working set far above device capacity -> admission can never
    # succeed; the invocation must FAIL (typed), not hang
    fn = make_model_function(rt.db, "big", arch="qwen2.5-3b",
                             declared_ro_bytes=8192 * MB)
    rt.register_function(fn)
    fut = rt.submit(make_request(rt.db, fn))
    with pytest.raises(DataLoadError):
        fut.result(timeout=60)
    assert rt.telemetry.error_count() == 1
    assert "DataLoadError" in rt.telemetry.errors()[0].error
    rt.shutdown()


# ---------------------------------------------------------------------------
# virtual-time twin: same bound, same failure semantics
# ---------------------------------------------------------------------------


def test_simulator_loader_bound_enforced():
    sim = Simulator("sage-nr", loader_threads=2)  # NR: every load is private
    f = SimFunction(PROFILES["resnet50"])
    sim.register(f)
    for i in range(12):
        sim.submit(f.name, 0.001 * i)
    sim.run(until=600.0)
    node = sim.nodes[0]
    assert sim.completed == 12
    assert node.max_inflight_loads <= 2
    assert node.max_inflight_loads >= 2  # the gate actually saturated


def test_simulator_failure_semantics_mirror_daemon():
    # capacity below one invocation's working set: the twin must resolve
    # every arrival as completed-or-failed (error recorded), never stuck
    sim = Simulator("fixedgsl", capacity=256 << 20, load_timeout_s=1.0)
    f = SimFunction(PROFILES["bert"])  # ~1.7 GB slot >> 256 MB
    sim.register(f)
    for i in range(4):
        sim.submit(f.name, 0.001 * i)
    sim.run(until=600.0)
    assert sim.failed == 4 and sim.completed == 0
    errs = sim.telemetry.errors()
    assert len(errs) == 4
    assert all("DataLoadError" in r.error for r in errs)
    assert all(r.end_t is not None for r in errs)
    node = sim.nodes[0]
    assert node.used == 0  # failed reservations hold nothing


def test_simulator_backpressure_admits_when_memory_frees():
    # two invocations with PRIVATE working sets (NR mode), device fits one:
    # the second waits for the first's release, then completes — no failure
    sim = Simulator("sage-nr", capacity=2 << 30, exit_ttl=0.5, load_timeout_s=300.0)
    f = SimFunction(PROFILES["bert"])
    sim.register(f)
    sim.submit(f.name, 0.0)
    sim.submit(f.name, 0.01)
    sim.run(until=900.0)
    assert sim.completed == 2 and sim.failed == 0
