"""HLO analyzer: exact dot-FLOP counting with scan (while) multipliers, and
collective byte attribution — validated against hand-computed programs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.hlo_analysis import (
    analyze_compiled, analyze_hlo_text, xla_cost_analysis,
)
from repro.analysis.roofline import model_flops, roofline_from_report
from repro.configs import ARCHS


def test_single_matmul_flops():
    f = lambda a, b: a @ b
    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((64, 128), jnp.float32),
        jax.ShapeDtypeStruct((128, 32), jnp.float32),
    ).compile()
    rep = analyze_hlo_text(c.as_text())
    assert rep.dot_flops == 2 * 64 * 128 * 32


def test_scan_multiplies_flops():
    def f(w, x):
        def body(h, wi):
            return h @ wi, None
        h, _ = jax.lax.scan(body, x, w)
        return h

    n = 7
    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((n, 32, 32), jnp.float32),
        jax.ShapeDtypeStruct((32, 32), jnp.float32),
    ).compile()
    rep = analyze_hlo_text(c.as_text())
    assert rep.dot_flops == n * 2 * 32 * 32 * 32
    assert n in rep.while_trips
    # XLA's own count misses the trip multiplier — that's why we parse
    xla = xla_cost_analysis(c).get("flops", 0)
    assert xla < rep.dot_flops


def test_nested_scan_multiplies_twice():
    def f(w, x):
        def outer(h, wi):
            def inner(h2, _):
                return h2 @ wi, None
            h2, _ = jax.lax.scan(inner, h, None, length=3)
            return h2, None
        h, _ = jax.lax.scan(outer, x, w)
        return h

    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((5, 16, 16), jnp.float32),
        jax.ShapeDtypeStruct((16, 16), jnp.float32),
    ).compile()
    rep = analyze_hlo_text(c.as_text())
    assert rep.dot_flops == 5 * 3 * 2 * 16 * 16 * 16


def test_roofline_terms_and_dominance():
    cfg = ARCHS["qwen3-8b"]
    report = {
        "flops": 1e12, "dot_flops": 1e12, "hbm_bytes": 1e12,
        "collective_bytes": 1e10, "collective_traffic_bytes": 1e10,
    }
    r = roofline_from_report(cfg, report, chips=256, mode="train",
                             tokens=1_000_000)
    assert r["dominant"] == "memory_s"  # 1e12/819e9 > 1e12/197e12
    np.testing.assert_allclose(r["compute_s"], 1e12 / 197e12)
    np.testing.assert_allclose(r["memory_s"], 1e12 / 819e9)
    np.testing.assert_allclose(r["collective_s"], 1e10 / 50e9)
    assert 0 < r["roofline_fraction"] <= 1.5


def test_model_flops_moe_uses_active_params():
    dense = ARCHS["qwen3-32b"]
    moe = ARCHS["llama4-maverick-400b-a17b"]
    assert moe.active_param_count() < 0.1 * moe.param_count()
    f_dense = model_flops(dense, "train", 1000)
    assert f_dense == 6.0 * dense.param_count() * 1000
    f_moe = model_flops(moe, "decode", 10)
    assert f_moe == 2.0 * moe.active_param_count() * 10


def test_param_counts_sane():
    """Analytic totals should land near the marketing numbers."""
    assert 6.5e10 < ARCHS["qwen2-vl-72b"].param_count() < 8.2e10
    assert 6.0e8 < ARCHS["mamba2-780m"].param_count() < 9.5e8
    assert 5.5e9 < ARCHS["olmoe-1b-7b"].param_count() < 8.0e9
    assert 3.3e11 < ARCHS["llama4-maverick-400b-a17b"].param_count() < 4.7e11
    assert 3.2e11 < ARCHS["jamba-1.5-large-398b"].param_count() < 4.6e11
    assert 2.7e10 < ARCHS["qwen3-32b"].param_count() < 3.7e10
    assert 2.4e9 < ARCHS["qwen2.5-3b"].param_count() < 3.6e9
    assert 6.5e9 < ARCHS["qwen3-8b"].param_count() < 9.0e9
    assert 3.2e9 < ARCHS["phi4-mini-3.8b"].param_count() < 4.6e9
    assert 1.8e8 < ARCHS["whisper-small"].param_count() < 3.5e8
    # MoE actives
    assert 0.9e9 < ARCHS["olmoe-1b-7b"].active_param_count() < 1.6e9
    assert 1.2e10 < ARCHS["llama4-maverick-400b-a17b"].active_param_count() < 2.4e10


def test_collective_bytes_all_gather():
    from jax.sharding import NamedSharding, PartitionSpec as P

    if jax.device_count() < 2:
        pytest.skip("needs >1 device (dry-run covers multi-device)")
