"""Virtual-time simulator: determinism + the paper's ordering properties."""
import pytest

from repro.core.profiles import PROFILES
from repro.core.simulator import (
    SimFunction, Simulator, maf_like_trace, poisson_arrivals,
)

NAMES = list(PROFILES)


def _run(system, trace, seed=1, **kw):
    sim = Simulator(system, seed=seed, **kw)
    for n in NAMES:
        sim.register(SimFunction(PROFILES[n]))
    for t, f in trace:
        sim.submit(f, t)
    sim.run(until=10 * (trace[-1][0] if trace else 1.0) + 100.0)
    return sim


@pytest.fixture(scope="module")
def trace():
    return maf_like_trace(NAMES, duration_s=300.0, seed=3, mean_rpm=20)


def test_deterministic(trace):
    a = _run("sage", trace)
    b = _run("sage", trace)
    assert a.completed == b.completed
    assert abs(a.telemetry.mean_e2e() - b.telemetry.mean_e2e()) < 1e-12


def test_all_requests_complete(trace):
    for system in ("sage", "fixedgsl", "dgsf", "sage-nr"):
        sim = _run(system, trace)
        assert sim.completed == len(trace), system


def test_sage_latency_beats_baselines(trace):
    e2e = {s: _run(s, trace).telemetry.mean_e2e()
           for s in ("sage", "fixedgsl", "dgsf", "sage-nr")}
    assert e2e["sage"] < e2e["dgsf"] < e2e["fixedgsl"]
    assert e2e["sage"] < e2e["sage-nr"]  # read-only sharing matters (Fig 16)


def test_sage_uses_least_memory(trace):
    mem = {s: _run(s, trace).mean_memory_bytes()
           for s in ("sage", "fixedgsl", "dgsf")}
    assert mem["sage"] < mem["fixedgsl"]
    assert mem["sage"] < mem["dgsf"]


def test_sage_warm_hits_dominate(trace):
    sim = _run("sage", trace)
    assert sim.telemetry.warm_fraction() > 0.8


def test_parallel_setup_hides_a_stage():
    """Cold SAGE-PS end-to-end ~= max(ctx, data) + compute, not their sum."""
    from repro.core.simulator import CPU_CTX_S, GPU_CTX_S

    f = SimFunction(PROFILES["resnet50"])
    solo_data = f.ro_bytes / 1.63e9 + f.ro_bytes / 5.05e9 + \
        f.w_bytes / 1.63e9 + f.w_bytes / 5.05e9
    sim = Simulator("sage-ps", seed=0)
    sim.register(f)
    sim.submit("resnet50", 0.0)
    sim.run(until=100.0)
    e2e = sim.telemetry.records[0].e2e
    serial = CPU_CTX_S + GPU_CTX_S + solo_data + f.compute_s
    parallel_bound = max(GPU_CTX_S + CPU_CTX_S, solo_data) + f.compute_s
    assert e2e < 0.9 * serial           # visibly better than serial
    assert e2e < parallel_bound * 1.35  # close to the overlap bound


def test_fixed_slot_granularity_caps_density():
    """1 GiB slot rounding pins more memory than exact-size allocation (the
    flexible variant instead suffers more data-path contention — the paper's
    FixedGSL-F finding; latency ordering between the two is load-dependent,
    so only the memory claim is asserted)."""
    burst = [(0.0 + i * 1e-3, "bert") for i in range(40)]
    gsl = _run("fixedgsl", burst, capacity=8 << 30)
    flex = _run("fixedgsl-f", burst, capacity=8 << 30)
    assert gsl.completed == flex.completed == 40
    assert gsl.mean_memory_bytes() > flex.mean_memory_bytes()


def test_poisson_arrivals_rate():
    import random

    arr = poisson_arrivals(10.0, 100.0, random.Random(0))
    assert 800 < len(arr) < 1200
