"""Hypothesis property tests on system invariants."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.clock import VirtualClock
from repro.core.datapath import BandwidthBroker
from repro.core.exit_policy import ExitLadder
from repro.training.compression import dequantize, quantize_int8

SETTINGS = dict(max_examples=40, deadline=None)


@settings(**SETTINGS)
@given(
    ttls=st.tuples(*[st.floats(0.01, 100.0) for _ in range(4)]),
    t_complete=st.floats(0.0, 1e6),
    dt=st.floats(0.0, 1e7),
)
def test_ladder_stage_monotonic_nondecreasing(ttls, t_complete, dt):
    """Stages only move forward in time; stage is within [1, 5]."""
    lad = ExitLadder(ttls=ttls)
    lad.on_complete(t_complete)
    s1 = lad.stage_at(t_complete + dt / 2)
    s2 = lad.stage_at(t_complete + dt)
    assert 1 <= s1 <= s2 <= 5


@settings(**SETTINGS)
@given(
    ttls=st.tuples(*[st.floats(0.01, 50.0) for _ in range(4)]),
    checks=st.lists(st.floats(0.0, 300.0), min_size=1, max_size=8),
)
def test_ladder_actions_fire_exactly_once_each(ttls, checks):
    fired = []
    lad = ExitLadder(ttls=ttls)
    lad.on_enter = {k: (lambda k=k: fired.append(k)) for k in (2, 3, 4)}
    lad.on_complete(0.0)
    for t in sorted(checks):
        lad.advance(t)
    assert fired == sorted(set(fired))  # in order, no duplicates


@settings(**SETTINGS)
@given(
    sizes=st.lists(st.integers(1, 200) , min_size=1, max_size=10),
    bw=st.floats(10.0, 1e4),
)
def test_broker_conservation_and_fairness(sizes, bw):
    """All virtual transfers complete; total busy time >= total_bytes / bw
    (a shared link can never beat its own bandwidth)."""
    clock = VirtualClock()
    b = BandwidthBroker(bw, clock)
    done = []
    for s in sizes:
        b.sim_transfer(float(s), lambda s=s: done.append((s, clock.now())))
    clock.run_until(1e9)
    assert len(done) == len(sizes)
    t_end = max(t for _, t in done)
    assert t_end >= 0.99 * sum(sizes) / bw  # conservation bound
    # no transfer finished faster than its solo time
    for s, t in done:
        assert t >= 0.99 * s / bw


@settings(**SETTINGS)
@given(
    arr=st.lists(st.floats(-1e3, 1e3, allow_nan=False, width=32),
                 min_size=1, max_size=64),
)
def test_int8_error_feedback_bounded(arr):
    """Quantization error per step is bounded by the scale, and the residual
    carries it exactly (x + r_in = q*scale + r_out)."""
    x = jnp.asarray(arr, jnp.float32)
    r = jnp.zeros_like(x)
    q, scale, r2 = quantize_int8(x, r)
    np.testing.assert_allclose(
        np.asarray(x + r), np.asarray(dequantize(q, scale) + r2), rtol=1e-5,
        atol=1e-5 * float(scale),
    )
    assert float(jnp.max(jnp.abs(r2))) <= float(scale) * 0.5 + 1e-6


@settings(**SETTINGS)
@given(st.data())
def test_int8_error_feedback_converges_on_repeat(data):
    """Feeding the same gradient repeatedly, the accumulated dequantized sum
    tracks the true sum (error feedback prevents bias accumulation)."""
    n = data.draw(st.integers(4, 32))
    g = np.asarray(data.draw(st.lists(
        st.floats(-10, 10, allow_nan=False, width=32), min_size=8, max_size=8)),
        np.float32)
    r = jnp.zeros(8, jnp.float32)
    acc = np.zeros(8, np.float64)
    for _ in range(n):
        q, s, r = quantize_int8(jnp.asarray(g), r)
        acc += np.asarray(dequantize(q, s), np.float64)
    true = g.astype(np.float64) * n
    scale_bound = max(np.abs(g).max() / 127.0, 1e-12)
    np.testing.assert_allclose(acc, true, atol=2 * scale_bound + 1e-6)


@settings(**SETTINGS)
@given(
    B=st.integers(1, 3), S=st.integers(2, 24),
    Hkv=st.sampled_from([1, 2]), G=st.sampled_from([1, 2, 4]),
    Dh=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_rowsum_property(B, S, Hkv, G, Dh, seed):
    """With v = ones, attention output must be exactly ones (softmax rows
    sum to 1) for any causal mask pattern."""
    from repro.models.layers import flash_attention_ref

    key = jax.random.PRNGKey(seed)
    Hq = Hkv * G
    q = jax.random.normal(key, (B, S, Hq, Dh))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, Hkv, Dh))
    v = jnp.ones((B, S, Hkv, Dh))
    out = flash_attention_ref(q, k, v, causal=True, block_q=8, block_k=8)
    np.testing.assert_allclose(np.asarray(out), 1.0, atol=2e-5)


@settings(max_examples=20, deadline=None)
@given(
    steps=st.lists(st.integers(0, 500), min_size=1, max_size=5, unique=True),
    host_split=st.sampled_from([1, 2, 4]),
)
def test_pipeline_deterministic_and_host_sharded(steps, host_split):
    """batch_at is pure in (seed, step); host shards partition the batch."""
    from repro.data.pipeline import DataConfig, TokenPipeline

    cfg = DataConfig(vocab_size=97, global_batch=8, seq_len=16, seed=5)
    p = TokenPipeline(cfg)
    for s in steps:
        b1 = p.batch_at(s)
        b2 = p.batch_at(s)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        per = cfg.global_batch // host_split
        for h in range(host_split):
            bh = p.batch_at(s, host_id=h, num_hosts=host_split)
            assert bh["tokens"].shape == (per, cfg.seq_len)
