"""Optimizer, loss, microbatching, and DP-compressed step equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.distributed.compat import make_mesh
from repro.training.loss import lm_loss
from repro.training.optimizer import OptimizerConfig, adamw_init, adamw_update, lr_at
from repro.training.steps import (
    init_dp_state, init_train_state, make_dp_compressed_step, make_train_step,
)


def test_adamw_descends_quadratic():
    cfg = OptimizerConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                          total_steps=1000, min_lr_ratio=1.0, clip_norm=0.0)
    params = {"x": jnp.asarray([5.0, -3.0])}
    opt = adamw_init(cfg, params)
    for _ in range(200):
        grads = {"x": 2 * params["x"]}
        params, opt, _ = adamw_update(cfg, grads, opt, params)
    assert float(jnp.max(jnp.abs(params["x"]))) < 1e-2


def test_lr_schedule_shape():
    cfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_ratio=0.1)
    lrs = [float(lr_at(cfg, jnp.asarray(s))) for s in (0, 5, 10, 55, 100)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 0.5) < 1e-6           # mid-warmup
    assert abs(lrs[2] - 1.0) < 1e-6           # peak
    assert lrs[2] > lrs[3] > lrs[4]           # cosine decay
    assert abs(lrs[4] - 0.1) < 1e-6           # floor


def test_loss_matches_manual_ce():
    cfg = ARCHS["qwen2.5-3b"].reduced()
    from repro.models import forward, init_params

    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab_size)
    total, metrics = lm_loss(cfg, params, {"tokens": toks}, z_loss=0.0)
    logits, _ = forward(cfg, params, {"tokens": toks})
    logp = jax.nn.log_softmax(logits[:, :-1], -1)
    manual = -jnp.take_along_axis(logp, toks[:, 1:, None], -1).mean()
    np.testing.assert_allclose(float(metrics["loss"]), float(manual), rtol=1e-5)


def test_loss_mask_zeroes_positions():
    cfg = ARCHS["qwen2.5-3b"].reduced()
    from repro.models import init_params

    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab_size)
    mask_all = jnp.ones((1, 8))
    mask_half = mask_all.at[:, 4:].set(0.0)
    _, m1 = lm_loss(cfg, params, {"tokens": toks, "loss_mask": mask_all})
    _, m2 = lm_loss(cfg, params, {"tokens": toks, "loss_mask": mask_half})
    assert float(m2["tokens"]) < float(m1["tokens"])
    assert np.isfinite(float(m2["loss"]))


def test_microbatching_matches_full_batch():
    """grad accumulation over microbatches == single big batch: loss and
    global grad-norm identical to fp tolerance across two steps. (Raw param
    tensors are NOT compared: Adam's first-step normalization m/sqrt(v)
    amplifies 1e-8 fp-accumulation noise to ~lr on zero-grad directions.)"""
    cfg = ARCHS["qwen2.5-3b"].reduced()
    opt = OptimizerConfig(lr=1e-2, warmup_steps=0, total_steps=10,
                          min_lr_ratio=1.0)
    s1 = init_train_state(cfg, opt, jax.random.PRNGKey(0))
    s2 = jax.tree_util.tree_map(jnp.copy, s1)
    step1 = make_train_step(cfg, opt, microbatches=1)
    step2 = make_train_step(cfg, opt, microbatches=2)
    for i in range(2):
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(i), (4, 16),
                                              0, cfg.vocab_size)}
        s1, m1 = step1(s1, batch)
        s2, m2 = step2(s2, batch)
        np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                                   rtol=2e-3)
        np.testing.assert_allclose(float(m1["grad_norm"]),
                                   float(m2["grad_norm"]), rtol=1e-3)


def test_dp_compressed_step_tracks_uncompressed():
    """On a 1-device mesh the compressed all-reduce is a no-op collective;
    the int8 quantization error must stay within the quantization bound and
    training must still descend."""
    cfg = ARCHS["qwen2.5-3b"].reduced()
    opt = OptimizerConfig(lr=1e-3, warmup_steps=0, total_steps=20,
                          min_lr_ratio=1.0)
    mesh = make_mesh((1,), ("data",))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                          cfg.vocab_size)}
    state = init_dp_state(cfg, opt, jax.random.PRNGKey(0))
    step = make_dp_compressed_step(cfg, opt, mesh, compress=True)
    losses = []
    for _ in range(5):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0]
