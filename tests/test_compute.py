"""Shared compute plane (docs/compute.md): slicing arithmetic, deterministic
packing, the EDF-slack batching guard, knob plumbing, defaults-off identity,
and runtime<->sim batch/stat parity."""
import threading
import time

import pytest

from repro.api.gateway import Gateway
from repro.api.spec import FunctionSpec
from repro.api.workload import Arrival, TraceWorkload
from repro.core.compute import (
    ComputeConfig,
    ComputePlane,
    batch_hold_s,
    batched_span,
    empty_compute_stats,
    resolve_compute,
    slices_for,
)
from repro.core.profiles import MB, FunctionProfile
from repro.core.simulator import SimFunction, Simulator

SMALL = dict(context_mb=1.0, read_only_mb=1.0, writable_mb=0.5)


def _fn(name="f", compute_ms=10.0, sm_fraction=None):
    return SimFunction(FunctionProfile(name, "t", compute_ms=compute_ms,
                                       **SMALL), sm_fraction=sm_fraction)


# ----------------------------------------------------------------------
# knob normalization + pure arithmetic
# ----------------------------------------------------------------------
def test_resolve_compute_forms():
    assert resolve_compute(None) is None
    assert resolve_compute("exclusive") is None
    # the explicit off-config resolves to the SAME off-state as None, so
    # every consumer has exactly one exclusive path to keep bit-identical
    assert resolve_compute(ComputeConfig(mode="exclusive")) is None
    assert resolve_compute("shared") == ComputeConfig()
    assert resolve_compute(True) == ComputeConfig()
    cfg = resolve_compute({"max_batch": 4, "slices": 4})
    assert cfg == ComputeConfig(max_batch=4, slices=4)
    with pytest.raises(ValueError, match="compute"):
        resolve_compute(7)


def test_compute_config_validation():
    for bad in (dict(mode="mps"), dict(slices=0), dict(max_batch=0),
                dict(batch_window_s=-0.1), dict(batch_marginal=1.5),
                dict(auto_full_ms=0.0)):
        with pytest.raises(ValueError):
            ComputeConfig(**bad)


def test_slices_for_declared_and_auto():
    cfg = ComputeConfig()
    # declared fractions quantize UP onto the 8-slice grid
    assert slices_for(cfg, 1.0, 0.0) == 8
    assert slices_for(cfg, 0.5, 0.0) == 4
    assert slices_for(cfg, 0.3, 0.0) == 3
    assert slices_for(cfg, 0.01, 0.0) == 1
    # auto mode scales the profiled compute stage against auto_full_ms
    assert slices_for(cfg, None, 0.005) == 1    # 5 ms / 40 ms -> 1/8
    assert slices_for(cfg, None, 0.015) == 3
    assert slices_for(cfg, None, 0.040) == 8
    assert slices_for(cfg, None, 9.0) == 8      # clamped to the budget


def test_batched_span_model():
    assert batched_span(0.01, 1, 0.3) == 0.01
    assert batched_span(0.01, 4, 0.3) == pytest.approx(0.019)
    assert batched_span(0.01, 4, 0.0) == pytest.approx(0.01)  # free stacking


def test_batch_hold_never_exceeds_edf_slack():
    cfg = ComputeConfig(batch_window_s=0.5)
    # no deadline: the full window
    assert batch_hold_s(cfg, 1.0, 1.0, None, 0.01) == 0.5
    # slack below the window caps the hold
    assert batch_hold_s(cfg, 1.0, 1.0, 0.1, 0.01) == pytest.approx(0.09)
    # already out of slack: zero hold, never negative
    assert batch_hold_s(cfg, 1.0, 0.0, 0.5, 0.01) == 0.0
    # with batching on, the slack is charged the worst-case stacked span
    cfg4 = ComputeConfig(batch_window_s=0.5, max_batch=4)
    assert batch_hold_s(cfg4, 1.0, 1.0, 0.1, 0.01) == pytest.approx(
        0.1 - batched_span(0.01, 4, cfg4.batch_marginal))


# ----------------------------------------------------------------------
# sim plane: deterministic packing + contention stretch
# ----------------------------------------------------------------------
def test_plane_packing_deterministic_and_contended():
    cfg = ComputeConfig(slices=8)
    ops = [(0.0, 4, 1.0), (0.0, 4, 1.0), (0.0, 4, 1.0), (0.5, 2, 1.0)]
    a, b = ComputePlane(cfg), ComputePlane(cfg)
    assert [a.acquire(*op) for op in ops] == [b.acquire(*op) for op in ops]

    p = ComputePlane(cfg)
    assert p.acquire(0.0, 4, 1.0) == (0.0, 1.0)  # 4 of 8: co-runs
    assert p.acquire(0.0, 4, 1.0) == (0.0, 1.0)  # budget exactly full
    # fully busy: the grant queues for the earliest free instant
    assert p.acquire(0.0, 4, 1.0) == (1.0, 1.0)
    assert p.grants == 3 and p.contended_grants == 0
    # only 4 slices idle at 1.0 (the queued grant holds the rest): a k=8
    # ask is granted short and its span stretches by k/g = 2x
    assert p.acquire(1.0, 8, 1.0) == (1.0, 2.0)
    assert p.contended_grants == 1
    p2 = ComputePlane(cfg)
    p2.acquire(0.0, 6, 1.0)
    start, span = p2.acquire(0.0, 4, 1.0)  # only 2 idle at start
    assert (start, span) == (0.0, 2.0)
    assert p2.contended_grants == 1


def test_plane_free_fraction_and_reset():
    p = ComputePlane(ComputeConfig(slices=8))
    assert p.free_fraction(0.0) == 1.0
    p.acquire(0.0, 4, 1.0)
    assert p.free_fraction(0.5) == 0.5
    assert p.free_fraction(1.5) == 1.0  # grant expired
    p.acquire(2.0, 8, 5.0)
    p.reset()  # crash teardown: in-flight grants die with the epoch
    assert p.free_fraction(2.0) == 1.0


# ----------------------------------------------------------------------
# sim driver: determinism, EDF-slack guard, defaults-off identity
# ----------------------------------------------------------------------
def _shared_sim(compute, seed=5):
    sim = Simulator("sage", n_nodes=2, seed=seed, scheduler="edf",
                    dispatch="locality", compute=compute)
    for name in ("a", "b"):
        sim.register(_fn(name))
    for i in range(40):
        sim.submit("a" if i % 2 else "b", 0.01 * i, deadline_s=2.0,
                   priority=1, request_id=f"r{i}")
    sim.run()
    return sim


def test_sim_shared_replay_deterministic():
    cfg = {"max_batch": 4, "batch_window_s": 0.02}
    key = lambda t: [(r.request_id, r.node_id, r.start_t, r.end_t,
                      r.batch_size, r.batched_with)
                     for r in t.snapshot()]
    assert key(_shared_sim(cfg).telemetry) == key(_shared_sim(cfg).telemetry)


def test_sim_batch_window_never_creates_slo_miss():
    """A huge collection window must not hold a tight member past its EDF
    slack: the hold is capped at arrival + deadline - now - est."""
    sim = Simulator("sage", n_nodes=1, seed=1,
                    compute={"max_batch": 8, "batch_window_s": 10.0})
    sim.register(_fn(compute_ms=10.0))
    sim.submit("f", 0.0, request_id="warm")  # absorb the cold start
    sim.submit("f", 5.0, deadline_s=0.2, request_id="tight")
    sim.run()
    rec = sim.telemetry.find("tight")
    assert rec.error is None and not rec.slo_miss
    assert rec.end_t <= 5.2 + 1e-9
    assert rec.end_t > 5.1   # ...but it DID wait out its real slack
    # and the wait paid off: it coalesced with the parked no-deadline member
    assert rec.batch_size == 2 and rec.batched_with == ("warm",)


def test_sim_defaults_identical_to_explicit_exclusive():
    base = _shared_sim(None)
    excl = _shared_sim({"mode": "exclusive"})
    key = lambda t: [(r.request_id, r.node_id, r.start_t, r.end_t)
                     for r in t.snapshot()]
    assert key(base.telemetry) == key(excl.telemetry)
    assert all(n.compute_plane is None for n in excl.nodes)
    assert excl.compute_stats() == empty_compute_stats("exclusive", 0)


def test_sim_shared_beats_exclusive_on_contended_smalls():
    """Three 1/8-GPU functions serialize on the seed FIFO but co-run on
    the shared plane — the tentpole effect, in miniature."""
    def run(compute):
        sim = Simulator("sage", n_nodes=1, seed=2, compute=compute)
        for name in ("a", "b", "c"):
            sim.register(_fn(name, compute_ms=5.0))
        for i in range(30):
            sim.submit("abc"[i % 3], 0.0, request_id=f"r{i}")
        sim.run()
        return max(r.end_t for r in sim.telemetry.snapshot())

    assert run("shared") < run(None)


# ----------------------------------------------------------------------
# knob plumbing: spec adoption / conflict (same rules as scheduler)
# ----------------------------------------------------------------------
def test_gateway_compute_spec_adoption_and_conflict():
    cfg = ComputeConfig(max_batch=4)
    spec = FunctionSpec.from_profile("resnet50", compute={"max_batch": 4})
    assert spec.compute == cfg  # dict literal normalized at construction
    gw = Gateway(backend="sim", policy="sage", n_nodes=2)
    gw.register(spec)
    assert gw.compute == cfg
    assert all(n.compute_plane is not None for n in gw.sim.nodes)
    with pytest.raises(ValueError, match="compute"):
        gw.register(FunctionSpec.from_profile("bert", compute="shared"))
    gw.register(FunctionSpec.from_profile("vgg11", compute=cfg))  # agrees
    # an explicit constructor choice is not overridable by a spec
    gw2 = Gateway(backend="sim", policy="sage", compute="shared")
    with pytest.raises(ValueError, match="compute"):
        gw2.register(FunctionSpec.from_profile(
            "resnet50", compute={"max_batch": 2}))
    with pytest.raises(ValueError):
        FunctionSpec(name="x", sm_fraction=1.5)


def test_gateway_compute_stats_backend_key_parity():
    """Both backends report the SAME compute_stats key set, off and on
    (dashboard code never needs a backend switch), and the off-state is
    the exclusive zero row."""
    expected = set(empty_compute_stats("exclusive", 0))
    gw_sim = Gateway(backend="sim", policy="sage", n_nodes=2)
    with Gateway(backend="runtime", policy="sage", n_nodes=2,
                 time_scale=0.02) as gw_rt:
        s, r = gw_sim.compute_stats(), gw_rt.compute_stats()
        assert set(s) == set(r) == expected
        assert s == r == empty_compute_stats("exclusive", 0)
    gw_on = Gateway(backend="sim", policy="sage", n_nodes=2,
                    compute="shared")
    with Gateway(backend="runtime", policy="sage", n_nodes=2,
                 time_scale=0.02, compute="shared") as gw_rt_on:
        s, r = gw_on.compute_stats(), gw_rt_on.compute_stats()
        assert set(s) == set(r) == expected
        assert s["mode"] == r["mode"] == "shared"
        assert s["slices"] == r["slices"] == 8


def test_placement_resilience_stats_parity_with_fractional_slots():
    """The fractional-slot plane must not skew the other stats planes:
    placement_stats and resilience_stats keep their exact backend key
    parity with compute sharing on."""
    kw = dict(policy="sage", n_nodes=2, dispatch="planned",
              compute="shared")
    gw_sim = Gateway(backend="sim", **kw)
    with Gateway(backend="runtime", time_scale=0.02, **kw) as gw_rt:
        ps, pr = gw_sim.placement_stats(), gw_rt.placement_stats()
        assert ps is not None and set(ps) == set(pr)
        rs, rr = gw_sim.resilience_stats(), gw_rt.resilience_stats()
        assert set(rs) == set(rr)


# ----------------------------------------------------------------------
# runtime<->sim batch parity: one simultaneous burst coalesces into ONE
# stacked launch on both drivers, with identical batch assignments
# ----------------------------------------------------------------------
def _burst_batches(backend):
    kw = dict(policy="sage", n_nodes=1, seed=3,
              compute={"max_batch": 4, "batch_window_s": 0.5})
    if backend == "runtime":
        kw["time_scale"] = 0.02
    gw = Gateway(backend=backend, **kw)
    try:
        gw.register(FunctionSpec(name="f", read_only_bytes=MB,
                                 writable_bytes=MB, context_bytes=MB,
                                 compute_ms=20.0))
        wl = TraceWorkload([Arrival(0.0, "f") for _ in range(4)])
        tel = gw.replay(wl, timeout=60.0)
        recs = [r for r in tel.snapshot() if not r.dropped]
        assert all(r.error is None for r in recs)
        stats = gw.compute_stats()
        return recs, stats
    finally:
        gw.shutdown()


@pytest.mark.parametrize("backend", ["sim", "runtime"])
def test_burst_coalesces_into_one_batch(backend):
    recs, stats = _burst_batches(backend)
    assert len(recs) == 4
    ids = {r.request_id for r in recs}
    for r in recs:
        assert r.batch_size == 4
        # every member names exactly the other three as peers
        assert set(r.batched_with) == ids - {r.request_id}
    assert stats["batches"] == 1 and stats["batched"] == 4
    assert stats["grants"] == 1  # the stacked launch is a single grant


def test_set_compute_after_registration_runtime():
    """The handler wrapper consults the plane at call time, so flipping
    the knob on a live runtime applies to already-registered functions."""
    from repro.core.engine import GPUFunction
    from repro.core.request import Request
    from repro.core.runtime import SageRuntime

    rt = SageRuntime("sage", max_workers=8)
    rt.sage_init()
    try:
        rt.register_function(GPUFunction(
            name="f", handler=lambda shim, req: time.sleep(0.002),
            context_builder=lambda: object(), context_bytes=MB,
            container_s=0.0, cpu_ctx_s=0.0, compute_s_hint=0.002))
        rt.submit(Request(function_name="f")).result(timeout=30.0)
        assert rt.compute_stats() == empty_compute_stats("exclusive", 0)
        rt.set_compute("shared")
        rt.submit(Request(function_name="f")).result(timeout=30.0)
        st = rt.compute_stats()
        assert st["mode"] == "shared" and st["grants"] == 1
        rt.set_compute(None)  # and back off again
        rt.submit(Request(function_name="f")).result(timeout=30.0)
        assert rt.compute_stats() == empty_compute_stats("exclusive", 0)
    finally:
        rt.shutdown()


def test_threaded_plane_contended_batches_no_leaked_slices():
    """Regression: when the budget is fully busy, a batch member parked on
    the free-slice wait must re-check its batch's grant on wake — the
    race double-granted the batch and leaked its first grant (deadlock)."""
    from repro.core.engine import GPUFunction
    from repro.core.request import Request
    from repro.core.runtime import SageRuntime

    rt = SageRuntime("sage", max_workers=32,
                     compute={"max_batch": 4, "batch_window_s": 0.005,
                              "slices": 4})
    rt.sage_init()
    try:
        for name in ("a", "b", "c"):
            rt.register_function(GPUFunction(
                name=name, handler=lambda shim, req: time.sleep(0.005),
                context_builder=lambda: object(), context_bytes=MB,
                container_s=0.0, cpu_ctx_s=0.0,
                compute_s_hint=0.020))  # k=4: each batch wants the budget
        futs = [rt.submit(Request(function_name="abc"[i % 3]))
                for i in range(24)]
        for f in futs:
            f.result(timeout=30.0)
        plane = rt._plane
        with plane._cond:
            assert plane._free == 4  # every grant released
            assert not plane._open
    finally:
        rt.shutdown()
