"""Checkpoint manager: atomic roundtrip, corruption detection, retention,
multi-host shards, elastic restore."""
import json
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager


@pytest.fixture()
def tmp_ckpt(tmp_path):
    return CheckpointManager(tmp_path / "ckpt", keep=2)


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 8)),
                   "b": jnp.zeros((8,), jnp.bfloat16)},
        "opt": {"m": {"w": jnp.ones((8, 8)), "b": jnp.zeros((8,))},
                "step": jnp.asarray(3, jnp.int32)},
    }


def test_roundtrip(tmp_ckpt):
    s = _state()
    tmp_ckpt.save(10, s)
    like = jax.tree_util.tree_map(jnp.zeros_like, s)
    step, restored = tmp_ckpt.restore_latest(like)
    assert step == 10
    for a, b in zip(jax.tree_util.tree_leaves(s),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_multi_host_shards(tmp_path):
    mgr = CheckpointManager(tmp_path / "c")
    s = _state()
    # hosts 1..3 write their shards into the tmp dir; host 0 commits last
    for h in (1, 2, 3):
        mgr.save(5, s, host_id=h, num_hosts=4)
    mgr.save(5, s, host_id=0, num_hosts=4)
    step, restored = mgr.restore_latest(jax.tree_util.tree_map(jnp.zeros_like, s))
    assert step == 5
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(s["params"]["w"]))


def test_corruption_detected(tmp_ckpt):
    s = _state()
    path = tmp_ckpt.save(1, s)
    shard = next(path.glob("shard_*.zst"))
    blob = bytearray(shard.read_bytes())
    blob[len(blob) // 2] ^= 0xFF
    shard.write_bytes(bytes(blob))
    with pytest.raises(Exception):
        tmp_ckpt.restore(1, jax.tree_util.tree_map(jnp.zeros_like, s))


def test_retention_keeps_newest(tmp_ckpt):
    s = _state()
    for step in (1, 2, 3, 4, 5):
        tmp_ckpt.save(step, s)
    assert tmp_ckpt.steps() == [4, 5]


def test_partial_write_is_invisible(tmp_ckpt):
    """A .tmp dir without manifest is never listed (atomicity)."""
    s = _state()
    tmp_ckpt.save(7, s)
    # simulate a crashed writer
    crash = tmp_ckpt.dir / "step_0000000009.tmp"
    crash.mkdir()
    (crash / "shard_00000.msgpack.zst").write_bytes(b"junk")
    assert tmp_ckpt.steps() == [7]
    assert tmp_ckpt.latest_step() == 7


def test_deterministic_resume_training(tmp_path):
    """A crash + restart reproduces the uninterrupted run exactly (same LR
    horizon, same data stream, checkpoint roundtrip bit-exact)."""
    from repro.launch.train import train_loop

    d1, d2 = tmp_path / "a", tmp_path / "b"
    _, losses_full, _ = train_loop(
        "qwen2.5-3b", steps=8, ckpt_dir=str(d1), ckpt_every=4,
        global_batch=2, seq_len=16, log_every=100,
    )
    with pytest.raises(RuntimeError):
        train_loop("qwen2.5-3b", steps=8, ckpt_dir=str(d2), ckpt_every=4,
                   fail_at_step=5, global_batch=2, seq_len=16, log_every=100)
    _, losses_resumed, _ = train_loop(
        "qwen2.5-3b", steps=8, ckpt_dir=str(d2), ckpt_every=4,
        global_batch=2, seq_len=16, log_every=100,
    )
    np.testing.assert_allclose(losses_full[-4:], losses_resumed, rtol=1e-4)
