"""SAGE core unit tests: daemon sharing/refcounts, exit ladder, shim
classification, executor readiness, baselines policy table."""
import threading
import time

import pytest

from repro.core.baselines import SYSTEMS, get_system
from repro.core.clock import RealClock, VirtualClock
from repro.core.daemon import GPU_CONTEXT_BYTES, MemoryDaemon, OutOfDeviceMemory, Tier
from repro.core.datapath import BandwidthBroker, DataPaths
from repro.core.exit_policy import ExitLadder, stage_skips
from repro.core.request import Data, DataType, Request
from repro.data.database import Database

MB = 1 << 20


def _daemon(cap_mb=1024, db=None):
    db = db or Database()
    paths = DataPaths.make(db_bw=1e12, pcie_bw=1e12)  # near-instant for tests
    return MemoryDaemon(paths, db, device_capacity=cap_mb * MB), db


def _req(fn="f", ro_mb=10, w_mb=2, db=None, uid=None):
    req = Request(function_name=fn)
    if db is not None:
        db.put(f"{fn}/w", b"W", size=ro_mb * MB)
        db.put(f"{fn}/in/{req.uuid}", b"X", size=w_mb * MB)
    req.in_data = [
        Data(key=f"{fn}/w", size=ro_mb * MB, dtype=DataType.READ_ONLY),
        Data(key=f"{fn}/in/{req.uuid}", size=w_mb * MB, dtype=DataType.WRITABLE),
    ]
    return req


class TestDaemon:
    def test_read_only_shared_loaded_once(self):
        d, db = _daemon()
        r1, r2 = _req(db=db), _req(db=db)
        h1 = d.prepare(r1)
        h2 = d.prepare(r2)
        for h in (*h1.values(), *h2.values()):
            h.wait(5)
        # 1 shared weights entry + 2 private inputs = 3 loads; 1 shared hit
        assert d.stats["loads"] == 3
        assert d.stats["shared_hits"] == 1
        assert h1["f/w"].entry is h2["f/w"].entry

    def test_no_sharing_when_disabled(self):
        d, db = _daemon()
        r1, r2 = _req(db=db), _req(db=db)
        h1 = d.prepare(r1, system_shares_ro=False)
        h2 = d.prepare(r2, system_shares_ro=False)
        for h in (*h1.values(), *h2.values()):
            h.wait(5)
        assert d.stats["shared_hits"] == 0
        assert d.stats["loads"] == 4

    def test_release_refcounts_and_writable_freed(self):
        d, db = _daemon()
        r1 = _req(db=db)
        h1 = d.prepare(r1)
        for h in h1.values():
            h.wait(5)
        used_before = d.device_used
        d.release(r1, h1)
        # writable freed; read-only cached (refcount 0, still on device)
        assert d.device_used == used_before - 2 * MB
        e = h1["f/w"].entry
        assert e.refcount == 0 and e.tier is Tier.DEVICE

    def test_demote_and_host_promotion(self):
        d, db = _daemon()
        r1 = _req(db=db)
        h1 = d.prepare(r1)
        for h in h1.values():
            h.wait(5)
        d.release(r1, h1)
        moved = d.demote_to_host("f")
        assert moved == 10 * MB
        assert h1["f/w"].entry.tier is Tier.HOST
        # next invocation promotes host -> device (PCIe only, no db load)
        r2 = _req(db=db)
        h2 = d.prepare(r2)
        for h in h2.values():
            h.wait(5)
        assert d.stats["host_promotions"] == 1
        assert h2["f/w"].entry.tier is Tier.DEVICE

    def test_oom_and_eviction(self):
        d, db = _daemon(cap_mb=32)
        r1 = _req(fn="a", ro_mb=20, w_mb=1, db=db)
        h1 = d.prepare(r1)
        for h in h1.values():
            h.wait(5)
        d.release(r1, h1)  # 20MB cached RO
        d.set_evictable_provider(lambda: d.evictable_entries("a"))
        # new function needs 20MB -> must evict a's cached weights
        db.put("b/w", b"W", size=20 * MB)
        r2 = Request(function_name="b",
                     in_data=[Data(key="b/w", size=20 * MB)])
        h2 = d.prepare(r2)
        for h in h2.values():
            h.wait(5)
        assert d.stats["evictions"] == 1
        assert h1["a/w"].entry.tier is Tier.DROPPED

    def test_hard_oom_raises(self):
        d, db = _daemon(cap_mb=8)
        with pytest.raises(OutOfDeviceMemory):
            d._reserve_device(16 * MB)


class TestExitLadder:
    def test_stage_progression(self):
        lad = ExitLadder(ttls=(1.0, 1.0, 1.0, 1.0))
        lad.on_complete(100.0)
        assert lad.stage_at(100.5) == 1
        assert lad.stage_at(101.5) == 2
        assert lad.stage_at(102.5) == 3
        assert lad.stage_at(103.5) == 4
        assert lad.stage_at(104.5) == 5

    def test_actions_fire_once_in_order(self):
        fired = []
        lad = ExitLadder(ttls=(1.0,) * 4)
        lad.on_enter = {k: (lambda k=k: fired.append(k)) for k in (2, 3, 4)}
        lad.on_complete(0.0)
        lad.advance(1.5)
        assert fired == [2]
        lad.advance(3.5)  # skipped ahead two stages -> both fire, in order
        assert fired == [2, 3, 4]
        lad.advance(3.6)
        assert fired == [2, 3, 4]  # idempotent

    def test_reuse_stops_exit_and_reports_stage(self):
        lad = ExitLadder(ttls=(1.0,) * 4)
        lad.on_complete(0.0)
        s = lad.on_reuse(1.5)
        assert s == 2
        assert lad.stage_at(99.0) == 0  # running again

    def test_warmer_stage_skips_more(self):
        assert len(stage_skips[1]) > len(stage_skips[2]) > len(stage_skips[3]) \
            > len(stage_skips[4])
        assert "gpu_data" in stage_skips[1] and "gpu_data" not in stage_skips[2]
        assert "gpu_ctx" in stage_skips[2] and "gpu_ctx" not in stage_skips[3]


class TestPolicies:
    def test_policy_table(self):
        sage = get_system("sage")
        assert sage.parallel_setup and sage.share_read_only and sage.multi_stage_exit
        fixed = get_system("fixedgsl")
        assert not fixed.parallel_setup and fixed.slot_granularity == 1 << 30
        flex = get_system("fixedgsl-f")
        assert flex.slot_granularity == 0
        dgsf = get_system("dgsf")
        assert dgsf.pre_created_contexts == 4 and not dgsf.share_read_only
        nr = get_system("sage-nr")
        assert nr.parallel_setup and not nr.share_read_only

    def test_unknown_system_raises(self):
        with pytest.raises(KeyError):
            get_system("nope")


class TestBroker:
    def test_solo_transfer_time(self):
        b = BandwidthBroker(100 * MB)  # 100 MB/s
        t = b.transfer(10 * MB)
        assert 0.08 < t < 0.5

    def test_fair_share_contention(self):
        b = BandwidthBroker(100 * MB)
        results = []

        def go():
            results.append(b.transfer(5 * MB))

        ts = [threading.Thread(target=go) for _ in range(4)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        solo = 5 * MB / b.bw
        assert min(results) > 1.2 * solo  # contended: visibly slower than solo
        assert b.max_concurrency >= 3

    def test_virtual_transfer(self):
        clock = VirtualClock()
        b = BandwidthBroker(100 * MB, clock)
        done = []
        b.sim_transfer(10 * MB, lambda: done.append(clock.now()))
        b.sim_transfer(10 * MB, lambda: done.append(clock.now()))
        clock.run_until(10.0)
        assert len(done) == 2
        # two equal transfers sharing the link both finish at ~2x solo
        assert abs(done[0] - 0.2) < 0.02
