"""Gray-failure tolerance primitives (docs/resilience.md, "Gray
failures"): the shared EWMA straggler primitive, the per-node per-stage
slowness detector with fleet-relative suspicion and graded health, the
exact duration-window hedge estimate (the P² cold-start pathology it
replaces), the hedging/quarantine knob surfaces, and the quarantine
drain -> cooldown -> probation -> readmit/retire state machine."""
import pytest

from repro.core.slowness import (
    HEDGE_STAT_KEYS, EwmaDetector, HedgeConfig, QuarantineConfig,
    QuarantineController, SlownessDetector, make_detector, resolve_hedging,
    resolve_quarantine,
)
from repro.core.slowness import _DurationWindow


# ---------------------------------------------------------------------------
# EwmaDetector — the shared primitive
# ---------------------------------------------------------------------------


def test_ewma_detector_flags_against_pre_update_baseline():
    det = EwmaDetector(factor=2.0, alpha=0.5)
    assert det.observe(1.0) is False  # first sample seeds, never flags
    assert det.ewma == 1.0
    # 2.5 > 2.0 * 1.0: flagged against the ewma BEFORE this observation —
    # the straggler must not drag the baseline it is judged against
    assert det.observe(2.5) is True
    assert det.ewma == pytest.approx(1.75)
    assert det.count == 2
    # exactly at the threshold is not a straggler (strict >)
    det2 = EwmaDetector(factor=2.0, alpha=0.5)
    det2.observe(1.0)
    assert det2.observe(2.0) is False


def test_ewma_detector_validates_parameters():
    with pytest.raises(ValueError):
        EwmaDetector(factor=1.0)
    with pytest.raises(ValueError):
        EwmaDetector(alpha=0.0)
    with pytest.raises(ValueError):
        EwmaDetector(alpha=1.5)


# ---------------------------------------------------------------------------
# _DurationWindow — exact bounded-window quantile for hedge estimates
# ---------------------------------------------------------------------------


def test_duration_window_forgets_cold_start():
    """The reason this is not a P² sketch: seed the window with slow cold
    loads, then displace them with warm traffic — the p95 must converge
    to the warm latency instead of riding the cold seed forever."""
    w = _DurationWindow(window=32)
    for _ in range(10):
        w.add(1.0)       # cold starts arrive first
    for _ in range(64):
        w.add(0.005)     # warm steady state displaces the whole ring
    assert w.count == 74
    assert w.quantile(0.95) == 0.005


def test_duration_window_quantile_is_exact():
    w = _DurationWindow(window=128)
    for v in range(1, 101):
        w.add(float(v))
    assert w.quantile(0.5) == 51.0
    assert w.quantile(0.95) == 96.0
    assert w.quantile(0.99) == 100.0


# ---------------------------------------------------------------------------
# SlownessDetector — fleet-relative suspicion + graded health
# ---------------------------------------------------------------------------


def _warm_fleet(det, nodes=("a", "b", "c"), n=10, value=0.01):
    for _ in range(n):
        for node in nodes:
            det.observe(node, "compute", value)


def test_detector_needs_sustained_breach_to_suspect():
    det = SlownessDetector(factor=2.5, alpha=0.2, min_samples=4)
    _warm_fleet(det, n=6)
    assert det.suspects() == []
    assert det.health_score("a") == 1.0
    # a breach streak shorter than min_samples never makes a suspect
    for _ in range(3):
        det.observe("a", "compute", 0.2)
    assert not det.is_suspect("a")
    det.observe("a", "compute", 0.2)
    assert det.is_suspect("a")
    assert det.suspects() == ["a"]
    # the graded score reflects the same drift continuously
    assert 0.0 < det.health_score("a") < 1.0
    assert det.health_score("b") == 1.0


def test_detector_streak_resets_on_clean_sample():
    det = SlownessDetector(factor=2.5, alpha=1.0, min_samples=4)
    _warm_fleet(det, n=6)
    for _ in range(3):
        det.observe("a", "compute", 0.2)
    det.observe("a", "compute", 0.01)  # one clean sample breaks the streak
    det.observe("a", "compute", 0.2)
    assert not det.is_suspect("a")


def test_detector_single_node_fleet_has_no_median():
    det = SlownessDetector(min_samples=2)
    for _ in range(20):
        assert det.observe("only", "compute", 5.0) is False
    assert not det.is_suspect("only")
    assert det.health_score("only") == 1.0


def test_detector_reset_node_wipes_evidence():
    det = SlownessDetector(factor=2.5, alpha=0.2, min_samples=3)
    _warm_fleet(det, n=5)
    for _ in range(3):
        det.observe("a", "compute", 0.5)
    assert det.is_suspect("a")
    det.reset_node("a")
    assert not det.is_suspect("a")
    assert det.health_score("a") == 1.0


def test_detector_is_slow_sample_one_shot():
    det = SlownessDetector(factor=2.0, min_samples=3)
    _warm_fleet(det, nodes=("b", "c"), n=4, value=0.1)
    # "a" has no stream at all — the canary check still judges it
    # one-shot against the mature peers' median
    assert det.is_slow_sample("a", "compute", 0.5) is True
    assert det.is_slow_sample("a", "compute", 0.1) is False


def test_detector_estimate_gated_on_samples_and_skips_suspects():
    det = SlownessDetector(factor=2.5, alpha=0.2, min_samples=3)
    assert det.estimate("f") is None
    for _ in range(5):
        det.observe_record("a", "f", {"compute": 0.01}, duration=0.02)
    assert det.estimate("f", min_samples=5) == pytest.approx(0.02)
    assert det.estimate("f", min_samples=6) is None
    # a suspect node's stragglers must not drag the hedge estimate up
    _warm_fleet(det, nodes=("b", "c"), n=4)
    for _ in range(3):
        det.observe("a", "compute", 0.5)
    assert det.is_suspect("a")
    before = det.estimate("f", min_samples=1)
    det.observe_record("a", "f", {"compute": 0.5}, duration=9.9)
    assert det.estimate("f", min_samples=1) == before


# ---------------------------------------------------------------------------
# knob surfaces
# ---------------------------------------------------------------------------


def test_hedge_config_validation():
    with pytest.raises(ValueError):
        HedgeConfig(hedge_quantile=1.0)
    with pytest.raises(ValueError):
        HedgeConfig(min_samples=0)
    with pytest.raises(ValueError):
        HedgeConfig(delay_factor=0.0)


def test_quarantine_config_validation():
    with pytest.raises(ValueError):
        QuarantineConfig(factor=1.0)
    with pytest.raises(ValueError):
        QuarantineConfig(min_samples=0)
    with pytest.raises(ValueError):
        QuarantineConfig(cooldown_s=0.0)
    with pytest.raises(ValueError):
        QuarantineConfig(canary_count=0)


def test_resolvers_normalize_all_knob_shapes():
    assert resolve_hedging(None) is None
    assert resolve_hedging(False) is None
    assert resolve_hedging(True) == HedgeConfig()
    cfg = HedgeConfig(min_samples=5)
    assert resolve_hedging(cfg) is cfg
    assert resolve_hedging({"min_samples": 5}) == cfg
    with pytest.raises(TypeError):
        resolve_hedging("yes")

    assert resolve_quarantine(None) is None
    assert resolve_quarantine(True) == QuarantineConfig()
    qc = QuarantineConfig(cooldown_s=2.0)
    assert resolve_quarantine(qc) is qc
    assert resolve_quarantine({"cooldown_s": 2.0}) == qc
    with pytest.raises(TypeError):
        resolve_quarantine(42)


def test_make_detector_splits_knob_ownership():
    det = make_detector(HedgeConfig(hedge_quantile=0.9),
                        QuarantineConfig(factor=3.0, min_samples=4))
    assert det.quantile == 0.9       # hedging owns the estimate quantile
    assert det.factor == 3.0         # quarantine owns suspicion thresholds
    assert det.min_samples == 4
    det2 = make_detector(None, None)
    assert det2.factor == QuarantineConfig().factor
    assert det2.quantile == 0.95


def test_hedge_stat_keys_frozen_contract():
    assert HEDGE_STAT_KEYS == ("hedges_launched", "hedges_won",
                               "hedges_wasted", "quarantines", "readmits")


# ---------------------------------------------------------------------------
# QuarantineController — drain -> cooldown -> probation -> readmit/retire
# ---------------------------------------------------------------------------


def _suspect_detector(node="a", min_samples=3):
    det = SlownessDetector(factor=2.5, alpha=0.2, min_samples=min_samples)
    _warm_fleet(det, nodes=(node, "b", "c"), n=min_samples + 1)
    for _ in range(min_samples):
        det.observe(node, "compute", 0.5)
    assert det.is_suspect(node)
    return det


def test_quarantine_readmit_after_clean_canaries():
    cfg = QuarantineConfig(min_samples=3, cooldown_s=5.0, canary_count=2)
    det = _suspect_detector(min_samples=3)
    qc = QuarantineController(cfg, det)
    assert qc.note_completion("a", now=10.0, compute_s=0.5) == "quarantine"
    assert qc.state("a") == QuarantineController.QUARANTINED
    assert not det.is_suspect("a")  # evidence wiped at quarantine
    assert qc.next_probe_at() == 15.0
    assert qc.due_probes(14.9) == []
    assert qc.due_probes(15.0) == ["a"]
    assert qc.state("a") == QuarantineController.PROBATION
    assert qc.next_probe_at() is None
    # two clean canaries: judged one-shot vs the fleet, both pass
    assert qc.note_completion("a", now=16.0, compute_s=0.01) is None
    assert qc.note_completion("a", now=17.0, compute_s=0.01) == "readmit"
    assert qc.state("a") == QuarantineController.ACTIVE
    assert qc.stats() == {"quarantines": 1, "readmits": 1}


def test_quarantine_retires_on_slow_canary():
    cfg = QuarantineConfig(min_samples=3, cooldown_s=1.0, canary_count=3)
    det = _suspect_detector(min_samples=3)
    qc = QuarantineController(cfg, det)
    assert qc.note_completion("a", now=0.0, compute_s=0.5) == "quarantine"
    assert qc.due_probes(1.0) == ["a"]
    # the first canary comes back slow: the node is retired for good
    assert qc.note_completion("a", now=2.0, compute_s=0.5) == "retire"
    assert qc.state("a") == QuarantineController.RETIRED
    # a retired node never acts again
    assert qc.note_completion("a", now=3.0, compute_s=0.01) is None
    assert qc.stats() == {"quarantines": 1, "readmits": 0}


def test_quarantine_healthy_node_never_acts():
    cfg = QuarantineConfig(min_samples=3)
    det = SlownessDetector(factor=2.5, min_samples=3)
    _warm_fleet(det, n=5)
    qc = QuarantineController(cfg, det)
    for t in range(10):
        assert qc.note_completion("a", now=float(t), compute_s=0.01) is None
    assert qc.state("a") == QuarantineController.ACTIVE
    assert qc.next_probe_at() is None
