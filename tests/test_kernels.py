"""Per-kernel validation: shape/dtype sweeps, interpret=True vs pure-jnp
oracle (assert_allclose), per the assignment."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssd_scan import ssd_scan
from repro.models.layers import decode_attention_ref, flash_attention_ref
from repro.models.mamba2 import ssd_chunked_ref

KEY = jax.random.PRNGKey(42)


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 3e-5


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,Sq,Sk,Hq,Hkv,Dh,causal,bq,bk",
    [
        (2, 128, 128, 4, 2, 64, True, 64, 64),
        (1, 256, 256, 8, 2, 32, True, 128, 64),
        (2, 96, 96, 4, 4, 64, True, 64, 64),      # padding path
        (1, 128, 128, 4, 1, 128, False, 64, 128),  # MQA, non-causal
        (1, 64, 192, 2, 2, 64, False, 64, 64),     # cross-attention shape
        (1, 512, 512, 8, 8, 64, True, 256, 256),   # MHA larger blocks
    ],
)
def test_flash_attention_sweep(dtype, B, Sq, Sk, Hq, Hkv, Dh, causal, bq, bk):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Sq, Hq, Dh), dtype)
    k = jax.random.normal(ks[1], (B, Sk, Hkv, Dh), dtype)
    v = jax.random.normal(ks[2], (B, Sk, Hkv, Dh), dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk,
                          interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal, block_q=bq, block_k=bk)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=_tol(dtype), rtol=_tol(dtype),
    )


def test_flash_ref_matches_plain_softmax():
    """The oracle itself vs unfused softmax attention."""
    B, S, Hq, Hkv, Dh = 2, 96, 4, 2, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, Hq, Dh))
    k = jax.random.normal(ks[1], (B, S, Hkv, Dh))
    v = jax.random.normal(ks[2], (B, S, Hkv, Dh))
    G = Hq // Hkv
    qg = q.reshape(B, S, Hkv, G, Dh)
    s = jnp.einsum("bqhgd,bkhd->bqhgk", qg, k) / jnp.sqrt(Dh)
    mask = jnp.arange(S)[:, None] >= jnp.arange(S)[None, :]
    s = jnp.where(mask[None, :, None, None, :], s, -1e30)
    plain = jnp.einsum("bqhgk,bkhd->bqhgd", jax.nn.softmax(s, -1), v)
    ref = flash_attention_ref(q, k, v, causal=True, block_q=32, block_k=32)
    np.testing.assert_allclose(
        np.asarray(ref), np.asarray(plain.reshape(B, S, Hq, Dh)), atol=2e-5
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,L,Hq,Hkv,Dh,bk",
    [
        (2, 256, 4, 2, 64, 64),
        (3, 300, 8, 8, 32, 128),   # padding + MHA
        (1, 1024, 16, 2, 128, 256),
        (4, 128, 8, 1, 64, 128),   # MQA
    ],
)
def test_decode_attention_sweep(dtype, B, L, Hq, Hkv, Dh, bk):
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, 1, Hq, Dh), dtype)
    kc = jax.random.normal(ks[1], (B, L, Hkv, Dh), dtype)
    vc = jax.random.normal(ks[2], (B, L, Hkv, Dh), dtype)
    lens = jax.random.randint(ks[3], (B,), 1, L + 1)
    out = decode_attention(q, kc, vc, lens, block_k=bk, interpret=True)
    ref = decode_attention_ref(q, kc, vc, lens, block_k=bk)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=_tol(dtype), rtol=_tol(dtype),
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,S,H,P,N,chunk",
    [
        (2, 64, 4, 16, 16, 16),
        (1, 128, 2, 32, 64, 32),
        (2, 100, 3, 16, 32, 32),   # padding path
        (1, 256, 8, 64, 128, 64),  # production-ish dims
    ],
)
def test_ssd_scan_sweep(dtype, B, S, H, P, N, chunk):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, S, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))).astype(jnp.float32)
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, N), dtype)
    Cm = jax.random.normal(ks[4], (B, S, N), dtype)
    y, fs = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    yr, fsr = ssd_chunked_ref(x, dt, A, Bm, Cm, chunk=chunk)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-3
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(fs), np.asarray(fsr), atol=tol, rtol=tol)


def test_ssd_chunk_invariance():
    """Chunk size must not change the result (duality correctness)."""
    B, S, H, P, N = 1, 96, 2, 16, 32
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(ks[4], (B, S, N))
    outs = [ssd_chunked_ref(x, dt, A, Bm, Cm, chunk=c)[0] for c in (16, 32, 96)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o), atol=2e-4)


def test_ssd_step_equals_scan():
    """Recurrent decode step == one-token chunked scan continuation."""
    from repro.models.mamba2 import ssd_step_ref

    B, S, H, P, N = 2, 32, 2, 8, 16
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, S + 1, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S + 1, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S + 1, N))
    Cm = jax.random.normal(ks[4], (B, S + 1, N))
    y_full, _ = ssd_chunked_ref(x, dt, A, Bm, Cm, chunk=16)
    _, state = ssd_chunked_ref(x[:, :S], dt[:, :S], A, Bm[:, :S], Cm[:, :S], chunk=16)
    y_step, _ = ssd_step_ref(state, x[:, S], dt[:, S], A, Bm[:, S], Cm[:, S])
    np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_full[:, S]),
                               atol=2e-4, rtol=2e-4)
