"""The unified serving API (repro.api): spec lowering, workload generation,
gateway behaviour, and the runtime/simulator parity contract.

The parity tests are the guard for docs/api.md + docs/dataplane.md: one
FunctionSpec and one Workload, replayed through BOTH backends, must yield
Telemetry records with identical stage-key structure, identical warm/cold
classification, and failures surfaced in ``InvocationRecord.error`` on both.
"""
import itertools

import pytest

from repro.api import (
    Arrival, BurstWorkload, FunctionSpec, Gateway, MAFWorkload, MixWorkload,
    PoissonWorkload, TraceWorkload,
)
from repro.core.profiles import MB, PROFILES
from repro.core.telemetry import STAGES

SMALL = dict(arch="qwen2.5-3b", profile="seq2seq")  # fast in both backends


# ---------------------------------------------------------------------------
# FunctionSpec lowering
# ---------------------------------------------------------------------------

def test_spec_lowers_to_sim_function_with_profile_bytes():
    spec = FunctionSpec.from_profile("resnet50")
    sf = spec.to_sim_function()
    assert sf.name == "resnet50"
    assert sf.ro_bytes == int(PROFILES["resnet50"].read_only_mb * MB)
    assert sf.ctx_bytes == int(PROFILES["resnet50"].context_mb * MB)
    assert sf.compute_s == PROFILES["resnet50"].compute_ms / 1e3


def test_spec_byte_overrides_flow_into_both_lowerings():
    spec = FunctionSpec(name="big", profile="resnet50",
                        read_only_bytes=2 << 30, writable_bytes=8 * MB,
                        compute_ms=50.0)
    prof = spec.resolved_profile()
    assert prof.name == "big"
    assert int(prof.read_only_mb * MB) == 2 << 30
    assert int(prof.writable_mb * MB) == 8 * MB
    assert prof.compute_ms == 50.0
    assert spec.to_sim_function().ro_bytes == 2 << 30


def test_spec_clone_names_for_many_functions():
    a = FunctionSpec.from_profile("bert", name="bert1")
    b = FunctionSpec.from_profile("bert", name="bert2")
    assert a.to_sim_function().name == "bert1"
    assert b.to_sim_function().name == "bert2"
    assert a.to_sim_function().ro_bytes == b.to_sim_function().ro_bytes


# ---------------------------------------------------------------------------
# Workloads
# ---------------------------------------------------------------------------

def test_poisson_workload_rate_determinism_and_truncation():
    wl = PoissonWorkload("f", 10.0, 100.0, seed=0)
    assert 800 < len(wl) < 1200
    assert wl.events() == PoissonWorkload("f", 10.0, 100.0, seed=0).events()
    assert all(0.0 < a.t < 100.0 for a in wl)
    capped = PoissonWorkload("f", 10.0, 100.0, seed=0, max_events=17)
    assert len(capped) == 17


def test_maf_workload_subsumes_maf_like_trace():
    from repro.core.simulator import maf_like_trace

    names = list(PROFILES)
    wl = MAFWorkload(names, 300.0, seed=3, mean_rpm=20)
    assert [(a.t, a.function) for a in wl] == \
        maf_like_trace(names, duration_s=300.0, seed=3, mean_rpm=20)


def test_mix_workload_per_function_rates():
    wl = MixWorkload({"a": 5.0, "b": 1.0}, 200.0, seed=1)
    counts = {"a": 0, "b": 0}
    for ev in wl:
        counts[ev.function] += 1
    assert counts["a"] > 3 * counts["b"] > 0
    assert wl.events() == MixWorkload({"a": 5.0, "b": 1.0}, 200.0, seed=1).events()


def test_burst_workload_rates_between_base_and_burst():
    wl = BurstWorkload("f", 1.0, 20.0, 600.0, period_s=100.0,
                       burst_len_s=10.0, seed=2)
    # expected mean rate = 0.9*1 + 0.1*20 = 2.9/s -> ~1740 events; a
    # generator that skips burst windows would emit ~600
    assert 600 * 2.0 < len(wl) < 600 * 4.0
    assert sorted(a.t for a in wl) == [a.t for a in wl]


def test_replay_gives_simultaneous_arrivals_unique_record_ids():
    gw = Gateway(backend="sim", policy="sage")
    gw.register(FunctionSpec.from_profile("resnet50", name="f"))
    tel = gw.replay(TraceWorkload([(0.0, "f"), (0.0, "f")]), until_pad=600.0)
    ids = [r.request_id for r in tel.records]
    assert len(ids) == 2 and len(set(ids)) == 2
    assert all(tel.find(i) is r for i, r in zip(ids, tel.records))


def test_workload_slo_metadata_and_spec_defaults():
    wl = TraceWorkload([Arrival(0.0, "a", deadline_s=0.5, priority=3),
                        (1.0, "b")])
    by_fn = {a.function: a for a in wl}
    assert by_fn["a"].deadline_s == 0.5 and by_fn["a"].priority == 3
    assert by_fn["b"].deadline_s is None  # falls back to the spec default
    assert by_fn["b"].priority is None

    gw = Gateway(backend="sim", policy="sage")
    gw.register(FunctionSpec.from_profile("resnet50", name="a"))
    gw.register(FunctionSpec.from_profile("resnet50", name="b",
                                          deadline_s=9.0, priority=1))
    tel = gw.replay(wl, until_pad=600.0)
    recs = {r.function: r for r in tel.records}
    assert recs["a"].deadline_s == 0.5 and recs["a"].priority == 3
    assert recs["b"].deadline_s == 9.0 and recs["b"].priority == 1


# ---------------------------------------------------------------------------
# Gateway (sim backend)
# ---------------------------------------------------------------------------

def test_gateway_sim_invoke_and_slo_recording():
    gw = Gateway(backend="sim", policy="sage")
    gw.register(FunctionSpec.from_profile("resnet50", deadline_s=1e-4))
    cold = gw.invoke("resnet50", at=0.0)
    warm = gw.invoke("resnet50")
    assert cold.warm_stage is None and warm.warm_stage == 1
    assert cold.deadline_s == 1e-4 and cold.slo_miss  # cold ~310 ms >> SLO
    assert gw.report().slo_miss_rate() > 0.0
    # same memory keys as the runtime backend (backend-parity contract)
    assert set(gw.memory_usage()) == {"device_used", "context_bytes",
                                      "host_used"}


def test_gateway_sim_invoke_async_strict_raises_on_failure():
    gw = Gateway(backend="sim", policy="sage",
                 device_capacity=600 * MB, load_timeout_s=5.0)
    gw.register(FunctionSpec.from_profile("bert"))  # 1282 MB RO never fits
    inv = gw.invoke_async("bert", at=0.0)
    with pytest.raises(RuntimeError, match="DataLoadError"):
        inv.wait()
    rec = inv.wait(strict=False)
    assert "DataLoadError" in rec.error


def test_gateway_rejects_unknown_backend_and_duplicate_register():
    with pytest.raises(ValueError):
        Gateway(backend="magic")
    gw = Gateway(backend="sim")
    gw.register(FunctionSpec.from_profile("resnet50"))
    with pytest.raises(ValueError):
        gw.register(FunctionSpec.from_profile("resnet50"))
    with pytest.raises(KeyError):
        gw.invoke("nope")


# ---------------------------------------------------------------------------
# Runtime/simulator parity (the data-plane API contract)
# ---------------------------------------------------------------------------

def _sorted_records(tel):
    return sorted(tel.records, key=lambda r: r.arrival_t)


def test_parity_stage_keys_and_warm_classification():
    """One spec + one workload through both backends: identical canonical
    stage-key sets, identical cold/warm classification, SLO metadata
    recorded on every record by both drivers."""
    spec = FunctionSpec(name="par", deadline_s=30.0, **SMALL)
    # spacing >> the real cold setup (~1 s compile) so the classification
    # is deterministic on the threaded backend too
    workload = TraceWorkload([(0.0, "par"), (2.5, "par"), (5.0, "par")])

    gw_sim = Gateway(backend="sim", policy="sage")
    gw_sim.register(spec)
    tel_sim = gw_sim.replay(workload, until_pad=60.0)
    with Gateway(backend="runtime", policy="sage", time_scale=0.05) as gw_rt:
        gw_rt.register(spec)
        tel_rt = gw_rt.replay(workload)

    for tel in (tel_sim, tel_rt):
        recs = _sorted_records(tel)
        assert len(recs) == 3
        assert all(r.error is None for r in recs)
        # identical stage structure: every record carries exactly the
        # canonical stage keys (skipped stages read 0.0)
        assert all(set(r.stages) == set(STAGES) for r in recs)
        assert all(r.deadline_s == 30.0 for r in recs)
    warm_sim = [r.warm_stage is None for r in _sorted_records(tel_sim)]
    warm_rt = [r.warm_stage is None for r in _sorted_records(tel_rt)]
    assert warm_sim == warm_rt == [True, False, False]


def test_parity_errors_surface_in_record_error_on_both_backends():
    """A working set that can never fit fails with a typed error in
    InvocationRecord.error on BOTH drivers (docs/dataplane.md contract)."""
    spec = FunctionSpec(name="big", arch="qwen2.5-3b", profile="bert")
    workload = TraceWorkload([(0.0, "big")])
    cap = 600 * MB  # fits the 414 MB context, never the 1282 MB weights

    gw_sim = Gateway(backend="sim", policy="sage", device_capacity=cap,
                     load_timeout_s=5.0)
    gw_sim.register(spec)
    tel_sim = gw_sim.replay(workload, until_pad=60.0)
    with Gateway(backend="runtime", policy="sage", device_capacity=cap,
                 time_scale=0.02, load_timeout_s=0.5) as gw_rt:
        gw_rt.register(spec)
        tel_rt = gw_rt.replay(workload)

    for tel in (tel_sim, tel_rt):
        assert tel.error_count() == 1
        assert "DataLoadError" in tel.errors()[0].error


def test_gateway_cluster_runtime_dispatches_across_nodes():
    with Gateway(backend="runtime", policy="sage", n_nodes=2,
                 time_scale=0.02, seed=0) as gw:
        gw.register(FunctionSpec(name="f", **SMALL))
        tel = gw.replay(TraceWorkload([(0.02 * i, "f") for i in range(4)]))
        assert len(tel.records) == 4
        assert tel.error_count() == 0
        # the merged cluster view keeps its O(1) lookup index populated
        rec = tel.records[0]
        assert gw.report().find(rec.request_id) is rec


# ---------------------------------------------------------------------------
# SLO-aware scheduling: the scheduler knob and the EDF-vs-FIFO contract
# ---------------------------------------------------------------------------

def test_gateway_scheduler_knob_plumbs_to_both_backends():
    gw = Gateway(backend="sim", policy="sage", scheduler="edf")
    assert gw.scheduler == "edf"
    assert all(n.scheduler == "edf" for n in gw.sim.nodes)
    with pytest.raises(ValueError):
        Gateway(backend="sim", scheduler="lifo")
    with Gateway(backend="runtime", policy="sage", scheduler="edf",
                 time_scale=0.02) as gw_rt:
        assert gw_rt.runtime.scheduler == "edf"
        assert gw_rt.runtime.daemon.scheduler == "edf"


def test_spec_scheduler_adoption_and_conflict():
    with pytest.raises(ValueError):
        FunctionSpec(name="x", scheduler="lifo")
    # an undecided gateway adopts the first spec's declared scheduler
    gw = Gateway(backend="sim", policy="sage")
    gw.register(FunctionSpec.from_profile("resnet50", scheduler="edf"))
    assert gw.scheduler == "edf" and gw.sim.nodes[0].scheduler == "edf"
    # a later spec declaring a different scheduler is refused
    with pytest.raises(ValueError, match="scheduler"):
        gw.register(FunctionSpec.from_profile("bert", scheduler="fifo"))
    # an explicit constructor choice is not overridable by a spec
    gw2 = Gateway(backend="sim", policy="sage", scheduler="fifo")
    with pytest.raises(ValueError, match="scheduler"):
        gw2.register(FunctionSpec.from_profile("resnet50", scheduler="edf"))
    # a spec that fails to lower must not pin the gateway's scheduler
    gw3 = Gateway(backend="sim", policy="sage")
    with pytest.raises(KeyError):
        gw3.register(FunctionSpec(name="bad", profile="nope", scheduler="edf"))
    assert gw3.scheduler == "fifo" and "bad" not in gw3.specs
    gw3.register(FunctionSpec.from_profile("resnet50", scheduler="fifo"))


def test_workload_priority_dict_per_function():
    wl = MixWorkload({"a": 5.0, "b": 1.0}, 50.0, seed=1,
                     deadline_s={"a": 0.5}, priority={"a": 2, "b": 0})
    for ev in wl:
        if ev.function == "a":
            assert ev.deadline_s == 0.5 and ev.priority == 2
        else:
            assert ev.deadline_s is None and ev.priority == 0


def _gateway_slo_replay(scheduler):
    """One contended mixed-deadline trace: four loose 500 MB loads queued
    on a single loader thread ahead of one tight 16 MB load."""
    gw = Gateway(backend="sim", policy="sage", scheduler=scheduler,
                 loader_threads=1)
    for i in range(4):
        gw.register(FunctionSpec(name=f"batch{i}", read_only_bytes=0,
                                 writable_bytes=500 * MB, context_bytes=MB,
                                 compute_ms=5.0, deadline_s=30.0, priority=0))
    gw.register(FunctionSpec(name="crit", read_only_bytes=0,
                             writable_bytes=16 * MB, context_bytes=MB,
                             compute_ms=5.0, deadline_s=1.2, priority=1))
    wl = TraceWorkload([Arrival(0.001 * i, f"batch{i}") for i in range(4)]
                       + [Arrival(0.05, "crit")])
    tel = gw.replay(wl, until_pad=600.0)
    node = gw.sim.nodes[0]
    assert tel.error_count() == 0
    assert node.max_inflight_loads <= 1  # pool bound holds under both orders
    assert node.host_used == 0           # no host-tier leakage after drain
    return tel


def test_gateway_edf_strictly_beats_fifo_and_reports_by_class():
    tel_fifo = _gateway_slo_replay("fifo")
    tel_edf = _gateway_slo_replay("edf")
    assert tel_fifo.slo_miss_rate() > 0.0
    assert tel_edf.slo_miss_rate() < tel_fifo.slo_miss_rate()
    # per-priority-class attainment: FIFO starves the high class, EDF
    # restores it without missing the loose class
    assert tel_fifo.slo_by_priority()[1]["attainment"] == 0.0
    by_prio = tel_edf.slo_by_priority()
    assert by_prio[1] == {"requests": 1, "misses": 0,
                          "miss_rate": 0.0, "attainment": 1.0}
    assert by_prio[0]["attainment"] == 1.0


# ---------------------------------------------------------------------------
# satellite regressions
# ---------------------------------------------------------------------------

def test_telemetry_reads_are_safe_against_concurrent_adds():
    """Read paths snapshot under the lock: hammering them while another
    thread add()s must neither raise nor produce internally inconsistent
    aggregates (miss rate is computed from ONE snapshot)."""
    import threading

    from repro.core.telemetry import InvocationRecord, Telemetry

    tel = Telemetry()
    n = 5000

    def writer():
        for i in range(n):
            tel.add(InvocationRecord(
                request_id=f"r{i}", function=f"f{i % 3}", system="sage",
                arrival_t=0.0, end_t=10.0, deadline_s=1.0, priority=i % 2))

    t = threading.Thread(target=writer)
    t.start()
    try:
        while t.is_alive():
            tel.by_function()
            tel.mean_e2e()
            tel.p99_e2e()
            tel.warm_fraction()
            if tel.records:
                assert tel.slo_miss_rate() == 1.0  # every record misses
            for c in tel.slo_by_priority().values():
                assert c["misses"] == c["requests"]
    finally:
        t.join(timeout=30)
    assert not t.is_alive()
    assert len(tel.records) == n

def test_instance_ids_come_from_unbounded_counter():
    from repro.core.engine import GPUFunction, Instance

    assert isinstance(Instance._ids, itertools.count)
    fn = GPUFunction(name="x", handler=lambda s, r: None,
                     context_builder=lambda: None)
    a, b = Instance(fn), Instance(fn)
    assert b.id == a.id + 1


def test_request_arrival_zero_is_preserved():
    """arrival_t == 0.0 is a legitimate arrival time; only the None
    sentinel means 'stamp me on submit'."""
    from repro.core import SageRuntime
    from repro.core.functions import make_model_function, make_request

    rt = SageRuntime("sage", time_scale=0.02)
    rt.sage_init()
    fn = make_model_function(rt.db, "f", arch="qwen2.5-3b",
                             profile=PROFILES["seq2seq"])
    rt.register_function(fn)
    req = make_request(rt.db, fn, seed=0)
    assert req.arrival_t is None  # sentinel until submission
    req.arrival_t = 0.0
    rt.sage_run(req)
    assert rt.telemetry.records[-1].arrival_t == 0.0
    # e2e against an explicit epoch arrival is the full monotonic offset —
    # the point is it was NOT clobbered by the clock
    fut = rt.submit(make_request(rt.db, fn, seed=1))
    fut.result(timeout=60)
    assert rt.telemetry.records[-1].arrival_t > 0.0
    rt.shutdown()
