"""Integration tests on the real threaded runtime: SAGE semantics vs
baselines, correctness of served results, memory accounting."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Request, SageRuntime
from repro.core.functions import make_model_function, make_request
from repro.core.profiles import PROFILES
from repro.models import forward, init_params


def _runtime(system, **kw):
    rt = SageRuntime(system, time_scale=0.02, exit_ttl=1.0, **kw)
    rt.sage_init()
    return rt


def test_served_result_matches_direct_forward():
    """The serverless path must compute exactly what the model computes."""
    rt = _runtime("sage")
    fn = make_model_function(rt.db, "f", arch="qwen2.5-3b", seed=3)
    rt.register_function(fn)
    req = make_request(rt.db, fn, seed=11)
    out_key = rt.sage_run(req)
    served = rt.db.fetch(out_key)
    # direct computation
    from repro.configs import ARCHS

    cfg = ARCHS["qwen2.5-3b"].reduced()
    params = rt.db.fetch("f/weights")
    toks = rt.db.fetch(req.in_data[1].key)
    direct, _ = forward(cfg, params, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(served),
                               np.asarray(direct[:, -1, :8]), atol=1e-4)
    rt.shutdown()


def test_sage_shares_read_only_across_concurrent():
    rt = _runtime("sage")
    fn = make_model_function(rt.db, "f", arch="qwen2.5-3b")
    rt.register_function(fn)
    futs = [rt.submit(make_request(rt.db, fn, seed=i)) for i in range(6)]
    for f in futs:
        f.result(timeout=120)
    # weights loaded once; every other invocation was a shared hit
    assert rt.daemon.stats["shared_hits"] >= 5
    assert rt.daemon.stats["loads"] <= 1 + 6  # 1 weights + <=6 inputs
    rt.shutdown()


def test_fixedgsl_never_shares():
    rt = _runtime("fixedgsl")
    fn = make_model_function(rt.db, "f", arch="qwen2.5-3b")
    rt.register_function(fn)
    futs = [rt.submit(make_request(rt.db, fn, seed=i)) for i in range(3)]
    for f in futs:
        f.result(timeout=120)
    assert rt.daemon.stats["shared_hits"] == 0
    rt.shutdown()


def test_fixedgsl_uses_more_memory_than_sage():
    peaks = {}
    for system in ("sage", "fixedgsl"):
        rt = _runtime(system)
        fn = make_model_function(rt.db, "f", arch="qwen2.5-3b",
                                 profile=PROFILES["resnet50"])
        rt.register_function(fn)
        futs = [rt.submit(make_request(rt.db, fn, seed=i)) for i in range(4)]
        for f in futs:
            f.result(timeout=120)
        peaks[system] = rt.memory_usage()["device_used"]
        rt.shutdown()
    assert peaks["fixedgsl"] > peaks["sage"]


def test_multi_stage_exit_frees_memory_over_time():
    """Drive the ladder deterministically by advancing at explicit stage
    midpoints (monkeypatched clock), not wall-clock sleeps."""
    rt = SageRuntime("sage", time_scale=0.02, exit_ttl=10.0)
    rt.sage_init()
    fn = make_model_function(rt.db, "f", arch="qwen2.5-3b",
                             profile=PROFILES["resnet50"])
    rt.register_function(fn)
    rt.sage_run(make_request(rt.db, fn, seed=0))
    eng = rt.engines["f"]
    inst = eng.instances[0]
    t0 = inst.ladder.completion_t
    used_hot = rt.memory_usage()["device_used"]

    class FakeClock:
        def __init__(self, t):
            self.t = t
        def now(self):
            return self.t
        def sleep(self, dt):
            pass

    eng.clock = FakeClock(t0 + 15.0)  # mid stage 2: RO demoted to host
    eng._advance_ladders()
    used_stage2 = rt.memory_usage()["device_used"]
    assert used_stage2 < used_hot
    eng.clock = FakeClock(t0 + 25.0)  # mid stage 3: ctx dropped
    eng._advance_ladders()
    used_stage3 = rt.memory_usage()["device_used"]
    assert used_stage3 < used_stage2
    assert rt.memory_usage()["host_used"] > 0  # RO parked in host RAM
    eng.clock = FakeClock(t0 + 45.0)  # past stage 5: destroyed
    eng._advance_ladders()
    assert rt.memory_usage()["device_used"] <= used_stage3
    rt.shutdown()


def test_dgsf_limits_concurrency_to_pool():
    rt = _runtime("dgsf")
    fn = make_model_function(rt.db, "f", arch="qwen2.5-3b")
    rt.register_function(fn)
    futs = [rt.submit(make_request(rt.db, fn, seed=i)) for i in range(6)]
    for f in futs:
        f.result(timeout=120)
    # all succeed; contexts were pre-reserved at registration
    assert rt.daemon.context_bytes_used > 0
    rt.shutdown()


def test_warm_stage_recorded():
    rt = SageRuntime("sage", time_scale=0.02, exit_ttl=5.0)
    rt.sage_init()
    fn = make_model_function(rt.db, "f", arch="qwen2.5-3b")
    rt.register_function(fn)
    rt.sage_run(make_request(rt.db, fn, seed=0))
    rt.sage_run(make_request(rt.db, fn, seed=1))
    recs = rt.telemetry.records
    assert recs[0].warm_stage is None      # cold
    assert recs[1].warm_stage == 1         # stage-1 warm hit
    assert recs[1].e2e < recs[0].e2e
    rt.shutdown()
