"""Engine-layer unit tests: the discrete-event kernel (repro.core.sim)."""
import warnings

import pytest

from repro.core.clock import RealClock, VirtualClock
from repro.core.sim import Event, EventKernel, EventKind, RngStreams


def test_events_fire_in_time_then_seq_order():
    k = EventKernel()
    fired = []
    k.schedule(2.0, fired.append, "late")
    k.schedule(1.0, fired.append, "early")
    k.schedule(1.0, fired.append, "early2")  # same t: insertion order wins
    k.schedule(0.0, fired.append, "now")
    k.run_until(10.0)
    assert fired == ["now", "early", "early2", "late"]
    assert k.now() == 10.0  # finite horizon: clock lands on t_end
    assert k.events_processed == 4


def test_event_record_fields_and_heap_comparability():
    e1 = Event(1.0, 1, EventKind.COMPUTE, print, ("x",))
    e2 = Event(1.0, 2, EventKind.CALL, print)
    assert (e1.t, e1.seq, e1.kind, e1.fn, e1.args) == \
        (1.0, 1, EventKind.COMPUTE, print, ("x",))
    # same timestamp, non-comparable fn: seq must decide before fn is reached
    assert e1 < e2
    assert "COMPUTE" in repr(e1)


def test_kind_counts_tally_per_taxonomy_bucket():
    k = EventKernel()
    k.schedule(0.1, lambda: None, kind=EventKind.TRANSFER)
    k.schedule(0.2, lambda: None, kind=EventKind.TRANSFER)
    k.schedule(0.3, lambda: None)  # CALL
    k.run_until(1.0)
    assert k.kind_counts[EventKind.TRANSFER] == 2
    assert k.kind_counts[EventKind.CALL] == 1


def test_negative_delay_clamps_to_now():
    k = EventKernel()
    out = []
    k.schedule(5.0, lambda: (out.append(k.now()),
                             k.schedule(-3.0, lambda: out.append(k.now()))))
    k.run_until(10.0)
    assert out == [5.0, 5.0]


def test_schedule_at_past_time_warns_once_and_counts():
    k = EventKernel()
    k.schedule(5.0, lambda: None)
    k.run_until(10.0)
    assert k.now() == 10.0
    fired = []
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        k.schedule_at(3.0, fired.append, 1)   # past: warns
        k.schedule_at(2.0, fired.append, 2)   # past again: counted, silent
        k.schedule_at(12.0, fired.append, 3)  # future: untouched
    assert [str(w.message) for w in caught
            if issubclass(w.category, RuntimeWarning) and
            "past" in str(w.message)] != []
    assert sum(1 for w in caught if issubclass(w.category, RuntimeWarning)) == 1
    assert k.past_events == 2
    k.run_until(20.0)
    assert fired == [1, 2, 3]  # clamped events fire at now, in call order


def test_empty_kernel_is_truthy_for_clock_defaulting():
    # BandwidthBroker does `clock or RealClock()`: an empty VirtualClock
    # must not be falsy, or every sim broker silently runs on real time
    clock = VirtualClock()
    assert clock.queued == 0
    assert (clock or RealClock()) is clock


def test_virtual_clock_is_a_kernel_facade():
    clock = VirtualClock()
    assert isinstance(clock, EventKernel)
    seen = []
    clock.schedule(1.5, seen.append, "a")
    clock.run_until(2.0)
    assert seen == ["a"] and clock.now() == 2.0


def test_run_until_returns_fired_count_and_drains_cascades():
    k = EventKernel()

    def cascade(depth):
        if depth:
            k.schedule(0.5, cascade, depth - 1)

    k.schedule(0.0, cascade, 3)
    assert k.run_until(10.0) == 4


def test_rng_streams_root_matches_seeded_random_and_named_are_stable():
    import random

    streams = RngStreams(42)
    assert streams.root.random() == random.Random(42).random()
    a = streams.get("telemetry")
    assert streams.get("telemetry") is a  # cached
    assert a.random() == random.Random("42:telemetry").random()
