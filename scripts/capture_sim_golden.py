"""Capture golden simulator traces for the kernel-equivalence suite.

Replays (a) the seeded paper-§7.8-style MAF trace through every system
policy and (b) one EDF+locality+preemptive multi-node knob trace, and
writes every record — request id, node, warm stage, full stage breakdown,
end time, error/preemption accounting — to ``tests/golden/sim_golden.json``.

``tests/test_sim_golden.py`` replays the same traces through the current
event kernel and asserts record-for-record identity, so any refactor of
the simulator core must reproduce the captured behavior bit-for-bit
(timestamps are compared at nanosecond resolution).

Run from the repo root to (re)generate the fixture — only do this when a
PR *intends* to change simulator behavior, and say so in the PR:

    PYTHONPATH=src python scripts/capture_sim_golden.py
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core.profiles import PROFILES  # noqa: E402
from repro.core.simulator import SimFunction, Simulator  # noqa: E402

OUT = Path(__file__).resolve().parents[1] / "tests" / "golden" / "sim_golden.json"

# deterministic sub-second resolution: round(9) keeps fp noise out while
# still catching any real ordering / duration change
R = 9

STAGE_KEYS = ("container_create", "cpu_ctx", "cpu_data", "gpu_ctx",
              "gpu_data", "compute", "return_result")


def record_rows(sim: Simulator) -> list:
    rows = []
    for r in sorted(sim.telemetry.snapshot(),
                    key=lambda r: (r.arrival_t, r.request_id)):
        rows.append([
            r.request_id,
            r.node_id,
            r.warm_stage,
            round(r.arrival_t, R),
            round(r.end_t, R),
            [round(r.stages.get(s, 0.0), R) for s in STAGE_KEYS],
            r.error is not None,
            r.preemptions,
            round(r.stalled_s, R),
            r.dispatch_tier,
        ])
    return rows


def maf_trace():
    try:  # canonical home after the PR-6 workload dedupe
        from repro.api.workload import maf_like_trace
    except ImportError:  # pre-refactor location
        from repro.core.simulator import maf_like_trace

    return maf_like_trace(sorted(PROFILES), duration_s=150.0, seed=3,
                          mean_rpm=15)


def run_system(system: str) -> Simulator:
    trace = maf_trace()
    sim = Simulator(system, seed=1)
    for n in sorted(PROFILES):
        sim.register(SimFunction(PROFILES[n]))
    for t, f in trace:
        sim.submit(f, t)
    sim.run(until=10 * trace[-1][0] + 100.0)
    return sim


def run_knobs() -> Simulator:
    """EDF scheduler + locality dispatch + preemptive transfer, 4 nodes,
    contended mixed-SLO trace (the PR-3/4/5 knob stack in one replay)."""
    sim = Simulator("sage", n_nodes=4, seed=5, loader_threads=1,
                    scheduler="edf", dispatch="locality",
                    transfer="preemptive")
    names = ["lbm", "seq2seq", "vgg11", "mrif"]
    for n in names:
        sim.register(SimFunction(PROFILES[n]))
    prio = {"lbm": 0, "vgg11": 0, "mrif": 0, "seq2seq": 2}
    dl = {"lbm": 60.0, "vgg11": 30.0, "mrif": 30.0, "seq2seq": 1.0}
    for i in range(400):
        f = names[i % 4]
        sim.submit(f, 0.02 * i, deadline_s=dl[f], priority=prio[f])
    sim.run(until=3600.0)
    return sim


def main() -> None:
    golden = {"resolution": R, "stage_keys": list(STAGE_KEYS), "traces": {}}
    for system in ("sage", "sage-nr", "fixedgsl", "dgsf"):
        sim = run_system(system)
        golden["traces"][f"maf:{system}"] = {
            "completed": sim.completed,
            "failed": sim.failed,
            "records": record_rows(sim),
        }
        print(f"maf:{system}: {sim.completed} completed, {sim.failed} failed")
    sim = run_knobs()
    golden["traces"]["knobs:edf+locality+preemptive"] = {
        "completed": sim.completed,
        "failed": sim.failed,
        "preemptions": sim.preemption_count(),
        "records": record_rows(sim),
    }
    print(f"knobs: {sim.completed} completed, {sim.failed} failed, "
          f"{sim.preemption_count()} preemptions")
    OUT.parent.mkdir(parents=True, exist_ok=True)
    OUT.write_text(json.dumps(golden, separators=(",", ":")) + "\n")
    print(f"wrote {OUT} ({OUT.stat().st_size} bytes)")


if __name__ == "__main__":
    main()
