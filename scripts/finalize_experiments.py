"""Append the final roofline table + dry-run summary to EXPERIMENTS.md.

Run after the full matrix: PYTHONPATH=src python scripts/finalize_experiments.py
"""
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks.roofline import load_records, table  # noqa: E402

ROOT = Path(__file__).resolve().parents[1]
EXP = ROOT / "EXPERIMENTS.md"
MARK = "## §Roofline — final table"


def main():
    recs16 = load_records("16x16")
    recs2p = load_records("2x16x16")
    ok16 = sum(1 for r in recs16 if r["status"] == "OK")
    sk16 = sum(1 for r in recs16 if r["status"] == "SKIP")
    ok2p = sum(1 for r in recs2p if r["status"] == "OK")
    sk2p = sum(1 for r in recs2p if r["status"] == "SKIP")
    fails = [r for r in recs16 + recs2p if r["status"] == "FAIL"]

    lines = [MARK, ""]
    lines.append(
        f"Matrix status: 16x16 -> {ok16} OK / {sk16} SKIP; "
        f"2x16x16 -> {ok2p} OK / {sk2p} SKIP; {len(fails)} FAIL."
    )
    lines.append("")
    lines.append("### Single-pod (16x16, 256 chips) — all 40 cells")
    lines.append("```")
    lines.append(table("16x16"))
    lines.append("```")
    lines.append("")
    lines.append("### Multi-pod (2x16x16, 512 chips)")
    lines.append("```")
    lines.append(table("2x16x16"))
    lines.append("```")
    lines.append("")
    # compile-time stats
    ts = [r.get("compile_s", 0) for r in recs16 + recs2p if r["status"] == "OK"]
    if ts:
        lines.append(
            f"AOT compile times: median {sorted(ts)[len(ts)//2]:.0f}s, "
            f"max {max(ts):.0f}s per cell (single CPU core)."
        )

    text = EXP.read_text()
    head = text.split(MARK)[0]
    EXP.write_text(head + "\n".join(lines) + "\n")
    print(f"appended roofline table ({ok16+ok2p} OK cells) to EXPERIMENTS.md")


if __name__ == "__main__":
    main()
