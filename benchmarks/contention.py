"""Paper Fig 4: data-loading slowdown under FixedGSL peak load vs solo run
(paper: 34.9x average)."""
from __future__ import annotations

from benchmarks.common import NAMES, Row, make_gateway
from repro.api import MixWorkload


def run(quick: bool = True):
    gw = make_gateway("fixedgsl")
    # near-saturation aggregate load across all ten functions
    gw.replay(MixWorkload({n: 1.0 for n in NAMES}, 120.0, seed=0),
              until=2000.0)
    node = gw.sim.nodes[0]
    db = node.db.mean_slowdown()
    pcie = node.pcie.mean_slowdown()
    overall = (db + pcie) / 2
    return [Row("fig4_dataload_contention_factor", overall * 1e6,
                f"db={db:.1f}x pcie={pcie:.1f}x (paper: 34.9x avg)")]


if __name__ == "__main__":
    for r in run():
        r.print()
