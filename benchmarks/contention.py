"""Paper Fig 4: data-loading slowdown under FixedGSL peak load vs solo run
(paper: 34.9x average)."""
from __future__ import annotations

import random

from benchmarks.common import NAMES, Row, make_sim
from repro.core.simulator import poisson_arrivals


def run(quick: bool = True):
    sim = make_sim("fixedgsl")
    rng = random.Random(0)
    # near-saturation aggregate load across all ten functions
    for name in NAMES:
        for t in poisson_arrivals(1.0, 120.0, rng):
            sim.submit(name, t)
    sim.run(until=2000.0)
    db = sim.nodes[0].db.mean_slowdown()
    pcie = sim.nodes[0].pcie.mean_slowdown()
    overall = (db + pcie) / 2
    return [Row("fig4_dataload_contention_factor", overall * 1e6,
                f"db={db:.1f}x pcie={pcie:.1f}x (paper: 34.9x avg)")]


if __name__ == "__main__":
    for r in run():
        r.print()
