"""SLO-aware admission scheduling: EDF vs FIFO under a contended
mixed-deadline workload (ROADMAP: HAS-GPU-style deadline-aware ordering).

Two request classes share one SAGE node whose loader pool is deliberately
narrow: a latency-critical class (small working set, tight deadline, high
priority) and a batch class (large working sets, loose deadlines). Under
FIFO the critical loads queue behind whatever batch arrived first; under
EDF the loader queue and the memory-admission wait both serve the tightest
remaining slack first. Rows report overall and per-priority-class SLO miss
rates for both schedulers from the same trace.
"""
from benchmarks.common import Row
from repro.api import FunctionSpec, Gateway, MixWorkload
from repro.core.profiles import MB

CRIT_DEADLINE_S = 1.2
BATCH_DEADLINE_S = 60.0


def _replay(scheduler: str, duration_s: float):
    # one loader thread at ~75% utilization: transient queues of a few
    # 500 MB batch loads form constantly — exactly the regime where FIFO
    # makes the tight-deadline class wait out its slack
    gw = Gateway(backend="sim", policy="sage", scheduler=scheduler,
                 loader_threads=1, seed=7)
    rates = {}
    for i in range(4):
        name = f"batch{i}"
        gw.register(FunctionSpec(
            name=name, read_only_bytes=0, writable_bytes=500 * MB,
            context_bytes=MB, compute_ms=10.0,
            deadline_s=BATCH_DEADLINE_S, priority=0))
        rates[name] = 0.45
    gw.register(FunctionSpec(
        name="crit", read_only_bytes=0, writable_bytes=16 * MB,
        context_bytes=MB, compute_ms=5.0,
        deadline_s=CRIT_DEADLINE_S, priority=1))
    rates["crit"] = 1.0
    wl = MixWorkload(rates, duration_s, seed=7)
    tel = gw.replay(wl, until_pad=600.0)
    return tel


def run(quick: bool = True):
    duration = 120.0 if quick else 900.0
    rows = []
    by_sched = {}
    for sched in ("fifo", "edf"):
        tel = _replay(sched, duration)
        by_sched[sched] = tel
        rows.append(Row(f"slo_{sched}_miss_rate_pct",
                        tel.slo_miss_rate() * 100.0,
                        f"n={len(tel.records)}"))
        for prio, c in sorted(tel.slo_by_priority().items()):
            rows.append(Row(
                f"slo_{sched}_prio{prio}_miss_rate_pct",
                c["miss_rate"] * 100.0,
                f"attainment={c['attainment']:.3f};requests={int(c['requests'])}",
            ))
    improvement = (by_sched["fifo"].slo_miss_rate()
                   - by_sched["edf"].slo_miss_rate())
    rows.append(Row("slo_edf_minus_fifo_miss_pts", improvement * 100.0,
                    "positive=EDF better"))
    return rows
