"""Paper Table 4: resnet50 end-to-end latency when the second invocation
lands in each exit-ladder stage (30 s per stage)."""
from __future__ import annotations

from benchmarks.common import Row, make_gateway
from repro.api import TraceWorkload
from repro.core.profiles import TABLE4_RESNET50

# second-arrival offsets hitting the middle of each stage (ttl = 30 s)
STAGE_OFFSETS = {
    "stage1": 15.0, "stage2": 45.0, "stage3": 75.0, "stage4": 105.0,
    "cold": 1000.0,
}


def run(quick: bool = True):
    rows = []
    e2e = {}
    for stage, dt in STAGE_OFFSETS.items():
        gw = make_gateway("sage")
        tel = gw.replay(
            TraceWorkload([(0.0, "resnet50"), (dt, "resnet50")]),
            until=dt + 1e5,
        )
        rec = max(tel.records, key=lambda r: r.arrival_t)  # the 2nd arrival
        e2e[stage] = rec.e2e
        paper = TABLE4_RESNET50[stage]["end_to_end"] / 1e3
        rows.append(Row(f"table4_resnet50_{stage}", rec.e2e * 1e6,
                        f"paper={paper*1e3:.1f}ms ratio={rec.e2e/paper:.2f}"))
    # the ladder property: warmer stages are strictly cheaper
    ordered = e2e["stage1"] <= e2e["stage2"] <= e2e["stage3"] <= e2e["cold"] * 1.001
    rows.append(Row("table4_ladder_monotonic", 0.0, f"monotonic={ordered}"))
    return rows


if __name__ == "__main__":
    for r in run():
        r.print()
