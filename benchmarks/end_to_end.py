"""Paper Figs 10/11/12: 4 systems x 10 functions on the MAF-like trace —
normalized mean latency, system throughput, memory usage."""
from __future__ import annotations

from benchmarks.common import NAMES, Row, replay
from repro.api import MAFWorkload

SYSTEMS = ("fixedgsl", "fixedgsl-f", "dgsf", "sage")


def run(quick: bool = True):
    dur = 600.0 if quick else 7200.0  # paper replays 2 h
    workload = MAFWorkload(NAMES, dur, seed=3, mean_rpm=30)
    stats = {}
    for system in SYSTEMS:
        gw = replay(system, workload, until_pad=10 * dur)
        # throughput counts only completions INSIDE the trace window — a
        # saturated system drains late and must not get credit for it
        in_window = sum(1 for r in gw.telemetry.records if r.end_t <= dur)
        stats[system] = dict(
            e2e=gw.telemetry.mean_e2e(),
            p99=gw.telemetry.p99_e2e(),
            thr=in_window / dur,
            mem=gw.mean_memory_bytes(),
        )
    f = stats["fixedgsl"]
    s = stats["sage"]
    d = stats["dgsf"]
    rows = [
        Row("fig10_latency_sage_vs_fixedgsl", s["e2e"] * 1e6,
            f"speedup={f['e2e']/s['e2e']:.1f}x (paper: 193.4x)"),
        Row("fig10_latency_sage_vs_dgsf", s["e2e"] * 1e6,
            f"speedup={d['e2e']/s['e2e']:.1f}x (paper: 13.3x)"),
        Row("fig10_p99_sage_vs_fixedgsl", s["p99"] * 1e6,
            f"speedup={f['p99']/s['p99']:.1f}x (paper: 54.1x)"),
        Row("fig11_throughput_sage_vs_fixedgsl", 1e6 / max(s["thr"], 1e-9),
            f"ratio={s['thr']/max(f['thr'],1e-9):.2f}x (paper: 8.9x)"),
        Row("fig11_throughput_sage_vs_dgsf", 1e6 / max(s["thr"], 1e-9),
            f"ratio={s['thr']/max(d['thr'],1e-9):.2f}x (paper: 1.22x)"),
        Row("fig12_memory_sage_over_fixedgsl", s["mem"] / (1 << 20),
            f"ratio={s['mem']/max(f['mem'],1):.3f} (paper: 0.187)"),
        Row("fig12_memory_sage_over_dgsf", s["mem"] / (1 << 20),
            f"ratio={s['mem']/max(d['mem'],1):.3f} (paper: 0.375)"),
    ]
    return rows


if __name__ == "__main__":
    for r in run():
        r.print()
