"""Paper Fig 16: DGSF vs SAGE-NR (no read-only sharing) vs SAGE."""
from __future__ import annotations

from benchmarks.common import NAMES, Row, replay
from repro.api import MAFWorkload


def run(quick: bool = True):
    workload = MAFWorkload(NAMES, 600.0, seed=3, mean_rpm=10)
    e2e, mem = {}, {}
    for system in ("dgsf", "sage-nr", "sage"):
        gw = replay(system, workload, until_pad=6000.0)
        e2e[system] = gw.telemetry.mean_e2e()
        mem[system] = gw.mean_memory_bytes()
    return [
        Row("fig16_sage_vs_sage_nr", e2e["sage"] * 1e6,
            f"speedup={e2e['sage-nr']/e2e['sage']:.1f}x (paper: 8.2x)"),
        Row("fig16_sage_vs_dgsf", e2e["sage"] * 1e6,
            f"speedup={e2e['dgsf']/e2e['sage']:.1f}x (paper: 13.3x)"),
        Row("fig16_sage_nr_beats_dgsf", e2e["sage-nr"] * 1e6,
            f"dgsf/sage_nr={e2e['dgsf']/e2e['sage-nr']:.2f}x (paper: >1)"),
        Row("fig16_memory_nr_over_sage", mem["sage-nr"] / (1 << 20),
            f"ratio={mem['sage-nr']/max(mem['sage'],1):.2f}"),
    ]


if __name__ == "__main__":
    for r in run():
        r.print()
