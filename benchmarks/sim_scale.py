"""Simulator-scale benchmark: the recorded perf trajectory (BENCH_*.json).

Replays trace-scale scenarios through the discrete-event kernel
(``repro.core.sim``) in ``record_mode="aggregate"`` and reports replay
throughput (events/sec, invocations/sec) next to the serving headlines
(p50/p99 e2e, goodput, warm fraction). The headline scenario drives
>=1,000,000 invocations across 64 simulated nodes; the target budget is
60 s of wall-clock on CI hardware.

Scenarios (full mode):

* ``steady_warm_1m`` — 64 nodes, 8 synthetic zero-writable-payload
  services at steady rate: ~1.02M arrivals, warm-dominated. This is the
  kernel-throughput headline: a warm SAGE hit costs 2 events
  (FEED + COMPUTE), so the replay measures the kernel + domain fast
  path, not the transfer solver.
* ``maf_replay`` — 8 nodes, the ten paper profiles under an MAF-like
  arrival mix (the §7.8-style trace at bench scale): cold starts, exit
  ladders, and the contended data path all exercised.
* ``flash_crowd`` — 16 nodes, EDF + locality dispatch + preemptive
  transfer under :class:`FlashCrowdWorkload` spikes with per-function
  deadlines: the PR-3/4/5 knob stack at scale, goodput is the headline.
* ``diurnal_multiregion`` — 32 nodes, three :class:`DiurnalWorkload`
  regions phase-shifted via :class:`MultiRegionWorkload` (compressed
  day): rolling peaks keep mean load moderate while troughs walk the
  exit ladders.

``--quick`` shrinks every duration ~20x for the CI smoke job; the
scenario *shapes* are unchanged.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

from benchmarks.common import NAMES, Row
from repro.api.workload import (
    DiurnalWorkload,
    FlashCrowdWorkload,
    MAFWorkload,
    MixWorkload,
    MultiRegionWorkload,
    Workload,
)
from repro.core.profiles import PROFILES, FunctionProfile
from repro.core.simulator import Simulator, SimFunction

BENCH_ID = 10  # perf-trajectory point for this PR (density section added)
SCHEMA = "sim_scale/v1"


def _synthetic_services(n: int = 8) -> List[FunctionProfile]:
    """Zero-writable-payload inference services (weights resident, request
    payload negligible): a warm hit moves no bytes, so steady-state load
    isolates kernel + policy overhead from the transfer solver."""
    return [
        FunctionProfile(f"svc{i}", "synthetic", context_mb=414.0,
                        read_only_mb=24.0 + 4.0 * i, writable_mb=0.0,
                        compute_ms=10.0 + 2.0 * i)
        for i in range(n)
    ]


def _replay(sim: Simulator, wl: Workload, until: float) -> Dict[str, float]:
    """Feed ``wl`` through the streaming replay path and run to ``until``;
    returns the scenario report (wall-clock covers feed + run)."""
    t0 = time.perf_counter()
    sim.replay_stream(wl.stream())
    sim.run(until)
    wall = time.perf_counter() - t0
    snap = sim.telemetry.snapshot()
    events = sim.clock.events_processed
    count = snap["count"]
    return {
        "nodes": len(sim.nodes),
        "invocations": count,
        "completed": snap["completed"],
        "failures": snap["failures"],
        "warm_fraction": round(snap["warm_fraction"], 4),
        "p50_e2e_s": round(snap["p50_e2e_s"], 6),
        "p99_e2e_s": round(snap["p99_e2e_s"], 6),
        "goodput": round(snap["goodput"], 4),
        "preemptions": sim.preemption_count(),
        "sim_horizon_s": sim.clock.now(),
        "wall_s": round(wall, 3),
        "invocations_per_s": round(count / wall, 1) if wall > 0 else 0.0,
        "events": events,
        "events_per_s": round(events / wall, 1) if wall > 0 else 0.0,
        "past_events": sim.clock.past_events,
    }


# ----------------------------------------------------------------------
# scenarios
# ----------------------------------------------------------------------
def steady_warm_1m(quick: bool = False) -> Dict[str, float]:
    """>=1M invocations across 64 nodes (the acceptance headline)."""
    duration = 20.0 if quick else 400.0  # 8 fns x 320/s -> 2560 arrivals/s
    sim = Simulator("sage", n_nodes=64, seed=7, record_mode="aggregate")
    profiles = _synthetic_services()
    for p in profiles:
        sim.register(SimFunction(p))
    wl = MixWorkload({p.name: 320.0 for p in profiles}, duration, seed=11)
    return _replay(sim, wl, duration + 100.0)


def maf_replay(quick: bool = False) -> Dict[str, float]:
    """Ten paper profiles, MAF-like mix, 8 nodes: the cold-path scenario."""
    duration = 300.0 if quick else 3600.0
    sim = Simulator("sage", n_nodes=8, seed=3, record_mode="aggregate")
    for n in NAMES:
        sim.register(SimFunction(PROFILES[n]))
    wl = MAFWorkload(NAMES, duration, seed=3, mean_rpm=60.0)
    return _replay(sim, wl, duration + 600.0)


def flash_crowd(quick: bool = False) -> Dict[str, float]:
    """EDF + locality + preemptive transfer under flash-crowd spikes."""
    duration = 90.0 if quick else 300.0
    sim = Simulator("sage", n_nodes=16, seed=5, record_mode="aggregate",
                    scheduler="edf", dispatch="locality",
                    transfer="preemptive", loader_threads=1)
    names = ["resnet50", "vgg11", "seq2seq", "inception3"]
    for n in names:
        sim.register(SimFunction(PROFILES[n]))
    wl = FlashCrowdWorkload(
        names, base_rate_per_s=25.0, duration_s=duration,
        spike_times_s=tuple(duration * f for f in (0.2, 0.5, 0.8)),
        spike_factor=8.0, decay_s=20.0, seed=5,
        deadline_s={"resnet50": 5.0, "vgg11": 10.0, "seq2seq": 1.0,
                    "inception3": 5.0},
        priority={"resnet50": 1, "vgg11": 0, "seq2seq": 2, "inception3": 1})
    return _replay(sim, wl, duration + 300.0)


def diurnal_multiregion(quick: bool = False) -> Dict[str, float]:
    """Three phase-shifted diurnal regions on 32 nodes (compressed day)."""
    duration = 120.0 if quick else 480.0
    period = duration / 2.0
    sim = Simulator("sage", n_nodes=32, seed=9, record_mode="aggregate",
                    dispatch="locality")
    names = ["resnet50", "deepspeech", "nasnet", "seq2seq", "mrif", "tpacf"]
    for n in names:
        sim.register(SimFunction(PROFILES[n]))
    regions = {
        region: DiurnalWorkload(
            names, base_rate_per_s=12.0, duration_s=duration,
            amplitude=0.8, period_s=period, seed=13 + i)
        for i, region in enumerate(("us", "eu", "ap"))
    }
    wl = MultiRegionWorkload(
        regions, offsets_s={"us": 0.0, "eu": period / 3.0,
                            "ap": 2.0 * period / 3.0})
    return _replay(sim, wl, duration + period + 300.0)


SCENARIOS = {
    "steady_warm_1m": steady_warm_1m,
    "maf_replay": maf_replay,
    "flash_crowd": flash_crowd,
    "diurnal_multiregion": diurnal_multiregion,
}


# ----------------------------------------------------------------------
# entry points
# ----------------------------------------------------------------------
def bench_json(quick: bool = False) -> Dict:
    """The BENCH_*.json document (docs/simulator.md describes the schema)."""
    scenarios = {name: fn(quick) for name, fn in SCENARIOS.items()}
    head = scenarios["steady_warm_1m"]
    return {
        "bench": BENCH_ID,
        "schema": SCHEMA,
        "quick": quick,
        "headline": {
            "invocations": head["invocations"],
            "nodes": head["nodes"],
            "wall_s": head["wall_s"],
            "invocations_per_s": head["invocations_per_s"],
            "events_per_s": head["events_per_s"],
        },
        "scenarios": scenarios,
    }


def run(quick: bool = True):
    """CSV-harness adapter (benchmarks/run.py default mode): one row per
    scenario — us_per_call is wall-microseconds per replayed invocation."""
    for name, fn in SCENARIOS.items():
        if quick and name != "steady_warm_1m":
            continue  # the smoke row; --bench-json runs the full set
        r = fn(quick)
        us = 1e6 * r["wall_s"] / max(r["invocations"], 1)
        yield Row(f"sim_scale/{name}", us,
                  f"inv={r['invocations']};ev_per_s={r['events_per_s']:.0f};"
                  f"p99_e2e={r['p99_e2e_s']:.4f};goodput={r['goodput']}")
