"""Kernel micro-benchmarks: wall time of the pure-jnp reference path on CPU
(the Pallas path targets TPU; interpret mode is a correctness tool, not a
performance path) + HLO-derived TPU roofline estimates per kernel.

The batch-axis sweep measures what same-function invocation batching
(docs/compute.md) buys at the kernel level: n concurrent invocations of
one function stack along the leading batch axis into a single launch, so
the per-invocation cost is t(n)/n and the marginal cost of each extra
member is (t(n) - t(1)) / ((n-1) * t(1)) — the measured counterpart of
the compute plane's ``batch_marginal`` model knob."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import Row
from repro.analysis.hlo_analysis import analyze_hlo_text
from repro.analysis.roofline import HBM_BW, PEAK_FLOPS

BATCH_SWEEP = (1, 2, 4, 8)


def _time(fn, *args, iters=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters


def batch_sweep(quick: bool = True):
    """Per-invocation amortization of stacking same-function invocations
    along the batch axis, for each of the three kernels. ``amort`` is
    t(n)/(n*t(1)) — perfect sharing is 1/n, no sharing is 1.0;
    ``marginal`` is the per-extra-member cost the compute plane models."""
    from repro.models.layers import decode_attention_ref, flash_attention_ref
    from repro.models.mamba2 import ssd_chunked_ref

    key = jax.random.PRNGKey(1)
    S = 256 if quick else 1024  # smaller seq: the sweep scales the batch
    L = 1024 if quick else 4096

    def flash(n):
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (n, S, 8, 64), jnp.float32)
        k = jax.random.normal(ks[1], (n, S, 2, 64), jnp.float32)
        v = jax.random.normal(ks[2], (n, S, 2, 64), jnp.float32)
        f = jax.jit(lambda q, k, v: flash_attention_ref(q, k, v, causal=True))
        return _time(f, q, k, v)

    def ssd(n):
        ks = jax.random.split(key, 5)
        x = jax.random.normal(ks[0], (n, S, 8, 64))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (n, S, 8)))
        A = -jnp.exp(jax.random.normal(ks[2], (8,)) * 0.3)
        Bm = jax.random.normal(ks[3], (n, S, 128))
        Cm = jax.random.normal(ks[4], (n, S, 128))
        g = jax.jit(lambda *a: ssd_chunked_ref(*a, chunk=128)[0])
        return _time(g, x, dt, A, Bm, Cm)

    def decode(n):
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (n, 1, 16, 128))
        kc = jax.random.normal(ks[1], (n, L, 2, 128))
        vc = jax.random.normal(ks[2], (n, L, 2, 128))
        lens = jnp.full((n,), L, jnp.int32)
        h = jax.jit(lambda *a: decode_attention_ref(*a))
        return _time(h, q, kc, vc, lens)

    rows = []
    for name, bench in (("flash_attention", flash), ("ssd_scan", ssd),
                        ("decode_attention", decode)):
        t1 = bench(1)
        for n in BATCH_SWEEP:
            t = t1 if n == 1 else bench(n)
            amort = t / (n * t1)
            marginal = ((t - t1) / ((n - 1) * t1)) if n > 1 else 1.0
            rows.append(Row(f"kernel_{name}_batch{n}", t * 1e6 / n,
                            f"amort={amort:.3f} marginal={marginal:.3f}"))
    return rows


def run(quick: bool = True):
    rows = []
    key = jax.random.PRNGKey(0)
    # flash attention reference at a serving-relevant shape
    from repro.models.layers import flash_attention_ref

    B, S, Hq, Hkv, Dh = 1, 2048, 8, 2, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, Hq, Dh), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, Dh), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, Dh), jnp.float32)
    f = jax.jit(lambda q, k, v: flash_attention_ref(q, k, v, causal=True))
    t = _time(f, q, k, v)
    lowered = f.lower(q, k, v).compile()
    rep = analyze_hlo_text(lowered.as_text())
    tpu_est = max(rep.dot_flops / PEAK_FLOPS, rep.hbm_bytes / HBM_BW)
    rows.append(Row("kernel_flash_attention_2k", t * 1e6,
                    f"flops={rep.dot_flops:.2e} tpu_roofline_est={tpu_est*1e6:.1f}us"))

    from repro.models.mamba2 import ssd_chunked_ref

    B, S, H, P, N = 1, 2048, 8, 64, 128
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(ks[4], (B, S, N))
    g = jax.jit(lambda *a: ssd_chunked_ref(*a, chunk=128)[0])
    t = _time(g, x, dt, A, Bm, Cm)
    rep = analyze_hlo_text(g.lower(x, dt, A, Bm, Cm).compile().as_text())
    tpu_est = max(rep.dot_flops / PEAK_FLOPS, rep.hbm_bytes / HBM_BW)
    rows.append(Row("kernel_ssd_scan_2k", t * 1e6,
                    f"flops={rep.dot_flops:.2e} tpu_roofline_est={tpu_est*1e6:.1f}us"))

    from repro.models.layers import decode_attention_ref

    B, L, Hq, Hkv, Dh = 8, 8192, 16, 2, 128
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (B, 1, Hq, Dh))
    kc = jax.random.normal(ks[1], (B, L, Hkv, Dh))
    vc = jax.random.normal(ks[2], (B, L, Hkv, Dh))
    lens = jnp.full((B,), L, jnp.int32)
    h = jax.jit(lambda *a: decode_attention_ref(*a))
    t = _time(h, q, kc, vc, lens)
    rep = analyze_hlo_text(h.lower(q, kc, vc, lens).compile().as_text())
    tpu_est = max(rep.dot_flops / PEAK_FLOPS, rep.hbm_bytes / HBM_BW)
    rows.append(Row("kernel_decode_attention_8k", t * 1e6,
                    f"hbm={rep.hbm_bytes:.2e}B tpu_roofline_est={tpu_est*1e6:.1f}us"))
    rows.extend(batch_sweep(quick))
    return rows


if __name__ == "__main__":
    import sys

    for r in run(quick="--full" not in sys.argv):
        r.print()
