"""Roofline table from the dry-run artifacts (§Roofline of EXPERIMENTS.md).

Reads artifacts/dryrun/*.json (produced by ``python -m repro.launch.dryrun
--all --both-meshes``) and prints the three-term roofline per (arch x shape
x mesh) with dominant bottleneck and MODEL_FLOPS / HLO_FLOPs ratio."""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import Row

ART = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def load_records(mesh: str = "16x16"):
    recs = []
    for f in sorted(ART.glob("*.json")):
        if f.name == "summary.json":
            continue
        r = json.loads(f.read_text())
        if r.get("mesh") == mesh:
            recs.append(r)
    return recs


def table(mesh: str = "16x16") -> str:
    rows = []
    hdr = (f"{'arch':26s} {'shape':12s} {'st':4s} {'compute_s':>10s} "
           f"{'memory_s':>10s} {'collect_s':>10s} {'dominant':>12s} "
           f"{'useful':>7s} {'roofline%':>9s}")
    rows.append(hdr)
    for r in load_records(mesh):
        if r["status"] != "OK":
            rows.append(f"{r['arch']:26s} {r['shape']:12s} {r['status']:4s} "
                        f"{r.get('reason', r.get('log', ''))}")
            continue
        rl = r["roofline"]
        rows.append(
            f"{r['arch']:26s} {r['shape']:12s} OK   {rl['compute_s']:10.4f} "
            f"{rl['memory_s']:10.4f} {rl['collective_s']:10.4f} "
            f"{rl['dominant']:>12s} {rl['useful_flops_ratio']:7.3f} "
            f"{rl['roofline_fraction']*100:8.2f}%"
        )
    return "\n".join(rows)


def run(quick: bool = True):
    recs = load_records("16x16")
    rows = []
    if not recs:
        rows.append(Row("roofline_table", 0.0,
                        "no artifacts — run: python -m repro.launch.dryrun --all --both-meshes"))
        return rows
    ok = [r for r in recs if r["status"] == "OK"]
    for r in ok:
        rl = r["roofline"]
        rows.append(Row(
            f"roofline_{r['arch']}_{r['shape']}",
            rl["step_time_bound_s"] * 1e6,
            f"dom={rl['dominant'][:-2]} useful={rl['useful_flops_ratio']:.2f} "
            f"roofline={rl['roofline_fraction']*100:.1f}%",
        ))
    frac = sum(r["roofline"]["roofline_fraction"] for r in ok) / max(len(ok), 1)
    rows.append(Row("roofline_mean_fraction", frac * 1e6,
                    f"mean_roofline_fraction={frac*100:.2f}% over {len(ok)} cells"))
    return rows


if __name__ == "__main__":
    print(table("16x16"))
