"""Paper Fig 2 (FixedGSL) / Fig 15 (SAGE parallel-setup-only): close-loop
cold-invocation duration breakdown per function."""
from __future__ import annotations

from benchmarks.common import NAMES, Row, make_gateway
from repro.core.telemetry import SETUP_STAGES, STAGES


def cold_breakdown(system: str) -> dict:
    """One isolated cold invocation per function (close-loop, no contention
    — the paper's Fig 2 solo methodology)."""
    out = {}
    for name in NAMES:
        gw = make_gateway(system)
        rec = gw.invoke(name, at=0.0)
        out[name] = {
            "e2e": rec.e2e,
            "stages": dict(rec.stages),
            "compute_share": rec.stages.get("compute", 0.0) / max(rec.e2e, 1e-12),
        }
    return out


def run(quick: bool = True):
    rows = []
    fixed = cold_breakdown("fixedgsl")
    sage_ps = cold_breakdown("sage-ps")
    mean_e2e_f = sum(v["e2e"] for v in fixed.values()) / len(fixed)
    mean_comp = sum(v["compute_share"] for v in fixed.values()) / len(fixed)
    rows.append(Row("fig2_fixedgsl_cold_e2e_mean", mean_e2e_f * 1e6,
                    f"compute_share={mean_comp:.3f} (paper: 0.071-0.121)"))
    # Fig 15: parallelized setup alone reduces setup time (paper: 20.8%)
    setup_f = sum(sum(v["stages"].get(s, 0) for s in SETUP_STAGES)
                  for v in fixed.values()) / len(fixed)
    e2e_ps = sum(v["e2e"] for v in sage_ps.values()) / len(sage_ps)
    setup_ps = e2e_ps - sum(
        v["stages"].get("compute", 0) + v["stages"].get("return_result", 0)
        for v in sage_ps.values()) / len(sage_ps)
    red = 1 - setup_ps / setup_f
    rows.append(Row("fig15_parallel_setup_reduction", setup_ps * 1e6,
                    f"setup_cut={red*100:.1f}% (paper: 20.8%)"))
    return rows


if __name__ == "__main__":
    for r in run():
        r.print()
