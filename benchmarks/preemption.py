"""Preemptive transfer scheduling: chunked, preemptible streams vs atomic
run-to-completion transfers under a contended mixed-size / mixed-deadline
trace (docs/dataplane.md, "Transfer scheduling"; FaaSTube arXiv:2411.01830).

One narrow loader pool (one worker) serves two classes: loose-deadline
batch functions with large working sets, and a tight-deadline
latency-critical function with a small one. ``scheduler="edf"`` is on for
BOTH arms, so queued work is already deadline-ordered — the only varied
knob is ``transfer``. Under ``run_to_completion`` a tight load arriving
mid-way through a loose 800 MB stream still waits the stream out; under
``preemptive`` the in-flight stream pauses between chunks and yields the
link, so the tight class's p99 duration collapses while the batch class
pays only the chunk-granularity stall. Rows report both backends (the
strictly-beats contract is asserted in tests/test_transfer.py).
"""
from __future__ import annotations

import time

from benchmarks.common import Row, data_plane_function
from repro.api import FunctionSpec, Gateway, MixWorkload
from repro.core.profiles import MB

TIGHT_DEADLINE_S = 1.2
BATCH_DEADLINE_S = 60.0


# ---------------------------------------------------------------------------
# virtual-time twin
# ---------------------------------------------------------------------------

def _sim_stats(transfer: str, duration_s: float):
    gw = Gateway(backend="sim", policy="sage", scheduler="edf",
                 transfer=transfer, loader_threads=1, seed=11)
    rates = {}
    for i in range(3):
        name = f"batch{i}"
        gw.register(FunctionSpec(
            name=name, read_only_bytes=0, writable_bytes=800 * MB,
            context_bytes=MB, compute_ms=10.0,
            deadline_s=BATCH_DEADLINE_S, priority=0))
        rates[name] = 0.3
    gw.register(FunctionSpec(
        name="tight", read_only_bytes=0, writable_bytes=24 * MB,
        context_bytes=MB, compute_ms=5.0,
        deadline_s=TIGHT_DEADLINE_S, priority=1))
    rates["tight"] = 1.0
    tel = gw.replay(MixWorkload(rates, duration_s, seed=11), until_pad=600.0)
    return {
        "tight_p99": tel.p99_duration("tight"),
        "tight_miss": (tel.slo_by_priority().get(1, {}) or {}).get("miss_rate", 0.0),
        "preemptions": float(gw.sim.preemption_count()),
        "stalled_s": tel.transfer_wait(),
        "n": float(len(tel.records)),
    }


# ---------------------------------------------------------------------------
# threaded runtime (synthetic functions: the comparison is the data plane)
# ---------------------------------------------------------------------------

def _runtime_stats(transfer: str, rounds: int):
    from repro.core.request import Data, DataType, Request
    from repro.core.runtime import SageRuntime

    rt = SageRuntime("sage", loader_threads=1, scheduler="edf",
                     transfer=transfer, serialize_compute=False)
    rt.sage_init()
    for i in range(2):
        rt.register_function(data_plane_function(f"batch{i}", wait_s=60.0))
    rt.register_function(data_plane_function("tight", wait_s=60.0))

    def req(fn, mb, deadline_s, priority, tag):
        r = Request(function_name=fn)
        key = f"{fn}/in/{tag}"
        rt.db.put(key, b"X", size=mb * MB)
        r.in_data = [Data(key=key, size=mb * MB, dtype=DataType.WRITABLE)]
        r.deadline_s, r.priority = deadline_s, priority
        return r

    try:
        futs = []
        for rnd in range(rounds):
            for i in range(2):  # loose 400 MB loads own the single worker
                futs.append(rt.submit(req(f"batch{i}", 400, BATCH_DEADLINE_S,
                                          0, f"{rnd}-{i}")))
            time.sleep(0.08)  # tight arrives mid-way through a batch stream
            futs.append(rt.submit(req("tight", 16, TIGHT_DEADLINE_S, 1,
                                      str(rnd))))
            time.sleep(0.4)  # drain most of the round before the next burst
        for f in futs:
            f.result(timeout=120)
        tel = rt.telemetry
        return {
            "tight_p99": tel.p99_duration("tight"),
            "tight_miss": (tel.slo_by_priority().get(1, {}) or {}).get("miss_rate", 0.0),
            "preemptions": float(rt.daemon.stats["preemptions"]),
            "stalled_s": tel.transfer_wait(),
            "n": float(len(tel.records)),
        }
    finally:
        rt.shutdown()


# ---------------------------------------------------------------------------

def run(quick: bool = True):
    duration = 90.0 if quick else 600.0
    rounds = 3 if quick else 10
    rows = []
    for backend, stats_fn, arg in (("sim", _sim_stats, duration),
                                   ("runtime", _runtime_stats, rounds)):
        res = {mode: stats_fn(mode, arg)
               for mode in ("run_to_completion", "preemptive")}
        rtc, pre = res["run_to_completion"], res["preemptive"]
        rows.append(Row(
            f"preempt_{backend}_tight_p99_rtc", rtc["tight_p99"] * 1e6,
            f"miss_rate={rtc['tight_miss']:.3f};n={int(rtc['n'])}"))
        rows.append(Row(
            f"preempt_{backend}_tight_p99_preemptive", pre["tight_p99"] * 1e6,
            f"miss_rate={pre['tight_miss']:.3f};"
            f"speedup={rtc['tight_p99']/max(pre['tight_p99'],1e-9):.1f}x"))
        rows.append(Row(
            f"preempt_{backend}_preemptions", pre["preemptions"],
            f"stalled_s={pre['stalled_s']:.3f};"
            f"rtc_stalled_s={rtc['stalled_s']:.3f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        r.print()
