"""Function-density benchmark: shared compute plane vs the exclusive seed
(docs/compute.md).

The paper's headline cluster result is a 1.22x function-density win from
fast setup alone. This benchmark measures the density the *compute* plane
adds on top: a contended multi-small-function trace is replayed twice per
driver —

* **exclusive**: the seed's one-kernel-at-a-time compute FIFO (the paper's
  ``Throughput_theo = T_period / T_comp`` model) — small functions
  serialize behind each other even though each needs a fraction of the SMs;
* **shared**: ``compute="shared"`` with same-function batching — each small
  function takes its auto-derived slice of the SM budget, co-runs with the
  others, and concurrent invocations of one function coalesce into a
  single stacked kernel launch (amortization pinned by
  ``benchmarks/kernel_bench.py``'s batch-axis sweep).

Function density is completions per node-second over the trace's makespan.
The gate: shared must beat exclusive by MORE than the paper's 1.22x on
BOTH drivers, with tight-class SLO attainment no worse under EDF (the
batch collector never holds a member past its EDF slack, so batching must
not buy throughput with tight-class misses). ``python -m
benchmarks.density`` prints both tables and exits non-zero on a miss.
"""
from __future__ import annotations

import sys
import time
from typing import Dict, Optional, Tuple

from repro.api.workload import ChaosWorkload
from repro.core.profiles import FunctionProfile
from repro.core.simulator import SimFunction, Simulator

DEFAULT_SEED = 47
N_NODES = 2
#: the paper's headline function-density ratio — the bar to beat
PAPER_DENSITY_X = 1.22

# the shared-plane config under test: auto slice sizing + batching
SHARED = {"max_batch": 4, "batch_window_s": 0.005}

# {function: (rate_per_s, deadline_s, priority)} — six small functions
# whose aggregate compute demand oversubscribes the exclusive FIFO on
# N_NODES (each needs ~3/8 of a node's SMs, so the shared plane packs
# ~2.7 of them per node instead of 1)
CLASSES: Dict[str, Tuple[float, Optional[float], int]] = {
    "tight0": (30.0, 0.5, 2),
    "tight1": (30.0, 0.5, 2),
    "tight2": (30.0, 0.5, 2),
    "loose0": (30.0, 5.0, 0),
    "loose1": (30.0, 5.0, 0),
    "loose2": (30.0, 5.0, 0),
}
COMPUTE_MS = 15.0


def _density_summary(t, n_nodes: int) -> Dict[str, object]:
    recs = [r for r in t.snapshot() if not r.dropped and r.error is None]
    if not recs:
        return {"completed": 0, "density_per_node_s": 0.0,
                "tight_attainment": 0.0, "makespan_s": 0.0,
                "mean_batch": 1.0}
    makespan = max(r.end_t for r in recs) - min(r.arrival_t for r in recs)
    tight = [r for r in recs if r.function.startswith("tight")]
    attained = sum(1 for r in tight if not r.slo_miss)
    return {
        "completed": len(recs),
        "makespan_s": round(makespan, 3),
        "density_per_node_s": round(len(recs) / (n_nodes * makespan), 3),
        "tight_attainment": round(attained / max(1, len(tight)), 4),
        "mean_batch": round(sum(r.batch_size for r in recs) / len(recs), 3),
    }


# ----------------------------------------------------------------------
# sim driver: EDF + locality, contended six-function trace
# ----------------------------------------------------------------------
def run_sim(compute, quick: bool = False,
            seed: int = DEFAULT_SEED) -> Dict[str, object]:
    duration = 15.0 if quick else 60.0
    sim = Simulator("sage", n_nodes=N_NODES, seed=seed,
                    scheduler="edf", dispatch="locality", compute=compute)
    for name in sorted(CLASSES):
        sim.register(SimFunction(FunctionProfile(
            name, "density", context_mb=64.0, read_only_mb=24.0,
            writable_mb=4.0, compute_ms=COMPUTE_MS)))
    wl = ChaosWorkload(CLASSES, duration, seed=seed)
    for i, a in enumerate(wl.events()):
        sim.submit(a.function, a.t, deadline_s=a.deadline_s,
                   priority=a.priority, request_id=f"d{i}-{a.function}")
    sim.run()  # drain fully: density is judged on the true makespan
    out = _density_summary(sim.telemetry, N_NODES)
    out["compute"] = sim.compute_stats()
    # the plane must leave the books exactly as the seed path does
    for n in sim.nodes:
        assert 0 <= n.used <= n.capacity, f"{n.name}: used={n.used}"
        assert n.inflight_loads == 0, f"{n.name} leaked loader slots"
    return out


# ----------------------------------------------------------------------
# runtime driver: real threads, sleep-modeled kernels, one node
# ----------------------------------------------------------------------
def run_runtime(compute, quick: bool = False,
                seed: int = DEFAULT_SEED) -> Dict[str, object]:
    from repro.core.engine import GPUFunction
    from repro.core.request import Request
    from repro.core.runtime import SageRuntime

    compute_s = 0.010
    per_fn = 8 if quick else 16
    fn_names = ["d0", "d1", "d2"]
    rt = SageRuntime("sage", max_workers=64, serialize_compute=True,
                     compute=compute)
    rt.sage_init()
    try:
        for name in fn_names:

            def handler(shim, request, _c=compute_s):
                time.sleep(_c)

            rt.register_function(GPUFunction(
                name=name, handler=handler,
                context_builder=lambda: object(),
                context_bytes=1 << 20, container_s=0.0, cpu_ctx_s=0.0,
                compute_s_hint=compute_s))
        t0 = rt.clock.now()
        futs = []
        # round-robin burst: concurrent same-function arrivals exist for
        # the batch collector, and all three functions contend at once
        for i in range(per_fn):
            for name in fn_names:
                futs.append(rt.submit(Request(
                    function_name=name, deadline_s=0.3, priority=2)))
        for f in futs:
            f.result(timeout=120.0)
        makespan = rt.clock.now() - t0
        recs = [r for r in rt.telemetry.snapshot() if r.error is None]
        attained = sum(1 for r in recs if not r.slo_miss)
        out = {
            "completed": len(recs),
            "makespan_s": round(makespan, 3),
            "density_per_node_s": round(len(recs) / makespan, 3),
            "tight_attainment": round(attained / max(1, len(recs)), 4),
            "mean_batch": round(sum(r.batch_size for r in recs)
                                / max(1, len(recs)), 3),
            "compute": rt.compute_stats(),
        }
        mu = rt.memory_usage()
        assert all(v >= 0 for v in mu.values()), f"memory books: {mu}"
        assert rt.daemon.leaked_bytes == 0, (
            f"{rt.daemon.leaked_bytes} leaked bytes after the burst")
        return out
    finally:
        rt.shutdown()


# ----------------------------------------------------------------------
# entry points
# ----------------------------------------------------------------------
def _compare(exclusive: Dict, shared: Dict) -> Dict[str, object]:
    dx = exclusive["density_per_node_s"]
    ds = shared["density_per_node_s"]
    return {
        "exclusive": exclusive,
        "shared": shared,
        "density_ratio": round(ds / dx, 3) if dx else float("inf"),
        "beats": (ds > dx * PAPER_DENSITY_X
                  and shared["tight_attainment"]
                  >= exclusive["tight_attainment"]),
    }


def bench_section(quick: bool = False) -> Dict[str, object]:
    """The ``density`` section of BENCH_*.json: the sim driver's exclusive
    vs shared density under the contended trace (the runtime driver is
    covered by the CI density smoke, not the artifact)."""
    out = _compare(run_sim(None, quick), run_sim(SHARED, quick))
    out["seed"] = DEFAULT_SEED
    out["paper_density_x"] = PAPER_DENSITY_X
    return out


def run(quick: bool = True):
    """CSV-harness adapter (benchmarks/run.py): one row per config."""
    from benchmarks.common import Row

    for label, compute in (("exclusive", None), ("shared", SHARED)):
        r = run_sim(compute, quick)
        yield Row(f"density/sim_{label}", 0.0,
                  f"density={r['density_per_node_s']}/node/s;"
                  f"tight_slo={r['tight_attainment']};"
                  f"mean_batch={r['mean_batch']}")


def main(quick: bool = False) -> int:
    ok = True
    for driver, fn in (("sim", run_sim), ("runtime", run_runtime)):
        cmp = _compare(fn(None, quick), fn(SHARED, quick))
        status = "PASS" if cmp["beats"] else "FAIL"
        ok &= cmp["beats"]
        ex, sh = cmp["exclusive"], cmp["shared"]
        print(f"[{driver}] exclusive {ex['density_per_node_s']}/node/s "
              f"(tight SLO {ex['tight_attainment']}) vs shared "
              f"{sh['density_per_node_s']}/node/s "
              f"(tight SLO {sh['tight_attainment']}, "
              f"mean_batch {sh['mean_batch']}) -> "
              f"{cmp['density_ratio']}x (bar {PAPER_DENSITY_X}x) {status}")
        print(f"  shared compute: {sh['compute']}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main(quick="--quick" in sys.argv))
