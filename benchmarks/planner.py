"""Planner benchmark: planned dispatch + predictive autoscaling vs a
fixed locality pool on a churning diurnal trace (docs/planner.md).

Six functions with phase-staggered :class:`~repro.api.workload
.DiurnalWorkload` rates rotate the hot set through the day, churning the
residency map. The baseline provisions ``locality`` dispatch a static
pool sized for the peak; the planned config starts at the autoscaler
floor and lets the control plane follow the forecast — planner homes for
warm routing, work stealing over saturated homes, drains through the
exact eviction teardown on the way down.

The headline is the strictly-beats contract (asserted here, in
tests/test_planner.py, and recorded in the BENCH artifact's ``planner``
section): planned+autoscale must deliver **equal-or-better per-class SLO
attainment at strictly lower node-seconds** than the locality pool, on
BOTH drivers. ``python -m benchmarks.planner`` prints both tables and
exits non-zero if either driver misses it; ``--quick`` shrinks the trace
for the CI smoke job.
"""
from __future__ import annotations

import sys
import time
from typing import Dict, List, Tuple

from benchmarks.common import Row, data_plane_function
from repro.api import FunctionSpec, Gateway
from repro.api.workload import Arrival, DiurnalWorkload
from repro.core.placement import AutoscaleConfig
from repro.core.profiles import MB

DEFAULT_SEED = 11
N_MAX = 8   # the static pool locality gets; the autoscaler's cap (sim)
N_MIN = 2   # autoscaler floor = the planned run's starting pool (sim)

# {function: (base_rate_per_s, deadline_s, priority)} — two SLO classes
# over six functions; the diurnal phases below stagger their peaks
CLASSES: Dict[str, Tuple[float, float, int]] = {
    "fn0": (1.5, 5.0, 2),
    "fn1": (1.5, 5.0, 2),
    "fn2": (1.5, 30.0, 0),
    "fn3": (1.5, 30.0, 0),
    "fn4": (1.5, 30.0, 0),
    "fn5": (1.5, 30.0, 0),
}

AUTOSCALE_SIM = AutoscaleConfig(
    min_nodes=N_MIN, max_nodes=N_MAX, node_rate_per_s=2.5, tick_s=5.0,
    ewma_alpha=0.35, headroom=1.3, up_ticks=1, down_ticks=3)


def _diurnal_arrivals(classes: Dict[str, Tuple[float, float, int]],
                      duration_s: float, period_s: float,
                      seed: int) -> List[Arrival]:
    """Phase-staggered per-function diurnal traces, merged and sorted —
    the hot set rotates as each function's peak comes around."""
    events: List[Arrival] = []
    for i, (fn, (rate, dl, pr)) in enumerate(sorted(classes.items())):
        wl = DiurnalWorkload(fn, rate, duration_s, amplitude=0.9,
                             period_s=period_s, phase_s=i * period_s / 8.0,
                             seed=seed, deadline_s=dl, priority=pr)
        events.extend(wl.events())
    events.sort(key=lambda a: a.t)
    return events


def _slo(t) -> Dict[int, float]:
    return {p: round(c["attainment"], 4)
            for p, c in sorted(t.slo_by_priority().items())}


def _beats(planned: Dict, baseline: Dict) -> bool:
    """The strictly-beats contract: every priority class at least as well
    served, strictly fewer node-seconds."""
    if planned["node_seconds"] >= baseline["node_seconds"]:
        return False
    return all(planned["slo"].get(p, 0.0) >= att
               for p, att in baseline["slo"].items())


# ----------------------------------------------------------------------
# sim driver
# ----------------------------------------------------------------------
def run_sim(planned: bool, quick: bool = False,
            seed: int = DEFAULT_SEED) -> Dict[str, object]:
    duration = 240.0 if quick else 720.0
    period = duration / 2.0
    horizon = duration + 60.0
    kw: Dict[str, object] = dict(backend="sim", policy="sage", seed=seed,
                                 loader_threads=2)
    if planned:
        gw = Gateway(n_nodes=N_MIN, dispatch="planned",
                     autoscale=AUTOSCALE_SIM, **kw)
    else:
        gw = Gateway(n_nodes=N_MAX, dispatch="locality", **kw)
    for fn, (rate, dl, pr) in sorted(CLASSES.items()):
        gw.register(FunctionSpec(
            name=fn, read_only_bytes=96 * MB, writable_bytes=8 * MB,
            context_bytes=64 * MB, compute_ms=20.0,
            deadline_s=dl, priority=pr))
    t = gw.replay(_diurnal_arrivals(CLASSES, duration, period, seed),
                  until=horizon)
    assert t.error_count() == 0, t.errors()[0].error
    ps = gw.placement_stats()
    node_seconds = (ps["node_seconds"] if ps is not None
                    else N_MAX * horizon)
    out: Dict[str, object] = {
        "config": "planned+autoscale" if planned else "locality",
        "arrivals": len(t.snapshot()),
        "slo": _slo(t),
        "node_seconds": round(float(node_seconds), 3),
        "p99_e2e_s": round(t.p99_e2e(), 4),
    }
    if ps is not None:
        out["placement"] = {k: ps[k] for k in (
            "planned_hits", "planned_misses", "hit_rate", "replans",
            "boards", "steals", "scale_ups", "scale_downs")}
        out["node_timeline"] = [(round(at, 1), n)
                                for at, n in ps["node_timeline"]]
    return out


# ----------------------------------------------------------------------
# runtime driver (real threaded cluster, small scale)
# ----------------------------------------------------------------------
def run_runtime(planned: bool, quick: bool = False,
                seed: int = DEFAULT_SEED) -> Dict[str, object]:
    from repro.core.request import Data, DataType, Request
    from repro.core.runtime import ClusterRuntime
    from repro.data.database import Database

    duration = 10.0 if quick else 16.0
    n_max, n_min, ro_mb = 4, 2, 24
    names = [f"fn{i}" for i in range(4)]
    classes = {fn: (2.5, 3.0 if i < 2 else 10.0, 2 if i < 2 else 0)
               for i, fn in enumerate(names)}
    db = Database()
    kw: Dict[str, object] = dict(database=db, loader_threads=2,
                                 serialize_compute=False)
    if planned:
        auto = AutoscaleConfig(
            min_nodes=n_min, max_nodes=n_max, node_rate_per_s=4.0,
            tick_s=0.5, ewma_alpha=0.4, headroom=1.3,
            up_ticks=1, down_ticks=2)
        cluster = ClusterRuntime(n_nodes=n_min, seed=seed,
                                 dispatch="planned", autoscale=auto, **kw)
    else:
        cluster = ClusterRuntime(n_nodes=n_max, seed=seed,
                                 dispatch="locality", **kw)
    cluster.sage_init()
    clk = cluster.nodes[0].clock
    t0 = clk.now()
    for name in names:
        db.put(f"{name}/weights", b"W", size=ro_mb * MB)
        cluster.register_function(
            lambda i, name=name: data_plane_function(name))
    events = _diurnal_arrivals(classes, duration, duration, seed)
    try:
        futs = []
        start = time.monotonic()
        for k, a in enumerate(events):
            lag = start + a.t - time.monotonic()
            if lag > 0:
                time.sleep(lag)
            wkey = f"{a.function}/in/{k}"
            db.put(wkey, b"X", size=2 * MB)
            req = Request(function_name=a.function)
            req.in_data = [
                Data(key=f"{a.function}/weights", size=ro_mb * MB,
                     dtype=DataType.READ_ONLY),
                Data(key=wkey, size=2 * MB, dtype=DataType.WRITABLE),
            ]
            req.deadline_s, req.priority = a.deadline_s, a.priority
            futs.append(cluster.submit(req))
        for f in futs:
            f.result(timeout=120)
        t1 = clk.now()
        tel = cluster.telemetry
        assert tel.error_count() == 0, tel.errors()[0].error
        ps = cluster.placement_stats()
        node_seconds = (ps["node_seconds"] if ps is not None
                        else n_max * (t1 - t0))
        out: Dict[str, object] = {
            "config": "planned+autoscale" if planned else "locality",
            "arrivals": len(tel.snapshot()),
            "slo": _slo(tel),
            "node_seconds": round(float(node_seconds), 3),
            "p99_e2e_s": round(tel.p99_e2e(), 4),
        }
        if ps is not None:
            out["placement"] = {k: ps[k] for k in (
                "planned_hits", "hit_rate", "scale_ups", "scale_downs")}
        return out
    finally:
        cluster.shutdown()


# ----------------------------------------------------------------------
# entry points
# ----------------------------------------------------------------------
def bench_section(quick: bool = False) -> Dict[str, object]:
    """The ``planner`` section of BENCH_*.json: locality pool vs
    planned+autoscale on the sim driver (the runtime driver is covered
    by the CI planner smoke, not the recorded artifact)."""
    baseline = run_sim(False, quick)
    planned = run_sim(True, quick)
    ratio = planned["node_seconds"] / baseline["node_seconds"]
    return {
        "seed": DEFAULT_SEED,
        "locality": baseline,
        "planned": planned,
        "node_seconds_ratio": round(ratio, 4),
        "beats": _beats(planned, baseline),
    }


def run(quick: bool = True):
    """CSV-harness adapter (benchmarks/run.py): one row per config."""
    baseline = run_sim(False, quick)
    planned = run_sim(True, quick)
    for r in (baseline, planned):
        yield Row(f"planner/sim_{r['config']}", 0.0,
                  f"node_seconds={r['node_seconds']};slo={r['slo']};"
                  f"p99={r['p99_e2e_s']}")
    yield Row("planner/sim_node_seconds_ratio",
              planned["node_seconds"] / baseline["node_seconds"] * 100.0,
              f"beats={_beats(planned, baseline)}")


def main(quick: bool = False) -> int:
    ok = True
    for driver, fn in (("sim", run_sim), ("runtime", run_runtime)):
        baseline = fn(False, quick)
        planned = fn(True, quick)
        beats = _beats(planned, baseline)
        ok &= beats
        ratio = planned["node_seconds"] / baseline["node_seconds"]
        print(f"[{driver}] locality node_seconds={baseline['node_seconds']} "
              f"slo={baseline['slo']} | planned "
              f"node_seconds={planned['node_seconds']} slo={planned['slo']} "
              f"ratio={ratio:.2f}x -> {'PASS' if beats else 'FAIL'}")
        if "placement" in planned:
            print(f"  placement: {planned['placement']}")
        if "node_timeline" in planned:
            print(f"  timeline : {planned['node_timeline']}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main(quick="--quick" in sys.argv))
