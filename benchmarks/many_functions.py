"""Paper Fig 14: 30 functions (each profile cloned x3) — sharing shrinks but
SAGE still wins on parallel setup + multi-stage exit."""
from __future__ import annotations

from benchmarks.common import Row
from repro.api import FunctionSpec, Gateway, MAFWorkload
from repro.core.profiles import PROFILES

NAMES30 = [f"{n}{i}" for n in PROFILES for i in (1, 2, 3)]


def _run(system, workload):
    gw = Gateway(backend="sim", policy=system, seed=1,
                 device_capacity=40 << 30)
    for n in NAMES30:  # each profile cloned x3 under distinct names
        gw.register(FunctionSpec.from_profile(n[:-1], name=n))
    gw.replay(workload, until_pad=6000.0)
    return gw


def run(quick: bool = True):
    workload = MAFWorkload(NAMES30, 600.0, seed=5, mean_rpm=20)
    stats = {s: _run(s, workload) for s in ("fixedgsl", "dgsf", "sage")}
    e2e = {s: gw.telemetry.mean_e2e() for s, gw in stats.items()}
    thr = {s: sum(1 for r in gw.telemetry.records if r.end_t <= 600.0) / 600.0
           for s, gw in stats.items()}
    return [
        Row("fig14_30fn_sage_vs_fixedgsl", e2e["sage"] * 1e6,
            f"speedup={e2e['fixedgsl']/e2e['sage']:.1f}x (paper: 211.9x)"),
        Row("fig14_30fn_sage_vs_dgsf", e2e["sage"] * 1e6,
            f"speedup={e2e['dgsf']/e2e['sage']:.1f}x (paper: 5.9x)"),
        Row("fig14_30fn_throughput_vs_dgsf", 1e6 / max(thr["sage"], 1e-9),
            f"ratio={thr['sage']/max(thr['dgsf'],1e-9):.2f}x (paper: 1.19x)"),
    ]


if __name__ == "__main__":
    for r in run():
        r.print()
