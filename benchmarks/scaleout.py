"""Paper Fig 17 (§7.8): 4-node cluster, random dispatch — SAGE's node-level
gains survive cluster scheduling.

Extended (docs/cluster.md): sharing-aware dispatch. The same contended
multi-function trace is replayed under ``dispatch="random"`` and
``dispatch="locality"`` on BOTH backends; locality routes repeat traffic to
the node where the function's read-only data already sits, so it must
strictly beat random on p50 invocation duration AND total ``bytes_loaded``
at a fixed node count (asserted in tests/test_dispatch.py, reported here).
"""
from __future__ import annotations

import time
from typing import Dict

from benchmarks.common import NAMES, Row, data_plane_function, replay
from repro.api import FunctionSpec, Gateway, MAFWorkload, TraceWorkload
from repro.core.profiles import MB


def run_fig17(quick: bool = True):
    # 4x the single-node load over 4 nodes
    workload = MAFWorkload(NAMES, 600.0, seed=7, mean_rpm=100)
    stats = {}
    for system in ("fixedgsl", "dgsf", "sage"):
        gw = replay(system, workload, n_nodes=4, until_pad=6000.0)
        inwin = sum(1 for r in gw.telemetry.records if r.end_t <= 600.0)
        stats[system] = (gw.telemetry.mean_e2e(), inwin / 600.0)
    e2e = {s: v[0] for s, v in stats.items()}
    thr = {s: v[1] for s, v in stats.items()}
    return [
        Row("fig17_4node_sage_vs_fixedgsl", e2e["sage"] * 1e6,
            f"speedup={e2e['fixedgsl']/e2e['sage']:.1f}x (paper: 207.1x)"),
        Row("fig17_4node_sage_vs_dgsf", e2e["sage"] * 1e6,
            f"speedup={e2e['dgsf']/e2e['sage']:.1f}x (paper: 12.5x)"),
        Row("fig17_4node_throughput_vs_fixedgsl", 1e6 / max(thr["sage"], 1e-9),
            f"ratio={thr['sage']/max(thr['fixedgsl'],1e-9):.2f}x (paper: 10.3x)"),
    ]


# ---------------------------------------------------------------------------
# random vs locality dispatch (both backends)
# ---------------------------------------------------------------------------

def _dispatch_trace(n_fns: int, repeats: int, *, gap_s: float = 4.0,
                    stagger_s: float = 0.05) -> TraceWorkload:
    """``repeats`` rounds of all ``n_fns`` functions, rounds close enough
    that warm state survives between them (contended: every round lands the
    whole function set on the loader pools at once)."""
    return TraceWorkload([
        (r * gap_s + i * stagger_s, f"fn{i}")
        for r in range(repeats) for i in range(n_fns)
    ])


def dispatch_comparison_sim(policy: str, *, n_fns: int = 8, repeats: int = 6,
                            n_nodes: int = 4, seed: int = 5) -> Dict[str, float]:
    """Replay the contended multi-function trace on the virtual-time twin
    under ``policy``; returns p50 duration / total db bytes / hit rate."""
    gw = Gateway(backend="sim", policy="sage", n_nodes=n_nodes,
                 dispatch=policy, loader_threads=2, seed=seed)
    for i in range(n_fns):
        gw.register(FunctionSpec(
            name=f"fn{i}", read_only_bytes=96 * MB, writable_bytes=8 * MB,
            context_bytes=64 * MB, compute_ms=20.0))
    tel = gw.replay(_dispatch_trace(n_fns, repeats), until_pad=600.0)
    assert tel.error_count() == 0, tel.errors()[0].error
    return {
        "p50_duration": tel.p50_duration(),
        "bytes_loaded": float(sum(n.bytes_loaded for n in gw.sim.nodes)),
        "hit_rate": tel.dispatch_hit_rate(),
        "n": float(len(tel.records)),
    }


def dispatch_comparison_runtime(policy: str, *, n_fns: int = 6,
                                repeats: int = 5, n_nodes: int = 4,
                                seed: int = 5, ro_mb: int = 24,
                                stagger_s: float = 0.02) -> Dict[str, float]:
    """The same shape on the REAL threaded cluster: synthetic functions
    (no jit compile — the comparison is about the data plane) whose handler
    waits on the daemon-prepared handles, one shared database."""
    from repro.core.request import Data, DataType, Request
    from repro.core.runtime import ClusterRuntime
    from repro.data.database import Database

    db = Database()
    cluster = ClusterRuntime(n_nodes=n_nodes, seed=seed, dispatch=policy,
                             database=db, loader_threads=2,
                             serialize_compute=False)
    cluster.sage_init()
    names = [f"fn{i}" for i in range(n_fns)]
    for name in names:
        db.put(f"{name}/weights", b"W", size=ro_mb * MB)
        cluster.register_function(
            lambda i, name=name: data_plane_function(name))

    try:
        futs = []
        for r in range(repeats):
            for name in names:
                req = Request(function_name=name)
                wkey = f"{name}/in/{r}"
                db.put(wkey, b"X", size=2 * MB)
                req.in_data = [
                    Data(key=f"{name}/weights", size=ro_mb * MB,
                         dtype=DataType.READ_ONLY),
                    Data(key=wkey, size=2 * MB, dtype=DataType.WRITABLE),
                ]
                futs.append(cluster.submit(req))
                # small stagger so residency from the previous submits is
                # visible to the next dispatch decision (open-loop-ish trace)
                time.sleep(stagger_s)
        for f in futs:
            f.result(timeout=120)
        tel = cluster.telemetry
        out = {
            "p50_duration": tel.p50_duration(),
            "bytes_loaded": float(sum(n.daemon.stats["bytes_loaded"]
                                      for n in cluster.nodes)),
            "hit_rate": tel.dispatch_hit_rate(),
            "n": float(len(tel.records)),
        }
        assert tel.error_count() == 0, tel.errors()[0].error
        return out
    finally:
        cluster.shutdown()


def run_dispatch(quick: bool = True):
    rows = []
    for backend, compare in (("sim", dispatch_comparison_sim),
                             ("runtime", dispatch_comparison_runtime)):
        res = {p: compare(p) for p in ("random", "locality")}
        rnd, loc = res["random"], res["locality"]
        rows.append(Row(
            f"dispatch_{backend}_p50_random", rnd["p50_duration"] * 1e6,
            f"hit_rate={rnd['hit_rate']:.2f};n={int(rnd['n'])}"))
        rows.append(Row(
            f"dispatch_{backend}_p50_locality", loc["p50_duration"] * 1e6,
            f"hit_rate={loc['hit_rate']:.2f};"
            f"speedup={rnd['p50_duration']/max(loc['p50_duration'],1e-9):.1f}x"))
        rows.append(Row(
            f"dispatch_{backend}_bytes_saved_pct",
            (1.0 - loc["bytes_loaded"] / max(rnd["bytes_loaded"], 1.0)) * 100.0,
            f"random={rnd['bytes_loaded']/MB:.0f}MB;"
            f"locality={loc['bytes_loaded']/MB:.0f}MB"))
    return rows


def run(quick: bool = True):
    return run_fig17(quick) + run_dispatch(quick)


if __name__ == "__main__":
    for r in run():
        r.print()
