"""Paper Fig 17 (§7.8): 4-node cluster, random dispatch — SAGE's node-level
gains survive cluster scheduling."""
from __future__ import annotations

from benchmarks.common import NAMES, Row, replay
from repro.api import MAFWorkload


def run(quick: bool = True):
    # 4x the single-node load over 4 nodes
    workload = MAFWorkload(NAMES, 600.0, seed=7, mean_rpm=100)
    stats = {}
    for system in ("fixedgsl", "dgsf", "sage"):
        gw = replay(system, workload, n_nodes=4, until_pad=6000.0)
        inwin = sum(1 for r in gw.telemetry.records if r.end_t <= 600.0)
        stats[system] = (gw.telemetry.mean_e2e(), inwin / 600.0)
    e2e = {s: v[0] for s, v in stats.items()}
    thr = {s: v[1] for s, v in stats.items()}
    return [
        Row("fig17_4node_sage_vs_fixedgsl", e2e["sage"] * 1e6,
            f"speedup={e2e['fixedgsl']/e2e['sage']:.1f}x (paper: 207.1x)"),
        Row("fig17_4node_sage_vs_dgsf", e2e["sage"] * 1e6,
            f"speedup={e2e['dgsf']/e2e['sage']:.1f}x (paper: 12.5x)"),
        Row("fig17_4node_throughput_vs_fixedgsl", 1e6 / max(thr["sage"], 1e-9),
            f"ratio={thr['sage']/max(thr['fixedgsl'],1e-9):.2f}x (paper: 10.3x)"),
    ]


if __name__ == "__main__":
    for r in run():
        r.print()
