"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (scaffold contract). Heavy trace
experiments run on the virtual-clock simulator (deterministic); kernel rows
measure the real CPU reference path and derive TPU roofline estimates; the
roofline rows read the dry-run artifacts when present.

Run:  PYTHONPATH=src python -m benchmarks.run [--full]
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale durations (slower)")
    ap.add_argument("--only", help="comma-separated module names")
    args = ap.parse_args()
    quick = not args.full

    from benchmarks import (
        contention, duration_breakdown, end_to_end, kernel_bench,
        many_functions, multistage, preemption, roofline, scaleout,
        sharing_ablation, slo_scheduling, throughput,
    )

    modules = {
        "duration_breakdown": duration_breakdown,  # Fig 2 / Fig 15
        "throughput": throughput,                  # Fig 3 / Fig 13
        "contention": contention,                  # Fig 4
        "end_to_end": end_to_end,                  # Fig 10 / 11 / 12
        "many_functions": many_functions,          # Fig 14
        "multistage": multistage,                  # Table 4
        "sharing_ablation": sharing_ablation,      # Fig 16
        "scaleout": scaleout,                      # Fig 17
        "slo_scheduling": slo_scheduling,          # EDF vs FIFO SLO report
        "preemption": preemption,                  # preemptive transfer vs RTC
        "kernel_bench": kernel_bench,              # Pallas kernel roofs
        "roofline": roofline,                      # §Roofline table
    }
    if args.only:
        keep = set(args.only.split(","))
        modules = {k: v for k, v in modules.items() if k in keep}

    print("name,us_per_call,derived")
    for name, mod in modules.items():
        try:
            for row in mod.run(quick=quick):
                row.print()
        except Exception as e:  # a failing table must not hide the others
            print(f"{name},-1,ERROR:{type(e).__name__}:{e}")


if __name__ == "__main__":
    main()
