"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (scaffold contract). Heavy trace
experiments run on the virtual-clock simulator (deterministic); kernel rows
measure the real CPU reference path and derive TPU roofline estimates; the
roofline rows read the dry-run artifacts when present.

Run:  PYTHONPATH=src python -m benchmarks.run [--full]

``--bench-json`` switches to the recorded perf trajectory instead: it
replays the simulator-scale scenarios (benchmarks/sim_scale.py — the
headline drives >=1M invocations across 64 nodes) plus the chaos
resilience scenario (benchmarks/chaos.py), the planner placement
scenario (benchmarks/planner.py), the gray-failure tail scenario
(benchmarks/tail_tolerance.py), and the shared-compute density
scenario (benchmarks/density.py) and writes ``BENCH_10.json``
(schema: docs/simulator.md). ``--quick`` shrinks the scenario durations
~20x for the CI smoke job; ``--min-events-per-s`` turns the run into an
anti-regression gate.
"""
import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

REPO_ROOT = Path(__file__).resolve().parents[1]


def bench_json_main(args) -> None:
    from benchmarks import chaos, density, planner, sim_scale, tail_tolerance

    doc = sim_scale.bench_json(quick=args.quick)
    # the resilience headline rides next to the perf scenarios: naive vs
    # hardened goodput under the seeded chaos fault trace (sim driver)
    doc["chaos"] = chaos.bench_section(quick=args.quick)
    # the placement headline: planned dispatch + predictive autoscaling
    # must strictly beat the fixed locality pool (docs/planner.md)
    doc["planner"] = planner.bench_section(quick=args.quick)
    # the tail headline: hedging + quarantine must strictly beat the
    # eviction-only config on tight-class p99 under gray faults
    doc["tail"] = tail_tolerance.bench_section(quick=args.quick)
    # the density headline: the shared compute plane (fractional SM
    # slices + same-function batching) must beat the exclusive FIFO by
    # more than the paper's 1.22x with tight-class SLO no worse
    doc["density"] = density.bench_section(quick=args.quick)
    out = Path(args.bench_out) if args.bench_out else (
        REPO_ROOT / f"BENCH_{sim_scale.BENCH_ID}.json")
    out.write_text(json.dumps(doc, indent=2, sort_keys=True) + "\n")
    head = doc["headline"]
    print(f"wrote {out}: {head['invocations']:,} invocations on "
          f"{head['nodes']} nodes in {head['wall_s']:.1f}s "
          f"({head['events_per_s']:,.0f} events/s); chaos goodput ratio "
          f"{doc['chaos']['goodput_ratio']}x; planner node-seconds ratio "
          f"{doc['planner']['node_seconds_ratio']}x; tail tight-p99 ratio "
          f"{doc['tail']['tight_p99_ratio']}x; density ratio "
          f"{doc['density']['density_ratio']}x")
    if doc["chaos"]["goodput_ratio"] < 2.0:
        print("FAIL: hardened config below 2x naive goodput under faults")
        sys.exit(1)
    if not doc["planner"]["beats"]:
        print("FAIL: planned+autoscale did not strictly beat the "
              "locality pool (equal-or-better SLO at lower node-seconds)")
        sys.exit(1)
    if not doc["tail"]["beats"]:
        print("FAIL: hedging+quarantine did not strictly beat the "
              "eviction-only config on tight-class p99 under gray faults")
        sys.exit(1)
    if not doc["density"]["beats"]:
        print("FAIL: shared compute plane did not beat the exclusive "
              f"FIFO by more than {doc['density']['paper_density_x']}x "
              "function density with tight-class SLO no worse")
        sys.exit(1)
    if args.min_events_per_s and head["events_per_s"] < args.min_events_per_s:
        print(f"FAIL: headline events/s {head['events_per_s']:,.0f} below "
              f"floor {args.min_events_per_s:,.0f}")
        sys.exit(1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale durations (slower)")
    ap.add_argument("--only", help="comma-separated module names")
    ap.add_argument("--bench-json", action="store_true",
                    help="replay the sim-scale scenarios and write BENCH_*.json")
    ap.add_argument("--quick", action="store_true",
                    help="with --bench-json: ~20x shorter scenario durations")
    ap.add_argument("--bench-out",
                    help="with --bench-json: output path (default BENCH_10.json)")
    ap.add_argument("--min-events-per-s", type=float, default=0.0,
                    help="with --bench-json: exit 1 if the headline replay "
                         "falls below this events/s floor")
    args = ap.parse_args()
    if args.bench_json:
        bench_json_main(args)
        return
    quick = not args.full

    from benchmarks import (
        chaos, contention, density, duration_breakdown, end_to_end,
        kernel_bench, many_functions, multistage, planner, preemption,
        roofline, scaleout, sharing_ablation, sim_scale, slo_scheduling,
        tail_tolerance, throughput,
    )

    modules = {
        "duration_breakdown": duration_breakdown,  # Fig 2 / Fig 15
        "throughput": throughput,                  # Fig 3 / Fig 13
        "contention": contention,                  # Fig 4
        "end_to_end": end_to_end,                  # Fig 10 / 11 / 12
        "many_functions": many_functions,          # Fig 14
        "multistage": multistage,                  # Table 4
        "sharing_ablation": sharing_ablation,      # Fig 16
        "scaleout": scaleout,                      # Fig 17
        "slo_scheduling": slo_scheduling,          # EDF vs FIFO SLO report
        "preemption": preemption,                  # preemptive transfer vs RTC
        "kernel_bench": kernel_bench,              # Pallas kernel roofs
        "roofline": roofline,                      # §Roofline table
        "sim_scale": sim_scale,                    # kernel replay throughput
        "chaos": chaos,                            # resilience under faults
        "planner": planner,                        # placement vs static pool
        "tail_tolerance": tail_tolerance,          # gray failures / hedging
        "density": density,                        # shared compute plane
    }
    if args.only:
        keep = set(args.only.split(","))
        modules = {k: v for k, v in modules.items() if k in keep}

    print("name,us_per_call,derived")
    for name, mod in modules.items():
        try:
            for row in mod.run(quick=quick):
                row.print()
        except Exception as e:  # a failing table must not hide the others
            print(f"{name},-1,ERROR:{type(e).__name__}:{e}")


if __name__ == "__main__":
    main()
