"""Shared benchmark infrastructure — everything drives load through the
unified serving API (``repro.api``): FunctionSpec registration, Workload
traces, Gateway replay. Mechanism-level state (brokers, node memory) is
reached through ``gateway.sim`` when a table needs it."""
from __future__ import annotations

import sys
from pathlib import Path
from typing import Optional

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.api import FunctionSpec, Gateway, Workload  # noqa: E402
from repro.core.profiles import PROFILES  # noqa: E402

NAMES = list(PROFILES)


def make_gateway(system: str, *, n_nodes: int = 1, seed: int = 1,
                 **kw) -> Gateway:
    """A sim-backed gateway with all ten paper-profile functions."""
    gw = Gateway(backend="sim", policy=system, n_nodes=n_nodes, seed=seed, **kw)
    for n in NAMES:
        gw.register(FunctionSpec.from_profile(n))
    return gw


def replay(system: str, workload: Workload, *, n_nodes: int = 1,
           until: Optional[float] = None, until_pad: float = 1800.0,
           **kw) -> Gateway:
    """Replay ``workload`` on a fresh gateway; returns the gateway so
    callers can read telemetry and memory traces."""
    gw = make_gateway(system, n_nodes=n_nodes, **kw)
    gw.replay(workload, until=until, until_pad=until_pad)
    return gw


def data_plane_function(name: str, *, wait_s: float = 30.0,
                        context_bytes: int = 1 << 20):
    """Synthetic ``GPUFunction`` whose handler only waits on the
    daemon-prepared handles — for runtime-backend benchmarks where the
    comparison is the data plane, not compute (no jit compile)."""
    from repro.core.engine import GPUFunction

    def handler(shim, request):
        for dd in request.in_data:
            shim.sage_load_to_gpu(dd.key).wait(wait_s)

    return GPUFunction(name=name, handler=handler,
                       context_builder=lambda: object(),
                       context_bytes=context_bytes, container_s=0.0,
                       cpu_ctx_s=0.0)


class Row:
    """One CSV row: name,us_per_call,derived."""

    def __init__(self, name: str, us_per_call: float, derived: str = ""):
        self.name = name
        self.us = us_per_call
        self.derived = derived

    def print(self):
        print(f"{self.name},{self.us:.1f},{self.derived}")
