"""Shared benchmark infrastructure."""
from __future__ import annotations

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core.profiles import PROFILES  # noqa: E402
from repro.core.simulator import SimFunction, Simulator, maf_like_trace  # noqa: E402

NAMES = list(PROFILES)


def make_sim(system: str, *, n_nodes: int = 1, seed: int = 1, **kw) -> Simulator:
    sim = Simulator(system, n_nodes=n_nodes, seed=seed, **kw)
    for n in NAMES:
        sim.register(SimFunction(PROFILES[n]))
    return sim


def replay(system: str, trace, *, n_nodes: int = 1, until_pad: float = 1800.0,
           **kw) -> Simulator:
    sim = make_sim(system, n_nodes=n_nodes, **kw)
    for t, f in trace:
        sim.submit(f, t)
    sim.run(until=(trace[-1][0] if trace else 0.0) + until_pad)
    return sim


class Row:
    """One CSV row: name,us_per_call,derived."""

    def __init__(self, name: str, us_per_call: float, derived: str = ""):
        self.name = name
        self.us = us_per_call
        self.derived = derived

    def print(self):
        print(f"{self.name},{self.us:.1f},{self.derived}")
