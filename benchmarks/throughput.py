"""Paper Fig 3 / Fig 13: peak system throughput vs theoretical
(Throughput_theo = T_period / T_comp), per function, open-loop Poisson load
ramped until the system can no longer drain the queue."""
from __future__ import annotations

from benchmarks.common import NAMES, Row, make_gateway
from repro.api import PoissonWorkload
from repro.core.profiles import PROFILES

DURATION = 120.0


def _stable_throughput(system: str, name: str, rate: float, seed: int = 0) -> float:
    """Offered Poisson ``rate``; returns completed/s if stable else -1."""
    gw = make_gateway(system, seed=seed)
    wl = PoissonWorkload(name, rate, DURATION, seed=seed)
    # hard cutoff: only what's done inside the window counts
    tel = gw.replay(wl, until=DURATION)
    done_in_window = sum(1 for r in tel.records if r.end_t <= DURATION)
    thr = done_in_window / DURATION
    stable = done_in_window >= 0.95 * len(wl)
    return thr if stable else -thr


def peak_ratio(system: str, name: str) -> float:
    """Ramp the load geometrically; return peak stable throughput / theo."""
    theo = 1.0 / PROFILES[name].compute_ms * 1e3  # 1 / T_comp
    best = 0.0
    rate = max(theo / 64.0, 0.2)
    while rate <= theo * 1.2:
        thr = _stable_throughput(system, name, rate)
        if thr < 0:
            break
        best = max(best, thr)
        rate *= 1.6
    return best / theo


def run(quick: bool = True):
    rows = []
    names = NAMES if not quick else NAMES[::2]  # every other fn in quick mode
    for system, paper in (("fixedgsl", "0.123"), ("sage", "0.651")):
        ratios = {n: peak_ratio(system, n) for n in names}
        mean = sum(ratios.values()) / len(ratios)
        rows.append(Row(
            f"fig{'3' if system == 'fixedgsl' else '13'}_{system}_peak_vs_theo",
            mean * 1e6,  # ratio scaled for the CSV column
            f"mean_ratio={mean:.3f} (paper: {paper}) "
            + " ".join(f"{n}={v:.2f}" for n, v in ratios.items()),
        ))
    return rows


if __name__ == "__main__":
    for r in run(quick=False):
        r.print()
