"""Chaos benchmark: one seeded fault trace, two resilience configs,
both drivers (docs/resilience.md).

A :func:`chaos_plan` schedule — three node crashes (one restarting), a
db-bandwidth brownout, a short db flap, and a 50%-poisoned loader for the
``flaky`` function — is replayed against a mixed-priority
:class:`~repro.api.workload.ChaosWorkload` twice per driver:

* **naive**: faults on, control layer off (`eviction`/`breaker`/
  `shedding` all default) — dispatch keeps feeding dead nodes and every
  in-flight invocation on a crashed node is a hard loss;
* **hardened**: eviction drains crashed nodes, crash-lost invocations
  re-dispatch within their retry budget, the ``flaky`` breaker cuts
  doomed loads, and watermark shedding sacrifices the loose class first.

The headline is the goodput ratio: the hardened config must hold >= 2x
the naive goodput on BOTH drivers, with the *identical* fault schedule
from the same seed (tests/test_faults.py and the CI chaos smoke assert
this). ``python -m benchmarks.chaos`` prints both tables and exits
non-zero if the ratio or the zero-leak accounting check fails.
"""
from __future__ import annotations

import sys
from typing import Dict, Optional, Tuple

from repro.api.gateway import Gateway
from repro.api.spec import FunctionSpec
from repro.api.workload import ChaosWorkload
from repro.core.faults import (
    BreakerConfig,
    DbFlap,
    FaultPlan,
    LinkDegradation,
    LoaderFault,
    NodeCrash,
    SheddingConfig,
)
from repro.core.profiles import FunctionProfile
from repro.core.simulator import SimFunction, Simulator

DEFAULT_SEED = 29
N_NODES = 4

# hardened-config control knobs (docs/resilience.md has the reference)
BREAKER = BreakerConfig(failure_threshold=0.5, window=16, min_requests=8,
                        cooldown_s=5.0, half_open_probes=2)
SHEDDING = SheddingConfig(watermark=0.75, hard_watermark=0.97,
                          loose_priority_max=0, saturation=8.0)

# {function: (rate_per_s, deadline_s, priority)} — the tight class is what
# the control layer protects; flaky carries no deadline so its poisoned
# loads burn capacity without moving goodput directly
CLASSES: Dict[str, Tuple[float, Optional[float], int]] = {
    "tight": (6.0, 3.0, 2),
    "loose": (6.0, 20.0, 0),
    "flaky": (1.0, None, 0),
}


def chaos_plan(duration_s: float, seed: int = DEFAULT_SEED) -> FaultPlan:
    """The seeded fault schedule, scaled to the workload duration: 3 of 4
    nodes crash early (gpu1 rejoins near the end), the db link browns out
    mid-window, gpu0's db flaps briefly at warmup, and the ``flaky``
    function's db leg fails half the time."""
    d = duration_s
    return FaultPlan([
        NodeCrash("gpu1", at_s=0.08 * d, restart_after_s=0.87 * d),
        NodeCrash("gpu2", at_s=0.10 * d),
        NodeCrash("gpu3", at_s=0.12 * d),
        LoaderFault("flaky", probability=0.5),
        LinkDegradation(at_s=0.30 * d, duration_s=0.20 * d, factor=0.5,
                        link="db"),
        DbFlap(at_s=0.02 * d, duration_s=0.02 * d, node="gpu0"),
    ], seed=seed)


def _workload(duration_s: float, seed: int = DEFAULT_SEED) -> ChaosWorkload:
    return ChaosWorkload(CLASSES, duration_s, seed=seed)


def _summary(t, stats) -> Dict[str, object]:
    recs = [r for r in t.snapshot() if not r.dropped]
    return {
        "arrivals": len(recs),
        "completed": sum(1 for r in recs if r.error is None),
        "goodput": round(1.0 - t.slo_miss_rate(), 4),
        "error_counts": t.error_counts(),
        "slo_by_priority": {p: round(c["attainment"], 4)
                            for p, c in sorted(t.slo_by_priority().items())},
        "resilience": stats,
    }


# ----------------------------------------------------------------------
# drivers
# ----------------------------------------------------------------------
def run_sim(hardened: bool, quick: bool = False,
            seed: int = DEFAULT_SEED) -> Dict[str, object]:
    duration = 40.0 if quick else 120.0
    kw: Dict[str, object] = {"faults": chaos_plan(duration, seed)}
    if hardened:
        kw.update(eviction=True, breaker=BREAKER, shedding=SHEDDING)
    sim = Simulator("sage", n_nodes=N_NODES, seed=seed, **kw)
    for name, (_, _, _) in sorted(CLASSES.items()):
        sim.register(SimFunction(FunctionProfile(
            name, "chaos", context_mb=414.0, read_only_mb=96.0,
            writable_mb=8.0, compute_ms=15.0)))
    for i, a in enumerate(_workload(duration, seed).events()):
        sim.submit(a.function, a.t, deadline_s=a.deadline_s,
                   priority=a.priority, request_id=f"c{i}-{a.function}")
    sim.run(duration + 120.0)
    out = _summary(sim.telemetry, sim.resilience_stats())
    # accounting must be exact after every crash/evict/redispatch
    for n in sim.nodes:
        assert 0 <= n.used <= n.capacity and n.host_used >= 0, (
            f"{n.name}: used={n.used} host_used={n.host_used}")
        assert n.inflight_loads == 0, f"{n.name} leaked loader slots"
    return out


def run_runtime(hardened: bool, quick: bool = False,
                seed: int = DEFAULT_SEED) -> Dict[str, object]:
    duration = 5.0 if quick else 8.0
    kw: Dict[str, object] = {"faults": chaos_plan(duration, seed)}
    if hardened:
        kw.update(eviction=True, breaker=BREAKER, shedding=SHEDDING)
    gw = Gateway(backend="runtime", n_nodes=N_NODES, seed=seed, **kw)
    try:
        for name in sorted(CLASSES):
            gw.register(FunctionSpec(
                name=name, read_only_bytes=24 << 20, writable_bytes=4 << 20,
                context_bytes=16 << 20, compute_ms=10.0))
        # rates scale up as the window scales down: same arrival count
        # intent as the sim scenario, wall-clock kept benchmark-friendly
        scale = 120.0 / duration / 10.0
        classes = {f: (r * scale, dl, pr)
                   for f, (r, dl, pr) in CLASSES.items()}
        wl = ChaosWorkload(classes, duration, seed=seed)
        t = gw.replay(wl, pace=1.0, timeout=120.0)
        out = _summary(t, gw.resilience_stats())
        for n in gw._nodes:
            mu = n.memory_usage()
            assert all(v >= 0 for v in mu.values()), f"{n.node_id}: {mu}"
            if not n.healthy:  # a dead node holds nothing
                assert mu["device_used"] == 0 and mu["host_used"] == 0, (
                    f"{n.node_id} leaked accounting after crash: {mu}")
        return out
    finally:
        gw.shutdown()


# ----------------------------------------------------------------------
# entry points
# ----------------------------------------------------------------------
def bench_section(quick: bool = False) -> Dict[str, object]:
    """The ``chaos`` section of BENCH_*.json: the sim driver's naive vs
    hardened goodput under the seeded fault trace (the runtime driver is
    covered by the CI chaos smoke, not the recorded perf artifact)."""
    naive = run_sim(False, quick)
    hardened = run_sim(True, quick)
    ratio = (hardened["goodput"] / naive["goodput"]
             if naive["goodput"] else float("inf"))
    return {
        "seed": DEFAULT_SEED,
        "naive": naive,
        "hardened": hardened,
        "goodput_ratio": round(ratio, 3),
    }


def run(quick: bool = True):
    """CSV-harness adapter (benchmarks/run.py): one row per config."""
    from benchmarks.common import Row

    for label, hardened in (("naive", False), ("hardened", True)):
        r = run_sim(hardened, quick)
        yield Row(f"chaos/sim_{label}", 0.0,
                  f"goodput={r['goodput']};completed={r['completed']};"
                  f"errors={sum(r['error_counts'].values())}")


def main(quick: bool = False) -> int:
    ok = True
    for driver, fn in (("sim", run_sim), ("runtime", run_runtime)):
        naive = fn(False, quick)
        hardened = fn(True, quick)
        ratio = (hardened["goodput"] / naive["goodput"]
                 if naive["goodput"] else float("inf"))
        status = "PASS" if ratio >= 2.0 else "FAIL"
        ok &= ratio >= 2.0
        print(f"[{driver}] naive goodput={naive['goodput']} "
              f"hardened goodput={hardened['goodput']} ratio={ratio:.2f}x "
              f"-> {status}")
        print(f"  naive    : {naive['error_counts']} "
              f"{naive['resilience']}")
        print(f"  hardened : {hardened['error_counts']} "
              f"{hardened['resilience']}")
        print(f"  hardened per-priority SLO attainment: "
              f"{hardened['slo_by_priority']}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main(quick="--quick" in sys.argv))
