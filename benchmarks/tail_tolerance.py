"""Tail-tolerance benchmark: gray failures, hedging & quarantine
(docs/resilience.md, "Gray failures").

One seeded gray-fault trace — a :class:`SlowNode` dragging every stage of
one node, heavy-tailed :class:`LoaderJitter` on the tight class, and a
:class:`MemoryLeak` creeping up a second node — is replayed against a
mixed tight/loose workload twice per driver:

* **baseline**: eviction on (the PR-7 hardened config) but no
  tail-tolerance — dispatch keeps feeding the slow-but-alive node and the
  tight class's p99 rides the straggler;
* **tail-tolerant**: the same config plus ``hedging=True`` and
  ``quarantine=True`` — straggling invocations launch one speculative
  twin on the best non-suspect node (first completion wins, the loser is
  cancelled byte-exactly), and the sustained suspect is drained, probed
  with canaries, and readmitted or retired.

The headline is the tight-class p99: the tail-tolerant config must
STRICTLY beat the baseline on BOTH drivers with the identical fault
schedule from the same seed. ``python -m benchmarks.tail_tolerance``
prints both tables and exits non-zero if the gate or the zero-leak
accounting check fails.
"""
from __future__ import annotations

import sys
from typing import Dict, Optional, Tuple

from repro.api.gateway import Gateway
from repro.api.spec import FunctionSpec
from repro.api.workload import ChaosWorkload
from repro.core.faults import FaultPlan, LoaderJitter, MemoryLeak, SlowNode
from repro.core.profiles import FunctionProfile
from repro.core.simulator import SimFunction, Simulator

DEFAULT_SEED = 31
N_NODES = 3

# {function: (rate_per_s, deadline_s, priority)} — the tight class is the
# one the tail-tolerance layer protects; loose rides along to keep the
# fleet median honest (a one-class trace would let the straggler drag
# the baseline it is judged against)
CLASSES: Dict[str, Tuple[float, Optional[float], int]] = {
    "tight": (6.0, 0.5, 2),
    "loose": (4.0, 5.0, 0),
}


def tail_plan(duration_s: float, factor: float,
              seed: int = DEFAULT_SEED) -> FaultPlan:
    """The seeded gray-fault schedule, scaled to the workload duration:
    gpu1 turns gray-slow early and stays slow, the tight class's loads
    pick up a Pareto-tailed jitter mid-window, and gpu2 leaks device
    memory over a bounded window (reclaimed at leak_off — the accounting
    asserts below check the books balance)."""
    d = duration_s
    return FaultPlan([
        SlowNode("gpu1", at_s=0.15 * d, factor=factor),
        LoaderJitter("tight", scale_s=0.05, alpha=1.5,
                     start_s=0.40 * d, end_s=0.70 * d),
        MemoryLeak("gpu2", at_s=0.30 * d, rate_bps=2 << 20,
                   duration_s=0.25 * d),
    ], seed=seed)


def _summary(t, stats) -> Dict[str, object]:
    recs = [r for r in t.snapshot() if not r.dropped]
    hedged = [r for r in t.snapshot()
              if r.dropped and r.error_class == "hedged"]
    return {
        "arrivals": len(recs),
        "completed": sum(1 for r in recs if r.error is None),
        "tight_p99": round(t.p99_duration("tight"), 4),
        "loose_p99": round(t.p99_duration("loose"), 4),
        "hedged_drops": len(hedged),
        "resilience": {k: v for k, v in stats.items()
                       if k in ("hedges_launched", "hedges_won",
                                "hedges_wasted", "quarantines", "readmits",
                                "redispatches")},
    }


# ----------------------------------------------------------------------
# drivers
# ----------------------------------------------------------------------
def run_sim(tolerant: bool, quick: bool = False,
            seed: int = DEFAULT_SEED) -> Dict[str, object]:
    duration = 40.0 if quick else 120.0
    kw: Dict[str, object] = {"faults": tail_plan(duration, 10.0, seed),
                             "eviction": True, "dispatch": "random"}
    if tolerant:
        kw.update(hedging=True, quarantine=True)
    sim = Simulator("sage", n_nodes=N_NODES, seed=seed, **kw)
    for name in sorted(CLASSES):
        sim.register(SimFunction(FunctionProfile(
            name, "tail", context_mb=64.0, read_only_mb=24.0,
            writable_mb=4.0, compute_ms=15.0)))
    wl = ChaosWorkload(CLASSES, duration, seed=seed)
    for i, a in enumerate(wl.events()):
        sim.submit(a.function, a.t, deadline_s=a.deadline_s,
                   priority=a.priority, request_id=f"t{i}-{a.function}")
    sim.run(duration + 120.0)
    out = _summary(sim.telemetry, sim.resilience_stats())
    # accounting must be exact after every hedge cancel/quarantine drain
    for n in sim.nodes:
        assert 0 <= n.used <= n.capacity and n.host_used >= 0, (
            f"{n.name}: used={n.used} host_used={n.host_used}")
        assert n.inflight_loads == 0, f"{n.name} leaked loader slots"
    return out


def run_runtime(tolerant: bool, quick: bool = False,
                seed: int = DEFAULT_SEED) -> Dict[str, object]:
    duration = 8.0 if quick else 15.0
    # the threaded runtime serves invocations concurrently (no queueing
    # on a slow node), so the straggler needs a harder factor than the
    # sim's to dominate the tail the same way
    kw: Dict[str, object] = {"faults": tail_plan(duration, 30.0, seed),
                             "eviction": True, "dispatch": "random"}
    if tolerant:
        # eager hedge thresholds: the wall-clock window is short, so the
        # estimate must arm before quarantine already drained the suspect
        kw.update(hedging=dict(min_samples=6, hedge_quantile=0.9),
                  quarantine=True)
    gw = Gateway(backend="runtime", policy="sage", n_nodes=N_NODES,
                 seed=seed, **kw)
    try:
        for name in sorted(CLASSES):
            gw.register(FunctionSpec(
                name=name, read_only_bytes=24 << 20, writable_bytes=4 << 20,
                context_bytes=16 << 20, compute_ms=10.0))
        # rates scale up as the window scales down: same arrival-count
        # intent as the sim scenario, wall-clock kept benchmark-friendly
        scale = 120.0 / duration / 4.0
        classes = {f: (r * scale, dl, pr)
                   for f, (r, dl, pr) in CLASSES.items()}
        wl = ChaosWorkload(classes, duration, seed=seed)
        t = gw.replay(wl, pace=1.0, timeout=120.0)
        out = _summary(t, gw.resilience_stats())
        for n in gw._nodes:
            mu = n.memory_usage()
            assert all(v >= 0 for v in mu.values()), f"{n.node_id}: {mu}"
            assert n.daemon.leaked_bytes == 0, (
                f"{n.node_id} kept {n.daemon.leaked_bytes} leaked bytes "
                "after leak_off reclaim")
        return out
    finally:
        gw.shutdown()


# ----------------------------------------------------------------------
# entry points
# ----------------------------------------------------------------------
def bench_section(quick: bool = False) -> Dict[str, object]:
    """The ``tail`` section of BENCH_*.json: the sim driver's baseline vs
    tail-tolerant tight-class p99 under the seeded gray-fault trace (the
    runtime driver is covered by the CI tail smoke, not the artifact)."""
    baseline = run_sim(False, quick)
    tolerant = run_sim(True, quick)
    ratio = (baseline["tight_p99"] / tolerant["tight_p99"]
             if tolerant["tight_p99"] else float("inf"))
    return {
        "seed": DEFAULT_SEED,
        "baseline": baseline,
        "tolerant": tolerant,
        "tight_p99_ratio": round(ratio, 3),
        "beats": tolerant["tight_p99"] < baseline["tight_p99"],
    }


def run(quick: bool = True):
    """CSV-harness adapter (benchmarks/run.py): one row per config."""
    from benchmarks.common import Row

    for label, tolerant in (("baseline", False), ("tolerant", True)):
        r = run_sim(tolerant, quick)
        res = r["resilience"]
        yield Row(f"tail/sim_{label}", 0.0,
                  f"tight_p99={r['tight_p99']};completed={r['completed']};"
                  f"hedges={res['hedges_launched']};"
                  f"quarantines={res['quarantines']}")


def main(quick: bool = False) -> int:
    ok = True
    for driver, fn in (("sim", run_sim), ("runtime", run_runtime)):
        baseline = fn(False, quick)
        tolerant = fn(True, quick)
        beats = tolerant["tight_p99"] < baseline["tight_p99"]
        launched = tolerant["resilience"]["hedges_launched"]
        status = "PASS" if beats and launched > 0 else "FAIL"
        ok &= beats and launched > 0
        print(f"[{driver}] baseline tight p99={baseline['tight_p99']}s "
              f"tolerant tight p99={tolerant['tight_p99']}s -> {status}")
        print(f"  baseline : {baseline['resilience']}")
        print(f"  tolerant : {tolerant['resilience']} "
              f"hedged_drops={tolerant['hedged_drops']}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main(quick="--quick" in sys.argv))
