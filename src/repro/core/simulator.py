"""Virtual-time discrete-event twin of the runtime, for trace-scale
experiments (Figs 3, 10-14, 16, 17).

Runs the SAME policy decisions (SystemPolicy flags, ExitLadder stages,
read-only sharing, slot accounting, FCFS context pools) as the threaded
runtime, but with modeled durations (paper Table 2/4 profiles + fair-share
brokers) under a VirtualClock — two hours of MAF trace replay complete in
milliseconds, deterministically.

Modeling choices (documented in DESIGN.md §2):
* GPU compute is FIFO (one kernel at a time) — consistent with the paper's
  Throughput_theo = T_period / T_comp definition;
* gpu_ctx creation = 285.1 ms (Table 4) and does not contend (paper §6.1:
  'context creation for function invocations does not interfere');
* db / PCIe paths are progressive-filling fair-share links (Fig 4's 34.9x
  contention emerges from these, not from a hard-coded factor).
"""
from __future__ import annotations

import heapq
import itertools
import math
import random
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.baselines import SystemPolicy, get_system
from repro.core.clock import VirtualClock
from repro.core.daemon import SCHEDULERS, AdmissionKey
from repro.core.dispatch import DISPATCH_POLICIES, NodeSnapshot, choose_node
from repro.core.datapath import DB_BANDWIDTH, PCIE_BANDWIDTH, BandwidthBroker
from repro.core.exit_policy import ExitLadder
from repro.core.profiles import MB, PROFILES, FunctionProfile
from repro.core.telemetry import STAGES, InvocationRecord, Telemetry
from repro.core.transfer import (
    DEFAULT_CHUNK_BYTES, TRANSFER_MODES, LinkArbiter,
)

GPU_CTX_S = 0.2851
CPU_CTX_S = 0.001
RETURN_S = 0.0001
CONTAINER_S = 2.0


@dataclass
class SimFunction:
    profile: FunctionProfile
    name: str = ""

    def __post_init__(self):
        self.name = self.name or self.profile.name

    @property
    def ro_bytes(self) -> int:
        return int(self.profile.read_only_mb * MB)

    @property
    def w_bytes(self) -> int:
        return int(self.profile.writable_mb * MB)

    @property
    def ctx_bytes(self) -> int:
        return int(self.profile.context_mb * MB)

    @property
    def compute_s(self) -> float:
        return self.profile.compute_ms / 1e3

    def slot_bytes(self, granularity: int) -> int:
        need = self.ctx_bytes + self.ro_bytes + self.w_bytes
        if granularity:
            need = ((need + granularity - 1) // granularity) * granularity
        return need


@dataclass
class SimInstance:
    fn: SimFunction
    ladder: ExitLadder = field(default_factory=ExitLadder)
    busy: bool = False
    dead: bool = False
    has_ctx: bool = False
    ctx_building: bool = False
    # (on_ready, on_fail) pairs: failure of the building invocation's ctx
    # reservation propagates to everyone latched onto it
    ctx_waiters: List[Tuple[Callable, Callable]] = field(default_factory=list)
    has_ro_device: bool = False
    has_ro_host: bool = False
    slot: int = 0


class _PendingReservation:
    """One queued device-memory reservation (may carry a failure deadline).
    ``key`` is the :data:`~repro.core.daemon.AdmissionKey` that orders the
    pending heap — the twin of the threaded daemon's waiter heap."""

    __slots__ = ("nbytes", "cont", "on_fail", "expired", "granted", "key",
                 "attempts", "max_retries")

    def __init__(self, nbytes: int, cont: Callable, on_fail: Optional[Callable],
                 key: AdmissionKey, max_retries: Optional[int] = None):
        self.nbytes = nbytes
        self.cont = cont
        self.on_fail = on_fail
        self.expired = False
        self.granted = False
        self.key = key
        # per-request OOM retry budget (twin of the daemon's): the failed
        # reserve() attempt that queued us counts as attempt #1; each failed
        # head admission in kick() is one retry
        self.attempts = 1
        self.max_retries = max_retries


class GPUNode:
    """One simulated GPU node (device memory + compute FIFO + data paths).

    Mirrors the threaded daemon's data-plane contract (docs/dataplane.md):
    loads run through a **bounded loader gate** (``loader_threads`` concurrent
    db->PCIe streams, high-water mark in ``max_inflight_loads``), and memory
    reservations given a deadline *fail* past ``load_timeout_s`` instead of
    queueing forever — the failed invocation's record carries ``error``."""

    def __init__(self, policy: SystemPolicy, clock: VirtualClock, *,
                 capacity: int = 40 << 30, host_capacity: int = 125 << 30,
                 exit_ttl: float = 30.0, name: str = "gpu0",
                 loader_threads: int = 4, load_timeout_s: float = 600.0,
                 scheduler: str = "fifo",
                 transfer: str = "run_to_completion",
                 chunk_bytes: int = DEFAULT_CHUNK_BYTES):
        if scheduler not in SCHEDULERS:
            raise ValueError(f"unknown scheduler {scheduler!r}; use one of {SCHEDULERS}")
        if transfer not in TRANSFER_MODES:
            raise ValueError(
                f"unknown transfer mode {transfer!r}; use one of {TRANSFER_MODES}")
        self.policy = policy
        self.clock = clock
        self.capacity = capacity
        self.host_capacity = host_capacity
        self.exit_ttl = exit_ttl
        self.name = name
        self.scheduler = scheduler
        self.used = 0
        # host-tier accounting (twin of the daemon's host admission): bytes
        # resident on host, plus which function's shared-RO host copy is
        # evictable (the refcount-0 HOST entries of the threaded daemon)
        self.host_used = 0
        self.host_resident: Dict[str, int] = {}
        self.host_touch: Dict[str, float] = {}  # last use, for LRU eviction
        self.host_evictions = 0
        self.db = BandwidthBroker(DB_BANDWIDTH, clock, "db", concurrency_penalty=0.06)
        self.pcie = BandwidthBroker(PCIE_BANDWIDTH, clock, "pcie")
        self.compute_free_at = 0.0
        self.instances: Dict[str, List[SimInstance]] = {}
        # SAGE shared read-only state per function: tier + waiters
        self.ro_state: Dict[str, str] = {}  # function -> none|loading|device|host
        self.ro_ready_cbs: Dict[str, List[Tuple[Callable, Callable]]] = {}
        self.dgsf_free: Dict[str, int] = {}
        self.dgsf_queue: Dict[str, List[Callable]] = {}
        self.mem_samples: List[Tuple[float, int]] = []
        # pending device reservations, heap-ordered by AdmissionKey (the
        # twin of the daemon's ordered waiter heap)
        self.pending_mem: List[Tuple[AdmissionKey, _PendingReservation]] = []
        # bounded loader gate (twin of daemon.LoaderPool). Only SAGE has the
        # unified memory daemon; baseline platforms (FixedGSL/DGSF) load in
        # per-invocation containers with no shared pool — gating them would
        # cap the very db-path contention Fig 4 measures (paper: 34.9x).
        self.daemon_pooled = policy.name.startswith("sage")
        self.loader_threads = max(1, int(loader_threads))
        self.load_timeout_s = load_timeout_s
        self.inflight_loads = 0
        self.max_inflight_loads = 0
        self._loader_queue: List[Tuple[AdmissionKey, Callable]] = []
        self._key_seq = itertools.count()
        # link arbiter (twin of the daemon's): demand = the tightest job
        # waiting on the loader gate; only the gated (SAGE) path ever
        # yields, exactly like the threaded pool (docs/dataplane.md)
        self.arbiter = LinkArbiter(
            transfer, chunk_bytes,
            demand=lambda: self._loader_queue[0][0] if self._loader_queue
            else None)
        self.load_failures = 0
        # data actually delivered over the db path (twin of the daemon's
        # stats["loads"]/["bytes_loaded"]: counted on completion, host
        # promotions not re-counted — they never touch the db leg)
        self.loads = 0
        self.bytes_loaded = 0

    # ------------------------------------------------------------------
    # SLO-aware admission keys (same formula as daemon._admission_key)
    # ------------------------------------------------------------------
    def admission_key(self, rec: Optional[InvocationRecord] = None) -> AdmissionKey:
        seq = next(self._key_seq)
        if self.scheduler == "edf" and rec is not None:
            dl = (math.inf if rec.deadline_s is None
                  else rec.arrival_t + rec.deadline_s)
            return (-rec.priority, dl, seq)
        return (0, 0.0, seq)  # fifo: pure arrival order

    # ------------------------------------------------------------------
    # dispatch snapshot (twin of MemoryDaemon.residency/pressure)
    # ------------------------------------------------------------------
    def residency(self, function: str) -> Tuple[str, int]:
        """(best tier, resident bytes) of ``function``'s shared read-only
        data — "device" > "loading" (an in-flight load new arrivals latch
        onto) > "host" > "none", same ranking as the threaded daemon's."""
        st = self.ro_state.get(function, "none")
        if st not in ("device", "loading", "host"):
            return "none", 0
        nbytes = next(
            (i.fn.ro_bytes for i in self.instances.get(function, [])
             if not i.dead),
            self.host_resident.get(function, 0),
        )
        return st, nbytes

    def pressure(self) -> Dict[str, int]:
        pending = sum(1 for _, p in self.pending_mem
                      if not p.expired and not p.granted)
        return {
            "device_free": max(self.capacity - self.used, 0),
            "device_capacity": self.capacity,
            "pending_admissions": pending,
            "loader_queue": (len(self._loader_queue) + self.inflight_loads
                             if self.daemon_pooled else 0),
            "loader_threads": self.loader_threads,
        }

    def dispatch_snapshot(self, function: str) -> NodeSnapshot:
        tier, ro_bytes = self.residency(function)
        return NodeSnapshot(node_id=self.name, ro_tier=tier,
                            ro_bytes=ro_bytes, **self.pressure())

    # ------------------------------------------------------------------
    # loader gate
    # ------------------------------------------------------------------
    def acquire_loader(self, start: Callable,
                       key: Optional[AdmissionKey] = None) -> None:
        """Run ``start`` when a loader slot frees up (AdmissionKey order
        past the bound — arrival order under "fifo", tightest slack first
        under "edf")."""
        if self.inflight_loads < self.loader_threads:
            self.inflight_loads += 1
            self.max_inflight_loads = max(self.max_inflight_loads, self.inflight_loads)
            start()
        else:
            heapq.heappush(self._loader_queue, (key or self.admission_key(), start))

    def release_loader(self) -> None:
        self.inflight_loads -= 1
        if self._loader_queue:
            _, nxt = heapq.heappop(self._loader_queue)
            self.inflight_loads += 1
            self.max_inflight_loads = max(self.max_inflight_loads, self.inflight_loads)
            nxt()

    def _drive(self, st, key: AdmissionKey, phase_done: Callable) -> None:
        """Advance ``st`` chunk by chunk (one full-size advance under
        ``run_to_completion``). Between chunks, if a strictly tighter
        ``(priority, deadline)`` class waits on the loader gate, the stream
        pauses (completed bytes kept), its continuation re-queues under its
        own key, and the freed slot goes to the queue head — identical
        yield semantics to the threaded daemon's ``_drive_stream``."""

        def step():
            if st.done or st.cancelled:
                phase_done()
                return
            if self.daemon_pooled and self.arbiter.should_yield(key):
                st.pause(self.clock.now())
                self.arbiter.note_preemption()

                def resume():
                    st.resume(self.clock.now())
                    step()

                # fresh seq: behind the tighter head, ahead of looser work
                resume_key = (key[0], key[1], next(self._key_seq))
                heapq.heappush(self._loader_queue, (resume_key, resume))
                self.release_loader()
                return
            # ungated (baseline) loads can never yield — the demand signal
            # is the loader gate they do not use — so chunking them would
            # only add events; advance full-size instead
            st.sim_advance(self.arbiter.chunk_hint()
                           if self.daemon_pooled else None, step)

        step()

    def load(self, nbytes: int, done: Callable, *, via_db: bool = True,
             key: Optional[AdmissionKey] = None,
             rec: Optional[InvocationRecord] = None) -> None:
        """One db->host->device stream. Under a SAGE daemon it runs on the
        bounded gate and the slot is held across the whole chain, exactly
        like a real loader-pool worker; baseline platforms stream ungated.

        Each leg is a chunked :class:`~repro.core.transfer.TransferStream`;
        with ``rec`` the PCIe leg's **actual** contended (+ preempted) span
        lands in ``rec.stages["gpu_data"]`` — the seed charged the solo
        estimate ``nbytes / pcie.bw``, which under-reports whenever the
        link is shared — and the streams' preemption/stall counters roll
        into ``rec.preemptions`` / ``rec.stalled_s``."""
        gated = self.daemon_pooled
        key = key if key is not None else self.admission_key()
        db_st = self.db.open_stream(nbytes) if via_db else None
        pcie_st = self.pcie.open_stream(nbytes)
        t_pcie = [0.0]

        def start():
            if via_db:
                self._drive(db_st, key, host_loaded)
            else:  # host promotion: PCIe only
                host_loaded()

        def host_loaded():
            t_pcie[0] = self.clock.now()
            self._drive(pcie_st, key, dev_loaded)

        def dev_loaded():
            if rec is not None:
                # actual span, accumulated per record (parallel private
                # legs overlap in time, same additive convention as before)
                rec.stages["gpu_data"] = (rec.stages.get("gpu_data", 0.0)
                                          + self.clock.now() - t_pcie[0])
                for st in (db_st, pcie_st):
                    if st is not None:
                        rec.preemptions += st.preemptions
                        rec.stalled_s += st.stalled_s
            if gated:
                self.release_loader()
            if via_db:  # completion-counted, like the daemon's stats
                self.loads += 1
                self.bytes_loaded += nbytes
            done()

        if gated:
            self.acquire_loader(start, key)
        else:
            start()

    # ------------------------------------------------------------------
    # host-tier admission (twin of MemoryDaemon._admit_host)
    # ------------------------------------------------------------------
    def reserve_host(self, nbytes: int) -> bool:
        """Admit ``nbytes`` to the host tier; past the ceiling, evict
        idle host-state shared-RO copies (the refcount-0 HOST entries of
        the threaded daemon) LRU-first — same victim order as the
        daemon's ``_admit_host`` — before giving up."""
        if self.host_used + nbytes > self.host_capacity:
            victims = sorted(self.host_resident,
                             key=lambda f: self.host_touch.get(f, 0.0))
            for fname in victims:
                if self.host_used + nbytes <= self.host_capacity:
                    break
                if self.ro_state.get(fname) != "host":
                    continue  # in use on device / mid-promotion: not evictable
                self.host_used -= self.host_resident.pop(fname)
                self.host_touch.pop(fname, None)
                self.ro_state[fname] = "none"
                for inst in self.instances.get(fname, []):
                    inst.has_ro_host = False
                self.host_evictions += 1
        if self.host_used + nbytes > self.host_capacity:
            return False
        self.host_used += nbytes
        return True

    def release_host(self, nbytes: int) -> None:
        self.host_used -= nbytes

    def touch_host(self, fname: str) -> None:
        if fname in self.host_resident:
            self.host_touch[fname] = self.clock.now()

    def drop_host_resident(self, fname: str) -> None:
        """Release the shared-RO host copy accounting for ``fname``."""
        self.release_host(self.host_resident.pop(fname, 0))
        self.host_touch.pop(fname, None)

    # ------------------------------------------------------------------
    def _sample_mem(self):
        self.mem_samples.append((self.clock.now(), self.used))

    def reserve(self, nbytes: int, cont: Callable, *,
                on_fail: Optional[Callable] = None,
                timeout: Optional[float] = None,
                key: Optional[AdmissionKey] = None,
                max_retries: Optional[int] = None) -> None:
        """Reserve device memory; queue (with lazy eviction) if full.

        Queued reservations are served in ``key`` order (:data:`AdmissionKey`
        — arrival order under "fifo", tightest remaining slack first under
        "edf"), mirroring the threaded daemon's ordered waiter heap. With
        ``on_fail``, the queued reservation expires after ``timeout``
        (default ``load_timeout_s``) — the twin of the daemon's OOM-retry
        deadline — and ``on_fail`` runs instead of ``cont``.

        ``max_retries`` is the per-request OOM retry budget (twin of the
        daemon's): ``0`` fails here on the first OOM instead of queueing,
        ``N`` allows N failed head re-admissions in :meth:`kick`, ``None``
        waits out the flat deadline."""
        self._advance_ladders()
        if self.used + nbytes <= self.capacity or self._evict(nbytes - (self.capacity - self.used)):
            self.used += nbytes
            self._sample_mem()
            cont()
            return
        if nbytes > self.capacity and on_fail is not None:
            # impossible request (bigger than the whole device): fail now
            # rather than head-of-line-block the queue until the deadline
            # (twin of the daemon's fast-fail in _reserve_device_blocking)
            self.load_failures += 1
            on_fail()
            return
        if max_retries is not None and max_retries <= 0 and on_fail is not None:
            # retry budget 0: the failed attempt above was the only one
            # allowed — fail-fast typed, exactly like the daemon's head
            # attempt raising with an exhausted budget
            self.load_failures += 1
            on_fail()
            return
        p = _PendingReservation(nbytes, cont, on_fail, key or self.admission_key(),
                                max_retries=max_retries)
        heapq.heappush(self.pending_mem, (p.key, p))
        if on_fail is not None:
            t = self.load_timeout_s if timeout is None else timeout

            def expire():
                if p.granted or p.expired:
                    return
                p.expired = True  # popped lazily by kick()
                self.load_failures += 1
                p.on_fail()
                self.kick()  # the queue head may have been behind this one

            self.clock.schedule(t, expire)

    def release(self, nbytes: int) -> None:
        self.used -= nbytes
        self._sample_mem()
        self.kick()

    def _grant(self, p: _PendingReservation) -> None:
        p.granted = True
        self.used += p.nbytes
        self._sample_mem()
        p.cont()

    def kick(self) -> None:
        """Admit pending reservations in AdmissionKey order, evicting idle
        warm instances (Lesson-3) when plain headroom is not enough. A
        blocked head parks; later waiters may only BACKFILL free bytes no
        earlier waiter could use — same semantics as the daemon's ordered
        admission wait."""
        if getattr(self, "_kicking", False):
            return
        self._kicking = True
        charged = set()  # reservations already charged a retry this kick
        try:
            while self.pending_mem:
                _, p = self.pending_mem[0]
                if p.expired:
                    heapq.heappop(self.pending_mem)
                    continue
                self._advance_ladders()
                if self.used + p.nbytes > self.capacity:
                    self._evict(p.nbytes - (self.capacity - self.used))
                if self.used + p.nbytes <= self.capacity:
                    heapq.heappop(self.pending_mem)
                    self._grant(p)
                    continue
                # failed head admission: ONE retry against the request's
                # budget per kick (= per memory event), however many
                # backfill iterations re-examine the same blocked head —
                # parity with the daemon's counted-wake accounting
                if id(p) not in charged:
                    charged.add(id(p))
                    p.attempts += 1
                    if (p.max_retries is not None and p.on_fail is not None
                            and p.attempts > p.max_retries):
                        heapq.heappop(self.pending_mem)
                        p.expired = True
                        self.load_failures += 1
                        p.on_fail()
                        continue
                # head blocked: backfill the best-keyed waiter that fits
                # WITHOUT eviction (walking in key order, every waiter
                # skipped could not use the free bytes anyway)
                backfilled = None
                for entry in sorted(self.pending_mem)[1:]:
                    q = entry[1]
                    if q.expired:
                        continue
                    if self.used + q.nbytes <= self.capacity:
                        backfilled = entry
                        break
                if backfilled is None:
                    break
                self.pending_mem.remove(backfilled)
                heapq.heapify(self.pending_mem)
                self._grant(backfilled[1])
        finally:
            self._kicking = False

    def _evict(self, need: int) -> bool:
        """Lesson-3: drop idle warm instances (oldest first) to fit."""
        if need <= 0:
            return True
        freed = 0
        for fname, insts in self.instances.items():
            for inst in sorted(insts, key=lambda i: i.ladder.completion_t or 0):
                if inst.busy or inst.dead:
                    continue
                freed += self._destroy(inst)
                if freed >= need:
                    return True
        return freed >= need

    def _destroy(self, inst: SimInstance) -> int:
        freed = 0
        if inst.dead:
            return 0
        inst.dead = True
        if inst.has_ctx:
            freed += inst.fn.ctx_bytes
            inst.has_ctx = False
        if inst.has_ro_device:
            freed += inst.fn.ro_bytes
            inst.has_ro_device = False
            self.ro_state[inst.fn.name] = "none"
        if inst.slot:
            freed += inst.slot
            inst.slot = 0
        # the shared-RO host copy dies with its function's instance
        # (device-resident entries keep a host copy too, like the daemon)
        if inst.has_ro_host and self.ro_state.get(inst.fn.name) == "host":
            self.ro_state[inst.fn.name] = "none"
        if self.ro_state.get(inst.fn.name) == "none":
            self.drop_host_resident(inst.fn.name)
        inst.has_ro_host = False
        self.instances[inst.fn.name].remove(inst)
        if freed:
            self.release(freed)
        return freed

    def _advance_ladders(self) -> None:
        now = self.clock.now()
        for insts in self.instances.values():
            for inst in list(insts):
                if inst.busy or inst.dead:
                    continue
                s = inst.ladder.advance(now)
                if s >= 5:
                    self._destroy(inst)


class Simulator:
    def __init__(self, system: str | SystemPolicy = "sage", *, n_nodes: int = 1,
                 capacity: int = 40 << 30, host_capacity: int = 125 << 30,
                 exit_ttl: float = 30.0, seed: int = 0,
                 loader_threads: int = 4, load_timeout_s: float = 600.0,
                 scheduler: str = "fifo", dispatch: str = "random",
                 transfer: str = "run_to_completion",
                 chunk_bytes: int = DEFAULT_CHUNK_BYTES):
        if dispatch not in DISPATCH_POLICIES:
            raise ValueError(
                f"unknown dispatch {dispatch!r}; use one of {DISPATCH_POLICIES}")
        self.policy = get_system(system) if isinstance(system, str) else system
        self.dispatch = dispatch
        self.clock = VirtualClock()
        self.nodes = [
            GPUNode(self.policy, self.clock, capacity=capacity,
                    host_capacity=host_capacity,
                    exit_ttl=exit_ttl, name=f"gpu{i}",
                    loader_threads=loader_threads, load_timeout_s=load_timeout_s,
                    scheduler=scheduler, transfer=transfer,
                    chunk_bytes=chunk_bytes)
            for i in range(n_nodes)
        ]
        self.telemetry = Telemetry()
        self.functions: Dict[str, SimFunction] = {}
        self._rng = random.Random(seed)
        self.completed = 0
        self.failed = 0

    @property
    def scheduler(self) -> str:
        return self.nodes[0].scheduler

    def set_scheduler(self, scheduler: str) -> None:
        """Switch loader/admission ordering ("fifo"|"edf"); applies to
        events queued after the call."""
        if scheduler not in SCHEDULERS:
            raise ValueError(
                f"unknown scheduler {scheduler!r}; use one of {SCHEDULERS}")
        for node in self.nodes:
            node.scheduler = scheduler

    def set_dispatch(self, dispatch: str) -> None:
        """Switch the cluster dispatch policy; applies to arrivals
        dispatched after the call."""
        if dispatch not in DISPATCH_POLICIES:
            raise ValueError(
                f"unknown dispatch {dispatch!r}; use one of {DISPATCH_POLICIES}")
        self.dispatch = dispatch

    @property
    def transfer(self) -> str:
        return self.nodes[0].arbiter.mode

    def set_transfer(self, transfer: str) -> None:
        """Switch the transfer mode ("run_to_completion"|"preemptive");
        applies to chunks advanced after the call."""
        for node in self.nodes:
            node.arbiter.set_mode(transfer)

    def preemption_count(self) -> int:
        """Total link preemptions across nodes (the twin of the daemon's
        ``stats["preemptions"]``)."""
        return sum(n.arbiter.preemptions for n in self.nodes)

    # ------------------------------------------------------------------
    def register(self, fn: SimFunction) -> None:
        self.functions[fn.name] = fn
        for node in self.nodes:
            node.instances[fn.name] = []
            node.ro_state[fn.name] = "none"
            node.ro_ready_cbs[fn.name] = []
            if self.policy.pre_created_contexts:
                # DGSF pins contexts permanently; with many functions the
                # pool must shrink to fit (4 x 414 MB x 30 fns > 40 GB)
                n = self.policy.pre_created_contexts
                while n > 1 and node.used + n * fn.ctx_bytes > 0.85 * node.capacity:
                    n -= 1
                node.dgsf_free[fn.name] = n
                node.dgsf_queue[fn.name] = []
                node.used += n * fn.ctx_bytes  # permanent DGSF overhead

    def submit(self, fn_name: str, t: float, *,
               deadline_s: Optional[float] = None, priority: int = 0,
               request_id: Optional[str] = None,
               max_retries: Optional[int] = None) -> None:
        self.clock.schedule_at(
            t, lambda: self._arrive(fn_name, t, deadline_s, priority,
                                    request_id, max_retries)
        )

    def run(self, until: float = float("inf")) -> None:
        self.clock.run_until(until)

    # ------------------------------------------------------------------
    def _dispatch_node(self, fn_name: str):
        """(node, residency tier at dispatch) for one arrival. Single-node
        sims have no dispatch decision (tier None keeps their records
        identical to the single-node runtime's). ``"random"`` consumes the
        same seeded ``rng.choice`` stream as the pre-dispatch simulator, so
        seeded §7.8 replays are unchanged."""
        if len(self.nodes) == 1:
            return self.nodes[0], None
        if self.dispatch == "random":
            node = self._rng.choice(self.nodes)
            return node, node.residency(fn_name)[0]
        snaps = [n.dispatch_snapshot(fn_name) for n in self.nodes]
        idx = choose_node(self.dispatch, snaps)
        return self.nodes[idx], snaps[idx].ro_tier

    def _arrive(self, fn_name: str, arrival_t: float,
                deadline_s: Optional[float] = None, priority: int = 0,
                request_id: Optional[str] = None,
                max_retries: Optional[int] = None) -> None:
        node, tier = self._dispatch_node(fn_name)
        fn = self.functions[fn_name]
        rec = InvocationRecord(
            request_id=request_id or f"{fn_name}@{arrival_t:.4f}",
            function=fn_name,
            system=self.policy.name, arrival_t=arrival_t,
            start_t=self.clock.now(),
            deadline_s=deadline_s, priority=priority,
            max_retries=max_retries,
            node_id=node.name, dispatch_tier=tier,
        )
        # canonical stage keys up front (stages a policy path skips read as
        # 0.0) — keeps the record structure identical to the threaded
        # runtime's, which the parity test in tests/test_api.py guards
        for s in STAGES:
            rec.stages.setdefault(s, 0.0)
        if self.policy.name.startswith("sage"):
            self._invoke_sage(node, fn, rec)
        elif self.policy.pre_created_contexts:
            self._invoke_dgsf(node, fn, rec)
        else:
            self._invoke_fixed(node, fn, rec)

    # ------------------------------------------------------------------
    def _fail_record(self, fn: SimFunction, rec: InvocationRecord,
                     reason: str) -> None:
        """Shared failure bookkeeping (the twin of ``Handle.wait()`` raising
        ``DataLoadError``): the invocation resolves with a typed error
        record instead of waiting forever. All policy paths go through
        here so the error-record format stays uniform."""
        self.failed += 1
        rec.error = f"DataLoadError: {fn.name}: {reason}"
        rec.end_t = self.clock.now()
        self.telemetry.add(rec)

    # ------------------------------------------------------------------
    def _finish(self, node: GPUNode, fn: SimFunction, rec: InvocationRecord,
                inst: Optional[SimInstance], release_bytes: int,
                extra_done: Optional[Callable] = None) -> None:
        """Queue FIFO compute, then return + cleanup."""

        def start_compute():
            now = self.clock.now()
            start = max(now, node.compute_free_at)
            node.compute_free_at = start + fn.compute_s
            rec.stages["compute"] = (start - now) + fn.compute_s
            self.clock.schedule_at(start + fn.compute_s, done)

        def done():
            rec.stages["return_result"] = RETURN_S
            rec.end_t = self.clock.now() + RETURN_S
            self.telemetry.add(rec)
            self.completed += 1
            if release_bytes:
                node.release(release_bytes)
            if inst is not None:
                inst.busy = False
                inst.ladder.on_complete(self.clock.now())
            if extra_done is not None:
                extra_done()
            node.kick()  # an idle warm instance is now evictable

        start_compute()

    # ------------------------------------------------------------------
    # SAGE
    # ------------------------------------------------------------------
    def _sage_inst(self, node: GPUNode, fn: SimFunction) -> SimInstance:
        insts = node.instances[fn.name]
        for i in insts:
            if not i.dead:
                return i
        inst = SimInstance(fn)
        inst.ladder.ttls = (
            (node.exit_ttl,) * 4 if self.policy.multi_stage_exit
            else (self.policy.keep_warm_s, 0.0, 0.0, 0.0)
        )
        inst.ladder.on_enter = {
            2: lambda: self._sage_demote(node, inst),
            3: lambda: self._sage_drop_ctx(node, inst),
            4: lambda: self._sage_drop_host(node, inst),
        }
        insts.append(inst)
        return inst

    def _sage_demote(self, node, inst):
        if inst.has_ro_device:
            inst.has_ro_device = False
            inst.has_ro_host = True
            node.ro_state[inst.fn.name] = "host"
            node.touch_host(inst.fn.name)
            node.release(inst.fn.ro_bytes)

    def _sage_drop_ctx(self, node, inst):
        if inst.has_ctx:
            inst.has_ctx = False
            node.release(inst.fn.ctx_bytes)

    def _sage_drop_host(self, node, inst):
        inst.has_ro_host = False
        if node.ro_state[inst.fn.name] == "host":
            node.ro_state[inst.fn.name] = "none"
        if node.ro_state[inst.fn.name] == "none":
            node.drop_host_resident(inst.fn.name)

    def _invoke_sage(self, node: GPUNode, fn: SimFunction, rec: InvocationRecord) -> None:
        node._advance_ladders()
        inst = self._sage_inst(node, fn)
        warm = inst.ladder.on_reuse(self.clock.now()) if inst.ladder.completion_t else None
        rec.warm_stage = warm
        inst.busy = True
        share = self.policy.share_read_only

        pending = {"mem": True, "ctx": True, "ro": True, "win": True}
        state = {"failed": False, "mem_granted": False}
        # bytes that die with this invocation: writable + private RO (NR
        # mode), reserved ATOMICALLY up front — piecemeal ro-then-writable
        # reservation deadlocks under load (every invocation holds half its
        # memory while waiting for the other half).
        release_bytes = fn.w_bytes + (0 if share else fn.ro_bytes)

        def fail(reason: str):
            if state["failed"]:
                return
            state["failed"] = True
            self._fail_record(fn, rec, reason)
            inst.busy = False
            inst.ladder.on_complete(self.clock.now())
            if state["mem_granted"] and release_bytes:
                node.release(release_bytes)
                node.release_host(release_bytes)

        def maybe_run(which: str):
            pending[which] = False
            if state["failed"]:
                return
            if not any(pending.values()):
                self._finish(
                    node, fn, rec, inst, release_bytes,
                    # private bytes leave the host tier with the invocation
                    # (the daemon drops writable entries at release())
                    extra_done=((lambda: node.release_host(release_bytes))
                                if release_bytes else None))

        # --- context path (parallel with data path). The context is shared
        # per instance: exactly ONE builder reserves+creates; concurrent
        # invocations latch onto it (double-reserving 414 MB per concurrent
        # arrival leaks the device dry under load).
        if inst.has_ctx:
            rec.stages["gpu_ctx"] = 0.0
            maybe_run("ctx")
        elif inst.ctx_building:
            inst.ctx_waiters.append(
                (lambda: maybe_run("ctx"),
                 lambda: fail("context memory not granted within deadline"))
            )
        else:
            inst.ctx_building = True
            rec.stages["cpu_ctx"] = CPU_CTX_S

            def ctx_done():
                inst.has_ctx = True
                inst.ctx_building = False
                maybe_run("ctx")
                for ok, _ in inst.ctx_waiters:
                    ok()
                inst.ctx_waiters = []

            def ctx_start():
                # paper-faithful: a dropped GPU context costs a full
                # re-creation (Table 4 stage 3 = 309.5 ms). The beyond-paper
                # ``executable_cache`` policy (TPU: XLA executables are
                # host-cacheable objects, CUDA contexts are not) re-loads the
                # program at ~10% of a compile.
                cost = GPU_CTX_S
                if getattr(self.policy, "executable_cache", False) and warm is not None:
                    cost = GPU_CTX_S * 0.1
                rec.stages["gpu_ctx"] = cost
                self.clock.schedule(CPU_CTX_S + cost, ctx_done)

            def ctx_fail():
                inst.ctx_building = False
                waiters, inst.ctx_waiters = inst.ctx_waiters, []
                fail("context memory not granted within deadline")
                for _, fl in waiters:
                    fl()

            node.reserve(fn.ctx_bytes, ctx_start, on_fail=ctx_fail,
                         key=node.admission_key(rec),
                         max_retries=rec.max_retries)

        # --- the invocation's private bytes, one atomic reservation; data
        # loads start only once the memory is granted. The private bytes
        # transit (and occupy) the host tier for the invocation's lifetime,
        # so host admission happens here too — the twin of the daemon's
        # _admit_host on the db->host leg.
        def mem_granted():
            if state["failed"]:
                # another path (ctx/ro) already failed this invocation:
                # hand the late grant straight back
                if release_bytes:
                    node.release(release_bytes)
                return
            if release_bytes and not node.reserve_host(release_bytes):
                node.release(release_bytes)
                node.load_failures += 1
                fail("host memory not granted within deadline")
                return
            state["mem_granted"] = True  # device AND host bytes held
            maybe_run("mem")
            if not share and fn.ro_bytes:
                self._load_private(node, fn.ro_bytes, rec,
                                   lambda: maybe_run("ro"),
                                   key=node.admission_key(rec))
            if fn.w_bytes:
                self._load_private(node, fn.w_bytes, rec,
                                   lambda: maybe_run("win"),
                                   key=node.admission_key(rec))
            else:
                maybe_run("win")

        if release_bytes:
            node.reserve(
                release_bytes, mem_granted,
                on_fail=lambda: fail("working-set memory not granted within deadline"),
                key=node.admission_key(rec),
                max_retries=rec.max_retries,
            )
        else:
            mem_granted()

        # --- read-only data path (shared)
        st = node.ro_state[fn.name] if share else "none"
        if not share or fn.ro_bytes == 0:
            if share or not fn.ro_bytes:  # nothing shared to wait for
                maybe_run("ro")
            # (private RO load is driven from mem_granted above)
        elif st == "device":
            rec.stages["gpu_data"] = 0.0
            maybe_run("ro")
        elif st == "loading":
            node.ro_ready_cbs[fn.name].append(
                (lambda: maybe_run("ro"),
                 lambda: fail("shared read-only load failed"))
            )
        elif st == "host":
            # stage-2 hit: PCIe only (the host copy is already resident
            # and admitted — no new host reservation)
            node.ro_state[fn.name] = "loading"
            node.touch_host(fn.name)

            def host_loaded():
                node.ro_state[fn.name] = "device"
                inst.has_ro_device = True
                inst.has_ro_host = False
                for ok, _ in node.ro_ready_cbs[fn.name]:
                    ok()
                node.ro_ready_cbs[fn.name] = []
                maybe_run("ro")

            def ro_host_fail():
                node.ro_state[fn.name] = "host"  # entry keeps its host copy
                cbs, node.ro_ready_cbs[fn.name] = node.ro_ready_cbs[fn.name], []
                fail("shared read-only memory not granted within deadline")
                for _, fl in cbs:
                    fl()

            node.reserve(
                fn.ro_bytes,
                lambda: node.load(fn.ro_bytes, host_loaded, via_db=False,
                                  key=node.admission_key(rec), rec=rec),
                on_fail=ro_host_fail,
                key=node.admission_key(rec),
                max_retries=rec.max_retries,
            )
        else:
            node.ro_state[fn.name] = "loading"

            def dev_loaded():
                node.ro_state[fn.name] = "device"
                inst.has_ro_device = True
                for ok, _ in node.ro_ready_cbs[fn.name]:
                    ok()
                node.ro_ready_cbs[fn.name] = []
                maybe_run("ro")

            def ro_fail():
                node.ro_state[fn.name] = "none"
                node.drop_host_resident(fn.name)
                cbs, node.ro_ready_cbs[fn.name] = node.ro_ready_cbs[fn.name], []
                fail("shared read-only memory not granted within deadline")
                for _, fl in cbs:
                    fl()

            def ro_dev_granted():
                # db->host leg needs host admission (daemon._admit_host
                # twin); the host copy then stays resident alongside the
                # device copy until stage 4 drops it
                if not node.reserve_host(fn.ro_bytes):
                    node.release(fn.ro_bytes)
                    node.load_failures += 1
                    ro_fail()
                    return
                node.host_resident[fn.name] = fn.ro_bytes
                node.touch_host(fn.name)
                node.load(fn.ro_bytes, dev_loaded,
                          key=node.admission_key(rec), rec=rec)

            node.reserve(
                fn.ro_bytes,
                ro_dev_granted,
                on_fail=ro_fail,
                key=node.admission_key(rec),
                max_retries=rec.max_retries,
            )
            rec.stages["cpu_data"] = fn.ro_bytes / node.db.bw

        # (writable input load is driven from mem_granted above)

    def _load_private(self, node: GPUNode, nbytes: int, rec, done: Callable,
                      *, key: Optional[AdmissionKey] = None) -> None:
        # memory was already granted atomically by the caller; the transfer
        # itself runs on the node's bounded loader gate. cpu_data keeps the
        # solo db estimate; gpu_data is recorded by load() as the ACTUAL
        # contended+preempted PCIe span (docs/dataplane.md)
        rec.stages["cpu_data"] = rec.stages.get("cpu_data", 0.0) + nbytes / node.db.bw
        node.load(nbytes, done, key=key, rec=rec)

    # ------------------------------------------------------------------
    # FixedGSL / FixedGSL-F
    # ------------------------------------------------------------------
    def _invoke_fixed(self, node: GPUNode, fn: SimFunction, rec: InvocationRecord) -> None:
        """Paper model (§3.2.1/§7.1): only the *container* is pre-warmed for
        FixedGSL — the coarse-grained platform re-runs every GPU setup stage
        per invocation (Fig 2 shows all stages on each call). The fixed slot
        is held while the container instance is warm, capping concurrency."""
        node._advance_ladders()
        insts = node.instances[fn.name]
        inst = None
        for cand in insts:
            if not cand.busy and not cand.dead and cand.ladder.stage_at(self.clock.now()) == 1:
                cand.ladder.on_reuse(self.clock.now())
                cand.busy = True
                rec.warm_stage = 1  # warm *container*: skips slot wait only
                inst = cand
                break

        def setup(inst: SimInstance):
            # serial chain: cpu_ctx -> gpu_ctx -> db -> pcie -> compute
            rec.stages["cpu_ctx"] = CPU_CTX_S
            rec.stages["gpu_ctx"] = GPU_CTX_S
            # ctx + data memory live inside the fixed slot (no extra reserve)
            total = fn.ro_bytes + fn.w_bytes

            def load():
                rec.stages["cpu_data"] = total / node.db.bw
                node.load(total, lambda: self._finish(node, fn, rec, inst, 0),
                          key=node.admission_key(rec), rec=rec)

            self.clock.schedule(CPU_CTX_S + GPU_CTX_S, load)

        if inst is not None:
            setup(inst)
            return
        inst = SimInstance(fn)
        inst.busy = True
        inst.ladder.ttls = (self.policy.keep_warm_s, 0.0, 0.0, 0.0)
        inst.ladder.on_enter = {2: (lambda i=inst: node._destroy(i))}
        insts.append(inst)
        slot = fn.slot_bytes(self.policy.slot_granularity)
        inst.slot = slot

        def slot_fail():
            # never got the slot: the instance dies without holding memory
            inst.slot = 0
            inst.dead = True
            if inst in insts:
                insts.remove(inst)
            self._fail_record(fn, rec, f"no {slot}-byte slot within deadline")

        node.reserve(slot, lambda: setup(inst), on_fail=slot_fail,
                     key=node.admission_key(rec),
                     max_retries=rec.max_retries)

    # ------------------------------------------------------------------
    # DGSF
    # ------------------------------------------------------------------
    def _invoke_dgsf(self, node: GPUNode, fn: SimFunction, rec: InvocationRecord) -> None:
        def with_ctx():
            rec.stages["cpu_ctx"] = CPU_CTX_S
            rec.stages["gpu_ctx"] = 0.0  # pre-created
            total = fn.ro_bytes + fn.w_bytes
            rec.warm_stage = 1

            def free_ctx_slot():
                node.dgsf_free[fn.name] += 1
                if node.dgsf_queue[fn.name]:
                    node.dgsf_queue[fn.name].pop(0)()

            def computed():
                # release data + ctx slot after compute
                def done_wrap():
                    node.release(total)
                    free_ctx_slot()
                self._finish_with_cb(node, fn, rec, done_wrap)

            def data_fail():
                self._fail_record(fn, rec,
                                  "data memory not granted within deadline")
                free_ctx_slot()

            rec.stages["cpu_data"] = total / node.db.bw
            node.reserve(total,
                         lambda: node.load(total, computed,
                                           key=node.admission_key(rec),
                                           rec=rec),
                         on_fail=data_fail, key=node.admission_key(rec),
                         max_retries=rec.max_retries)

        if node.dgsf_free[fn.name] > 0:
            node.dgsf_free[fn.name] -= 1
            with_ctx()
        else:
            node.dgsf_queue[fn.name].append(
                lambda: (node.dgsf_free.__setitem__(fn.name, node.dgsf_free[fn.name] - 1), with_ctx())
            )

    def _finish_with_cb(self, node, fn, rec, cb: Callable) -> None:
        now = self.clock.now()
        start = max(now, node.compute_free_at)
        node.compute_free_at = start + fn.compute_s
        rec.stages["compute"] = (start - now) + fn.compute_s

        def done():
            rec.stages["return_result"] = RETURN_S
            rec.end_t = self.clock.now() + RETURN_S
            self.telemetry.add(rec)
            self.completed += 1
            cb()

        self.clock.schedule_at(start + fn.compute_s, done)

    # ------------------------------------------------------------------
    def mean_memory_bytes(self) -> float:
        total = 0.0
        for node in self.nodes:
            if not node.mem_samples:
                continue
            samples = node.mem_samples
            t_end = self.clock.now()
            acc, last_t, last_v = 0.0, samples[0][0], samples[0][1]
            for t, v in samples[1:]:
                acc += last_v * (t - last_t)
                last_t, last_v = t, v
            acc += last_v * (t_end - last_t)
            total += acc / max(t_end - samples[0][0], 1e-9)
        return total


# ---------------------------------------------------------------------------
# workload generation (Poisson open-loop + MAF-style trace)
# ---------------------------------------------------------------------------


def poisson_arrivals(rate_per_s: float, duration_s: float, rng: random.Random) -> List[float]:
    t, out = 0.0, []
    while True:
        t += rng.expovariate(rate_per_s)
        if t >= duration_s:
            return out
        out.append(t)


def maf_like_trace(
    functions: List[str], duration_s: float, seed: int = 0,
    mean_rpm: float = 12.0,
) -> List[Tuple[float, str]]:
    """Azure-Functions-like trace: per-function Poisson with log-normal rate
    spread and hour-scale bursts (Shahrad et al.: most functions see a few
    to dozens of requests/minute)."""
    rng = random.Random(seed)
    events: List[Tuple[float, str]] = []
    for f in functions:
        rate = (mean_rpm / 60.0) * math.exp(rng.gauss(0.0, 0.8))
        burst_phase = rng.random() * duration_s
        t = 0.0
        while True:
            # burst modulation: 2x rate inside a 10% duty window
            mult = 2.0 if ((t + burst_phase) % 600.0) < 60.0 else 1.0
            t += rng.expovariate(rate * mult)
            if t >= duration_s:
                break
            events.append((t, f))
    events.sort()
    return events
