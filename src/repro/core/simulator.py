"""Virtual-time discrete-event twin of the runtime, for trace-scale
experiments (Figs 3, 10-14, 16, 17).

Runs the SAME policy decisions (SystemPolicy flags, ExitLadder stages,
read-only sharing, slot accounting, FCFS context pools) as the threaded
runtime, but with modeled durations (paper Table 2/4 profiles + fair-share
brokers) under a VirtualClock — two hours of MAF trace replay complete in
milliseconds, deterministically.

Modeling choices (documented in DESIGN.md §2):
* GPU compute is FIFO (one kernel at a time) — consistent with the paper's
  Throughput_theo = T_period / T_comp definition;
* gpu_ctx creation = 285.1 ms (Table 4) and does not contend (paper §6.1:
  'context creation for function invocations does not interfere');
* db / PCIe paths are progressive-filling fair-share links (Fig 4's 34.9x
  contention emerges from these, not from a hard-coded factor).
"""
from __future__ import annotations

import heapq
import math
import random
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.baselines import SystemPolicy, get_system
from repro.core.clock import VirtualClock
from repro.core.datapath import DB_BANDWIDTH, PCIE_BANDWIDTH, BandwidthBroker
from repro.core.exit_policy import ExitLadder
from repro.core.profiles import MB, PROFILES, FunctionProfile
from repro.core.telemetry import InvocationRecord, Telemetry

GPU_CTX_S = 0.2851
CPU_CTX_S = 0.001
RETURN_S = 0.0001
CONTAINER_S = 2.0


@dataclass
class SimFunction:
    profile: FunctionProfile
    name: str = ""

    def __post_init__(self):
        self.name = self.name or self.profile.name

    @property
    def ro_bytes(self) -> int:
        return int(self.profile.read_only_mb * MB)

    @property
    def w_bytes(self) -> int:
        return int(self.profile.writable_mb * MB)

    @property
    def ctx_bytes(self) -> int:
        return int(self.profile.context_mb * MB)

    @property
    def compute_s(self) -> float:
        return self.profile.compute_ms / 1e3

    def slot_bytes(self, granularity: int) -> int:
        need = self.ctx_bytes + self.ro_bytes + self.w_bytes
        if granularity:
            need = ((need + granularity - 1) // granularity) * granularity
        return need


@dataclass
class SimInstance:
    fn: SimFunction
    ladder: ExitLadder = field(default_factory=ExitLadder)
    busy: bool = False
    dead: bool = False
    has_ctx: bool = False
    ctx_building: bool = False
    ctx_waiters: List[Callable] = field(default_factory=list)
    has_ro_device: bool = False
    has_ro_host: bool = False
    slot: int = 0


class GPUNode:
    """One simulated GPU node (device memory + compute FIFO + data paths)."""

    def __init__(self, policy: SystemPolicy, clock: VirtualClock, *,
                 capacity: int = 40 << 30, exit_ttl: float = 30.0, name: str = "gpu0"):
        self.policy = policy
        self.clock = clock
        self.capacity = capacity
        self.exit_ttl = exit_ttl
        self.name = name
        self.used = 0
        self.db = BandwidthBroker(DB_BANDWIDTH, clock, "db", concurrency_penalty=0.06)
        self.pcie = BandwidthBroker(PCIE_BANDWIDTH, clock, "pcie")
        self.compute_free_at = 0.0
        self.instances: Dict[str, List[SimInstance]] = {}
        # SAGE shared read-only state per function: tier + waiters
        self.ro_state: Dict[str, str] = {}  # function -> none|loading|device|host
        self.ro_ready_cbs: Dict[str, List[Callable]] = {}
        self.dgsf_free: Dict[str, int] = {}
        self.dgsf_queue: Dict[str, List[Callable]] = {}
        self.mem_samples: List[Tuple[float, int]] = []
        self.pending_mem: List[Tuple[int, Callable]] = []

    # ------------------------------------------------------------------
    def _sample_mem(self):
        self.mem_samples.append((self.clock.now(), self.used))

    def reserve(self, nbytes: int, cont: Callable) -> None:
        """Reserve device memory; queue (with lazy eviction) if full."""
        self._advance_ladders()
        if self.used + nbytes <= self.capacity or self._evict(nbytes - (self.capacity - self.used)):
            self.used += nbytes
            self._sample_mem()
            cont()
        else:
            self.pending_mem.append((nbytes, cont))

    def release(self, nbytes: int) -> None:
        self.used -= nbytes
        self._sample_mem()
        self.kick()

    def kick(self) -> None:
        """Admit pending reservations FIFO, evicting idle warm instances
        (Lesson-3) when plain headroom is not enough."""
        if getattr(self, "_kicking", False):
            return
        self._kicking = True
        try:
            while self.pending_mem:
                nb, cont = self.pending_mem[0]
                self._advance_ladders()
                if self.used + nb > self.capacity:
                    self._evict(nb - (self.capacity - self.used))
                if self.used + nb <= self.capacity:
                    self.pending_mem.pop(0)
                    self.used += nb
                    self._sample_mem()
                    cont()
                else:
                    break
        finally:
            self._kicking = False

    def _evict(self, need: int) -> bool:
        """Lesson-3: drop idle warm instances (oldest first) to fit."""
        if need <= 0:
            return True
        freed = 0
        for fname, insts in self.instances.items():
            for inst in sorted(insts, key=lambda i: i.ladder.completion_t or 0):
                if inst.busy or inst.dead:
                    continue
                freed += self._destroy(inst)
                if freed >= need:
                    return True
        return freed >= need

    def _destroy(self, inst: SimInstance) -> int:
        freed = 0
        if inst.dead:
            return 0
        inst.dead = True
        if inst.has_ctx:
            freed += inst.fn.ctx_bytes
            inst.has_ctx = False
        if inst.has_ro_device:
            freed += inst.fn.ro_bytes
            inst.has_ro_device = False
            self.ro_state[inst.fn.name] = "none"
        if inst.slot:
            freed += inst.slot
            inst.slot = 0
        self.instances[inst.fn.name].remove(inst)
        if freed:
            self.release(freed)
        return freed

    def _advance_ladders(self) -> None:
        now = self.clock.now()
        for insts in self.instances.values():
            for inst in list(insts):
                if inst.busy or inst.dead:
                    continue
                s = inst.ladder.advance(now)
                if s >= 5:
                    self._destroy(inst)


class Simulator:
    def __init__(self, system: str | SystemPolicy = "sage", *, n_nodes: int = 1,
                 capacity: int = 40 << 30, exit_ttl: float = 30.0, seed: int = 0):
        self.policy = get_system(system) if isinstance(system, str) else system
        self.clock = VirtualClock()
        self.nodes = [
            GPUNode(self.policy, self.clock, capacity=capacity,
                    exit_ttl=exit_ttl, name=f"gpu{i}")
            for i in range(n_nodes)
        ]
        self.telemetry = Telemetry()
        self.functions: Dict[str, SimFunction] = {}
        self._rng = random.Random(seed)
        self.completed = 0

    # ------------------------------------------------------------------
    def register(self, fn: SimFunction) -> None:
        self.functions[fn.name] = fn
        for node in self.nodes:
            node.instances[fn.name] = []
            node.ro_state[fn.name] = "none"
            node.ro_ready_cbs[fn.name] = []
            if self.policy.pre_created_contexts:
                # DGSF pins contexts permanently; with many functions the
                # pool must shrink to fit (4 x 414 MB x 30 fns > 40 GB)
                n = self.policy.pre_created_contexts
                while n > 1 and node.used + n * fn.ctx_bytes > 0.85 * node.capacity:
                    n -= 1
                node.dgsf_free[fn.name] = n
                node.dgsf_queue[fn.name] = []
                node.used += n * fn.ctx_bytes  # permanent DGSF overhead

    def submit(self, fn_name: str, t: float) -> None:
        self.clock.schedule_at(t, lambda: self._arrive(fn_name, t))

    def run(self, until: float = float("inf")) -> None:
        self.clock.run_until(until)

    # ------------------------------------------------------------------
    def _arrive(self, fn_name: str, arrival_t: float) -> None:
        node = self._rng.choice(self.nodes)
        fn = self.functions[fn_name]
        rec = InvocationRecord(
            request_id=f"{fn_name}@{arrival_t:.4f}", function=fn_name,
            system=self.policy.name, arrival_t=arrival_t,
            start_t=self.clock.now(),
        )
        if self.policy.name.startswith("sage"):
            self._invoke_sage(node, fn, rec)
        elif self.policy.pre_created_contexts:
            self._invoke_dgsf(node, fn, rec)
        else:
            self._invoke_fixed(node, fn, rec)

    # ------------------------------------------------------------------
    def _finish(self, node: GPUNode, fn: SimFunction, rec: InvocationRecord,
                inst: Optional[SimInstance], release_bytes: int,
                extra_done: Optional[Callable] = None) -> None:
        """Queue FIFO compute, then return + cleanup."""

        def start_compute():
            now = self.clock.now()
            start = max(now, node.compute_free_at)
            node.compute_free_at = start + fn.compute_s
            rec.stages["compute"] = (start - now) + fn.compute_s
            self.clock.schedule_at(start + fn.compute_s, done)

        def done():
            rec.stages["return_result"] = RETURN_S
            rec.end_t = self.clock.now() + RETURN_S
            self.telemetry.add(rec)
            self.completed += 1
            if release_bytes:
                node.release(release_bytes)
            if inst is not None:
                inst.busy = False
                inst.ladder.on_complete(self.clock.now())
            if extra_done is not None:
                extra_done()
            node.kick()  # an idle warm instance is now evictable

        start_compute()

    # ------------------------------------------------------------------
    # SAGE
    # ------------------------------------------------------------------
    def _sage_inst(self, node: GPUNode, fn: SimFunction) -> SimInstance:
        insts = node.instances[fn.name]
        for i in insts:
            if not i.dead:
                return i
        inst = SimInstance(fn)
        inst.ladder.ttls = (
            (node.exit_ttl,) * 4 if self.policy.multi_stage_exit
            else (self.policy.keep_warm_s, 0.0, 0.0, 0.0)
        )
        inst.ladder.on_enter = {
            2: lambda: self._sage_demote(node, inst),
            3: lambda: self._sage_drop_ctx(node, inst),
            4: lambda: self._sage_drop_host(node, inst),
        }
        insts.append(inst)
        return inst

    def _sage_demote(self, node, inst):
        if inst.has_ro_device:
            inst.has_ro_device = False
            inst.has_ro_host = True
            node.ro_state[inst.fn.name] = "host"
            node.release(inst.fn.ro_bytes)

    def _sage_drop_ctx(self, node, inst):
        if inst.has_ctx:
            inst.has_ctx = False
            node.release(inst.fn.ctx_bytes)

    def _sage_drop_host(self, node, inst):
        inst.has_ro_host = False
        if node.ro_state[inst.fn.name] == "host":
            node.ro_state[inst.fn.name] = "none"

    def _invoke_sage(self, node: GPUNode, fn: SimFunction, rec: InvocationRecord) -> None:
        node._advance_ladders()
        inst = self._sage_inst(node, fn)
        warm = inst.ladder.on_reuse(self.clock.now()) if inst.ladder.completion_t else None
        rec.warm_stage = warm
        inst.busy = True
        share = self.policy.share_read_only

        pending = {"mem": True, "ctx": True, "ro": True, "win": True}
        # bytes that die with this invocation: writable + private RO (NR
        # mode), reserved ATOMICALLY up front — piecemeal ro-then-writable
        # reservation deadlocks under load (every invocation holds half its
        # memory while waiting for the other half).
        release_bytes = fn.w_bytes + (0 if share else fn.ro_bytes)

        def maybe_run(which: str):
            pending[which] = False
            if not any(pending.values()):
                self._finish(node, fn, rec, inst, release_bytes)

        # --- context path (parallel with data path). The context is shared
        # per instance: exactly ONE builder reserves+creates; concurrent
        # invocations latch onto it (double-reserving 414 MB per concurrent
        # arrival leaks the device dry under load).
        if inst.has_ctx:
            rec.stages["gpu_ctx"] = 0.0
            maybe_run("ctx")
        elif inst.ctx_building:
            inst.ctx_waiters.append(lambda: maybe_run("ctx"))
        else:
            inst.ctx_building = True
            rec.stages["cpu_ctx"] = CPU_CTX_S

            def ctx_done():
                inst.has_ctx = True
                inst.ctx_building = False
                maybe_run("ctx")
                for cb in inst.ctx_waiters:
                    cb()
                inst.ctx_waiters = []

            def ctx_start():
                # paper-faithful: a dropped GPU context costs a full
                # re-creation (Table 4 stage 3 = 309.5 ms). The beyond-paper
                # ``executable_cache`` policy (TPU: XLA executables are
                # host-cacheable objects, CUDA contexts are not) re-loads the
                # program at ~10% of a compile.
                cost = GPU_CTX_S
                if getattr(self.policy, "executable_cache", False) and warm is not None:
                    cost = GPU_CTX_S * 0.1
                rec.stages["gpu_ctx"] = cost
                self.clock.schedule(CPU_CTX_S + cost, ctx_done)

            node.reserve(fn.ctx_bytes, ctx_start)

        # --- the invocation's private bytes, one atomic reservation; data
        # loads start only once the memory is granted
        def mem_granted():
            maybe_run("mem")
            if not share and fn.ro_bytes:
                self._load_private(node, fn.ro_bytes, rec,
                                   lambda: maybe_run("ro"), account=False)
            if fn.w_bytes:
                self._load_private(node, fn.w_bytes, rec,
                                   lambda: maybe_run("win"), account=False)
            else:
                maybe_run("win")

        if release_bytes:
            node.reserve(release_bytes, mem_granted)
        else:
            mem_granted()

        # --- read-only data path (shared)
        st = node.ro_state[fn.name] if share else "none"
        if not share or fn.ro_bytes == 0:
            if share or not fn.ro_bytes:  # nothing shared to wait for
                maybe_run("ro")
            # (private RO load is driven from mem_granted above)
        elif st == "device":
            rec.stages["gpu_data"] = 0.0
            maybe_run("ro")
        elif st == "loading":
            node.ro_ready_cbs[fn.name].append(lambda: maybe_run("ro"))
        elif st == "host":
            # stage-2 hit: PCIe only
            node.ro_state[fn.name] = "loading"

            def host_loaded():
                node.ro_state[fn.name] = "device"
                inst.has_ro_device = True
                inst.has_ro_host = False
                for cb in node.ro_ready_cbs[fn.name]:
                    cb()
                node.ro_ready_cbs[fn.name] = []
                maybe_run("ro")

            node.reserve(fn.ro_bytes, lambda: node.pcie.sim_transfer(fn.ro_bytes, host_loaded))
            rec.stages["gpu_data"] = fn.ro_bytes / node.pcie.bw  # solo estimate
        else:
            node.ro_state[fn.name] = "loading"

            def dev_loaded():
                node.ro_state[fn.name] = "device"
                inst.has_ro_device = True
                for cb in node.ro_ready_cbs[fn.name]:
                    cb()
                node.ro_ready_cbs[fn.name] = []
                maybe_run("ro")

            def host_loaded():
                node.pcie.sim_transfer(fn.ro_bytes, dev_loaded)

            node.reserve(fn.ro_bytes, lambda: node.db.sim_transfer(fn.ro_bytes, host_loaded))
            rec.stages["cpu_data"] = fn.ro_bytes / node.db.bw
            rec.stages["gpu_data"] = fn.ro_bytes / node.pcie.bw

        # (writable input load is driven from mem_granted above)

    def _load_private(self, node: GPUNode, nbytes: int, rec, done: Callable, *,
                      account: bool = True) -> None:
        def host_loaded():
            node.pcie.sim_transfer(nbytes, done)

        def start():
            node.db.sim_transfer(nbytes, host_loaded)

        rec.stages["cpu_data"] = rec.stages.get("cpu_data", 0.0) + nbytes / node.db.bw
        rec.stages["gpu_data"] = rec.stages.get("gpu_data", 0.0) + nbytes / node.pcie.bw
        if account:
            node.reserve(nbytes, start)
        else:
            start()

    # ------------------------------------------------------------------
    # FixedGSL / FixedGSL-F
    # ------------------------------------------------------------------
    def _invoke_fixed(self, node: GPUNode, fn: SimFunction, rec: InvocationRecord) -> None:
        """Paper model (§3.2.1/§7.1): only the *container* is pre-warmed for
        FixedGSL — the coarse-grained platform re-runs every GPU setup stage
        per invocation (Fig 2 shows all stages on each call). The fixed slot
        is held while the container instance is warm, capping concurrency."""
        node._advance_ladders()
        insts = node.instances[fn.name]
        inst = None
        for cand in insts:
            if not cand.busy and not cand.dead and cand.ladder.stage_at(self.clock.now()) == 1:
                cand.ladder.on_reuse(self.clock.now())
                cand.busy = True
                rec.warm_stage = 1  # warm *container*: skips slot wait only
                inst = cand
                break

        def setup(inst: SimInstance):
            # serial chain: cpu_ctx -> gpu_ctx -> db -> pcie -> compute
            rec.stages["cpu_ctx"] = CPU_CTX_S
            rec.stages["gpu_ctx"] = GPU_CTX_S
            # ctx + data memory live inside the fixed slot (no extra reserve)
            total = fn.ro_bytes + fn.w_bytes

            def host_loaded():
                node.pcie.sim_transfer(
                    total, lambda: self._finish(node, fn, rec, inst, 0)
                )

            def load():
                rec.stages["cpu_data"] = total / node.db.bw
                rec.stages["gpu_data"] = total / node.pcie.bw
                node.db.sim_transfer(total, host_loaded)

            self.clock.schedule(CPU_CTX_S + GPU_CTX_S, load)

        if inst is not None:
            setup(inst)
            return
        inst = SimInstance(fn)
        inst.busy = True
        inst.ladder.ttls = (self.policy.keep_warm_s, 0.0, 0.0, 0.0)
        inst.ladder.on_enter = {2: (lambda i=inst: node._destroy(i))}
        insts.append(inst)
        slot = fn.slot_bytes(self.policy.slot_granularity)
        inst.slot = slot
        node.reserve(slot, lambda: setup(inst))

    # ------------------------------------------------------------------
    # DGSF
    # ------------------------------------------------------------------
    def _invoke_dgsf(self, node: GPUNode, fn: SimFunction, rec: InvocationRecord) -> None:
        def with_ctx():
            rec.stages["cpu_ctx"] = CPU_CTX_S
            rec.stages["gpu_ctx"] = 0.0  # pre-created
            total = fn.ro_bytes + fn.w_bytes
            rec.warm_stage = 1

            def host_loaded():
                node.pcie.sim_transfer(total, computed)

            def computed():
                # release data + ctx slot after compute
                def done_wrap():
                    node.release(total)
                    node.dgsf_free[fn.name] += 1
                    if node.dgsf_queue[fn.name]:
                        node.dgsf_queue[fn.name].pop(0)()
                self._finish_with_cb(node, fn, rec, done_wrap)

            rec.stages["cpu_data"] = total / node.db.bw
            rec.stages["gpu_data"] = total / node.pcie.bw
            node.reserve(total, lambda: node.db.sim_transfer(total, host_loaded))

        if node.dgsf_free[fn.name] > 0:
            node.dgsf_free[fn.name] -= 1
            with_ctx()
        else:
            node.dgsf_queue[fn.name].append(
                lambda: (node.dgsf_free.__setitem__(fn.name, node.dgsf_free[fn.name] - 1), with_ctx())
            )

    def _finish_with_cb(self, node, fn, rec, cb: Callable) -> None:
        now = self.clock.now()
        start = max(now, node.compute_free_at)
        node.compute_free_at = start + fn.compute_s
        rec.stages["compute"] = (start - now) + fn.compute_s

        def done():
            rec.stages["return_result"] = RETURN_S
            rec.end_t = self.clock.now() + RETURN_S
            self.telemetry.add(rec)
            self.completed += 1
            cb()

        self.clock.schedule_at(start + fn.compute_s, done)

    # ------------------------------------------------------------------
    def mean_memory_bytes(self) -> float:
        total = 0.0
        for node in self.nodes:
            if not node.mem_samples:
                continue
            samples = node.mem_samples
            t_end = self.clock.now()
            acc, last_t, last_v = 0.0, samples[0][0], samples[0][1]
            for t, v in samples[1:]:
                acc += last_v * (t - last_t)
                last_t, last_v = t, v
            acc += last_v * (t_end - last_t)
            total += acc / max(t_end - samples[0][0], 1e-9)
        return total


# ---------------------------------------------------------------------------
# workload generation (Poisson open-loop + MAF-style trace)
# ---------------------------------------------------------------------------


def poisson_arrivals(rate_per_s: float, duration_s: float, rng: random.Random) -> List[float]:
    t, out = 0.0, []
    while True:
        t += rng.expovariate(rate_per_s)
        if t >= duration_s:
            return out
        out.append(t)


def maf_like_trace(
    functions: List[str], duration_s: float, seed: int = 0,
    mean_rpm: float = 12.0,
) -> List[Tuple[float, str]]:
    """Azure-Functions-like trace: per-function Poisson with log-normal rate
    spread and hour-scale bursts (Shahrad et al.: most functions see a few
    to dozens of requests/minute)."""
    rng = random.Random(seed)
    events: List[Tuple[float, str]] = []
    for f in functions:
        rate = (mean_rpm / 60.0) * math.exp(rng.gauss(0.0, 0.8))
        burst_phase = rng.random() * duration_s
        t = 0.0
        while True:
            # burst modulation: 2x rate inside a 10% duty window
            mult = 2.0 if ((t + burst_phase) % 600.0) < 60.0 else 1.0
            t += rng.expovariate(rate * mult)
            if t >= duration_s:
                break
            events.append((t, f))
    events.sort()
    return events
