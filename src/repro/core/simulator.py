"""Virtual-time discrete-event twin of the runtime, for trace-scale
experiments (Figs 3, 10-14, 16, 17).

Runs the SAME policy decisions (SystemPolicy flags, ExitLadder stages,
read-only sharing, slot accounting, FCFS context pools) as the threaded
runtime, but with modeled durations (paper Table 2/4 profiles + fair-share
brokers) under a VirtualClock — two hours of MAF trace replay complete in
milliseconds, deterministically.

This module is the FACADE over the layered simulator package
(docs/simulator.md):

* engine — :mod:`repro.core.sim.kernel` (event heap) and
  :mod:`repro.core.sim.rng` (seeded streams);
* domain — :mod:`repro.core.sim.domain` (:class:`GPUNode`,
  :class:`SimInstance`, transfer-leg machines) and
  :mod:`repro.core.sim.invocations` (per-policy invocation lifecycles);
* policy — :mod:`repro.core.sim.policies` (admission + dispatch plugins,
  sharing the daemon's key formula and ``choose_node`` byte-for-byte).

Modeling choices (documented in DESIGN.md §2):
* GPU compute is FIFO (one kernel at a time) — consistent with the paper's
  Throughput_theo = T_period / T_comp definition;
* gpu_ctx creation = 285.1 ms (Table 4) and does not contend (paper §6.1:
  'context creation for function invocations does not interfere');
* db / PCIe paths are progressive-filling fair-share links (Fig 4's 34.9x
  contention emerges from these, not from a hard-coded factor).
"""
from __future__ import annotations

import random
import warnings
from typing import Dict, Iterable, List, Optional, Tuple

from repro.core.baselines import SystemPolicy, get_system
from repro.core.clock import VirtualClock
from repro.core.compute import (
    ComputePlane, empty_compute_stats, resolve_compute,
)
from repro.core.daemon import SCHEDULERS
from repro.core.faults import (
    BreakerConfig, CircuitBreaker, FaultPlan, SheddingConfig, node_pressure,
)
from repro.core.placement import (
    DISPATCH_POLICIES, PlacementControl, choose_node, resolve_autoscale,
)
from repro.core.sim.domain import (  # noqa: F401  (re-exported API)
    CONTAINER_S, CPU_CTX_S, GPU_CTX_S, RETURN_S, GPUNode, PendingReservation,
    SimFunction, SimInstance,
)
from repro.core.sim.invocations import (
    CallbackCompletion, Completion, DgsfInvocation, FixedInvocation,
    SageInvocation,
)
from repro.core.sim.kernel import EventKind
from repro.core.sim.metrics import AggregateTelemetry
from repro.core.sim.policies import dispatch_strategy
from repro.core.sim.rng import RngStreams
from repro.core.slowness import (
    QuarantineController, make_detector, resolve_hedging, resolve_quarantine,
)
from repro.core.telemetry import STAGES, InvocationRecord, Telemetry
from repro.core.transfer import DEFAULT_CHUNK_BYTES

# back-compat: pre-refactor code imported the private name
_PendingReservation = PendingReservation

# prototype stage dict copied into every fresh record (stages are empty at
# that point, so the bulk update equals the old per-key setdefault loop)
_STAGE_ZEROS = {s: 0.0 for s in STAGES}

# error-record prefix per failure class (docs/resilience.md); the prefixes
# are what telemetry.classify_error parses back out
_ERROR_PREFIX = {
    "data_load": "DataLoadError",
    "node_lost": "NodeLostError",
    "shed": "ShedError",
    "breaker": "BreakerOpenError",
    "timeout": "TimeoutError",
    "hedged": "HedgedError",
}

# MemoryLeak creep granularity (workload seconds between leak ticks)
_LEAK_TICK_S = 0.5


def _rec_done(rec: InvocationRecord) -> bool:
    """A record is resolved once ``end_t`` is stamped (records are born
    with ``end_t == 0.0``; completion and every failure path stamp it)."""
    return rec.end_t > 0.0


class _HedgePair:
    """One speculative duplicate in flight: the primary record, its hedge
    clone, and their invocation machines. The first twin to COMPLETE
    resolves the pair and cancels the other; a twin that *fails* while its
    sibling is still live is dropped silently (the logical request is
    still in flight — only the last-standing twin's failure counts)."""

    __slots__ = ("primary", "hedge", "machines", "resolved")

    def __init__(self, primary: InvocationRecord, hedge: InvocationRecord):
        self.primary = primary
        self.hedge = hedge
        self.machines: Dict[int, object] = {}
        self.resolved = False

    def twin(self, rec: InvocationRecord) -> InvocationRecord:
        return self.hedge if rec is self.primary else self.primary


class Simulator:
    """Drives a cluster of :class:`GPUNode`s through a submitted trace.

    ``record_mode`` selects the telemetry sink: ``"full"`` (default)
    retains every :class:`InvocationRecord` in a classic
    :class:`Telemetry`; ``"aggregate"`` streams records through
    :class:`~repro.core.sim.metrics.AggregateTelemetry` (O(1) memory —
    the million-invocation replay mode, where broker transfer history is
    also disabled)."""

    def __init__(self, system: str | SystemPolicy = "sage", *, n_nodes: int = 1,
                 capacity: int = 40 << 30, host_capacity: int = 125 << 30,
                 exit_ttl: float = 30.0, seed: int = 0,
                 loader_threads: int = 4, load_timeout_s: float = 600.0,
                 scheduler: str = "fifo", dispatch: str = "random",
                 transfer: str = "run_to_completion",
                 chunk_bytes: int = DEFAULT_CHUNK_BYTES,
                 record_mode: str = "full",
                 faults: Optional[FaultPlan] = None,
                 breaker: Optional[BreakerConfig] = None,
                 shedding: Optional[SheddingConfig] = None,
                 eviction: bool = False,
                 autoscale=None,
                 hedging=None, quarantine=None, compute=None):
        if dispatch not in DISPATCH_POLICIES:
            raise ValueError(
                f"unknown dispatch {dispatch!r}; use one of {DISPATCH_POLICIES}")
        if record_mode not in ("full", "aggregate"):
            raise ValueError(
                f"unknown record_mode {record_mode!r}; use 'full' or 'aggregate'")
        self.policy = get_system(system) if isinstance(system, str) else system
        self.dispatch = dispatch
        self._dispatcher = dispatch_strategy(dispatch)
        self.clock = VirtualClock()
        # static node-construction kwargs, kept for the dynamic pool's
        # add_node (scheduler/transfer are re-read from a live node so a
        # later set_scheduler/set_transfer carries over to joiners)
        self._node_kwargs = dict(
            capacity=capacity, host_capacity=host_capacity,
            exit_ttl=exit_ttl, loader_threads=loader_threads,
            load_timeout_s=load_timeout_s, chunk_bytes=chunk_bytes)
        self.nodes = [
            GPUNode(self.policy, self.clock, name=f"gpu{i}",
                    scheduler=scheduler, transfer=transfer,
                    **self._node_kwargs)
            for i in range(n_nodes)
        ]
        self._node_seq = n_nodes  # next gpu<i> id for add_node
        self.record_mode = record_mode
        if record_mode == "aggregate":
            self.telemetry = AggregateTelemetry(seed=seed)
            for node in self.nodes:  # no per-transfer history either
                node.db.keep_history = False
                node.pcie.keep_history = False
        else:
            self.telemetry = Telemetry()
        self.functions: Dict[str, SimFunction] = {}
        self.rng = RngStreams(seed)
        # root stream = random.Random(seed): bit-compatible with the
        # pre-kernel Simulator._rng that seeded §7.8 replays consume
        self._rng = self.rng.root
        self.completed = 0
        self.failed = 0
        # launched-but-unresolved invocations (the twin of the threaded
        # node's ``_inflight``): lets a manual drain on a sim WITHOUT
        # fault tracking (no faults/control plane — the active set is
        # never maintained there) prove whole-sim quiescence before the
        # teardown, instead of retiring over an invisible live invocation
        self.inflight = 0
        # resilience layer (docs/resilience.md). With every knob at its
        # default the whole layer is inert: no draw stream exists, no FAULT
        # event is scheduled, nodes skip active-set tracking, and the
        # seeded golden traces are bit-identical to the pre-fault kernel.
        self.faults = faults
        self.eviction = bool(eviction)
        self.shedding = shedding
        self._breaker_cfg = breaker
        self._breaker_overrides: Dict[str, BreakerConfig] = {}
        self.breakers: Dict[str, CircuitBreaker] = {}
        self._fault_draws = faults.make_draws() if faults is not None else None
        self.shed_count = 0
        self.breaker_rejections = 0
        self.node_lost_count = 0
        self.redispatches = 0
        # tail-tolerance layer (docs/resilience.md, "Gray failures"):
        # hedged redispatch + suspect-node quarantine over one shared
        # SlownessDetector. Both knobs default off — _slowness stays None,
        # the invocation machines skip their completion hook, and no timer
        # is ever scheduled, so seeded golden traces are bit-identical.
        self._hedging = resolve_hedging(hedging)
        self._quarantine_cfg = resolve_quarantine(quarantine)
        self._slowness = None
        self._quarantine: Optional[QuarantineController] = None
        self.hedges_launched = 0
        self.hedges_won = 0
        self.hedges_wasted = 0
        if self._hedging is not None or self._quarantine_cfg is not None:
            self._init_slowness()
        if faults is not None:
            for node in self.nodes:
                node.fault_tracking = True
            for t, action, spec in faults.events():
                self.clock.schedule_at(t, self._apply_fault, action, spec,
                                       kind=EventKind.FAULT)
        # placement control plane (docs/planner.md): planner + work
        # stealer + predictive autoscaler over a dynamic node pool. With
        # dispatch != "planned" and autoscale=None the whole layer is
        # inert (no control object, no extra events) — golden-trace safe.
        # shared compute plane (docs/compute.md): fractional SM slicing +
        # same-function batching. With compute=None the attribute stays
        # None, no plane is attached, and the FIFO compute arithmetic in
        # sim.invocations is byte-identical to the seed (golden-trace safe).
        self._compute = resolve_compute(compute)
        if self._compute is not None:
            for node in self.nodes:
                node.compute_plane = ComputePlane(self._compute)
        self.autoscale = resolve_autoscale(autoscale)
        self._control: Optional[PlacementControl] = None
        self._has_drains = False  # fast-path guard for dispatchable_nodes
        if dispatch == "planned" or self.autoscale is not None:
            self._ensure_control()

    @property
    def scheduler(self) -> str:
        return self.nodes[0].scheduler

    def set_scheduler(self, scheduler: str) -> None:
        """Switch loader/admission ordering ("fifo"|"edf"); applies to
        events queued after the call."""
        if scheduler not in SCHEDULERS:
            raise ValueError(
                f"unknown scheduler {scheduler!r}; use one of {SCHEDULERS}")
        for node in self.nodes:
            node.scheduler = scheduler

    def set_dispatch(self, dispatch: str) -> None:
        """Switch the cluster dispatch policy; applies to arrivals
        dispatched after the call."""
        if dispatch not in DISPATCH_POLICIES:
            raise ValueError(
                f"unknown dispatch {dispatch!r}; use one of {DISPATCH_POLICIES}")
        self.dispatch = dispatch
        self._dispatcher = dispatch_strategy(dispatch)
        if dispatch == "planned":
            self._ensure_control()

    def set_autoscale(self, autoscale) -> None:
        """Enable (or swap) predictive autoscaling mid-run — the spec
        adoption path (docs/planner.md). Creates the placement control
        plane on first use."""
        self.autoscale = resolve_autoscale(autoscale)
        if self.autoscale is None:
            if self._control is not None:
                self._control.set_autoscale(None)
            return
        self._ensure_control()
        self._control.set_autoscale(self.autoscale)

    def set_compute(self, compute) -> None:
        """Enable (or swap) the shared compute plane mid-run — the spec
        adoption path (docs/compute.md). Applies to compute stages entered
        after the call; ``"exclusive"``/None detaches the plane and
        restores the seed FIFO arithmetic."""
        self._compute = resolve_compute(compute)
        for node in self.nodes:
            node.compute_plane = (ComputePlane(self._compute)
                                  if self._compute is not None else None)
            node.compute_batches.clear()

    def compute_stats(self) -> Dict[str, object]:
        """Compute-plane counters aggregated over nodes (key set shared
        with the runtime gateway's ``compute_stats`` — docs/compute.md)."""
        if self._compute is None:
            return empty_compute_stats("exclusive", 0)
        out = empty_compute_stats("shared", self._compute.slices)
        for node in self.nodes:
            plane = node.compute_plane
            if plane is None:
                continue
            out["grants"] += plane.grants
            out["contended_grants"] += plane.contended_grants
            out["batches"] += plane.batches
            out["batched"] += plane.batched
        return out

    def set_hedging(self, hedging) -> None:
        """Enable (or swap) hedged redispatch mid-run — the spec adoption
        path (docs/resilience.md). Applies to arrivals launched after the
        call."""
        self._hedging = resolve_hedging(hedging)
        if self._hedging is not None:
            self._init_slowness()

    def set_quarantine(self, quarantine) -> None:
        """Enable (or swap) suspect-node quarantine mid-run — the spec
        adoption path (docs/resilience.md)."""
        self._quarantine_cfg = resolve_quarantine(quarantine)
        if self._quarantine_cfg is not None:
            self._init_slowness()
            if self._quarantine is None \
                    or self._quarantine.cfg != self._quarantine_cfg:
                self._quarantine = QuarantineController(
                    self._quarantine_cfg, self._slowness)
        else:
            self._quarantine = None

    def _init_slowness(self) -> None:
        """Build the shared detector (+ quarantine controller) once either
        tail-tolerance knob turns on; nodes get active-set tracking so a
        quarantine drain's idle check can see live invocations."""
        if self._slowness is None:
            self._slowness = make_detector(self._hedging,
                                           self._quarantine_cfg)
        if self._quarantine_cfg is not None and self._quarantine is None:
            self._quarantine = QuarantineController(
                self._quarantine_cfg, self._slowness)
        for node in self.nodes:
            node.fault_tracking = True

    def node_snapshot(self, node, fn_name: str):
        """One dispatch snapshot, health-graded when slowness detection is
        on (every snapshot-scoring call site routes through here so
        dispatch, the planner, and hedge targeting see the same grade)."""
        if self._slowness is None:
            return node.dispatch_snapshot(fn_name)
        return node.dispatch_snapshot(
            fn_name, health_score=self._slowness.health_score(node.name))

    @property
    def transfer(self) -> str:
        return self.nodes[0].arbiter.mode

    def set_transfer(self, transfer: str) -> None:
        """Switch the transfer mode ("run_to_completion"|"preemptive");
        applies to chunks advanced after the call."""
        for node in self.nodes:
            node.arbiter.set_mode(transfer)

    def preemption_count(self) -> int:
        """Total link preemptions across nodes (the twin of the daemon's
        ``stats["preemptions"]``)."""
        return sum(n.arbiter.preemptions for n in self.nodes)

    # ------------------------------------------------------------------
    def register(self, fn: SimFunction) -> None:
        self.functions[fn.name] = fn
        for node in self.nodes:
            self._register_on_node(node, fn)
        if self._control is not None:
            self._control.register_function(fn.name,
                                            fn.ro_bytes + fn.ctx_bytes)

    def _register_on_node(self, node, fn: SimFunction) -> None:
        node.instances[fn.name] = []
        node.ro_state[fn.name] = "none"
        node.ro_ready_cbs[fn.name] = []
        if self.policy.pre_created_contexts:
            # DGSF pins contexts permanently; with many functions the
            # pool must shrink to fit (4 x 414 MB x 30 fns > 40 GB)
            n = self.policy.pre_created_contexts
            while n > 1 and node.used + n * fn.ctx_bytes > 0.85 * node.capacity:
                n -= 1
            node.dgsf_free[fn.name] = n
            node.dgsf_queue[fn.name] = []
            node.used += n * fn.ctx_bytes  # permanent DGSF overhead

    def retire(self, fn_name: str) -> None:
        """Unregister a function: new arrivals for it raise KeyError and
        the planner frees its planned share (a churn signal —
        docs/planner.md). Resident state ages out via the exit ladder."""
        self.functions.pop(fn_name, None)
        if self._control is not None:
            self._control.retire_function(fn_name)

    def submit(self, fn_name: str, t: float, *,
               deadline_s: Optional[float] = None, priority: int = 0,
               request_id: Optional[str] = None,
               max_retries: Optional[int] = None) -> None:
        self.clock.schedule_at(
            t, self._arrive, fn_name, t, deadline_s, priority,
            request_id, max_retries, kind=EventKind.ARRIVAL)

    def replay_stream(self, events: Iterable) -> None:
        """Feed a (possibly huge / lazy) time-ordered arrival stream with
        at most ONE feeder event on the heap at a time — the
        million-invocation replay path, which never pre-schedules the whole
        trace. ``events`` yields :class:`~repro.api.workload.Arrival`-likes
        (``t``/``function``/``deadline_s``/``priority`` attributes) or
        ``(t, function)`` tuples; times must be non-decreasing."""
        self._feed_next(iter(events))

    def _feed_next(self, it) -> None:
        nxt = next(it, None)
        if nxt is None:
            return
        if isinstance(nxt, tuple):
            t, fn_name = nxt[0], nxt[1]
            deadline_s = nxt[2] if len(nxt) > 2 else None
            priority = nxt[3] if len(nxt) > 3 else 0
        else:
            t, fn_name = nxt.t, nxt.function
            deadline_s = getattr(nxt, "deadline_s", None)
            priority = getattr(nxt, "priority", None)
        self.clock.schedule_at(t, self._feed_fire, it, t, fn_name,
                               deadline_s, 0 if priority is None else priority,
                               kind=EventKind.FEED)

    def _feed_fire(self, it, t: float, fn_name: str,
                   deadline_s: Optional[float], priority: int) -> None:
        self._arrive(fn_name, t, deadline_s, priority, None, None)
        self._feed_next(it)

    def run(self, until: float = float("inf")) -> None:
        self.clock.run_until(until)

    # ------------------------------------------------------------------
    def _dispatch_node(self, fn_name: str):
        """(node, residency tier at dispatch) for one arrival. Single-node
        sims have no dispatch decision (tier None keeps their records
        identical to the single-node runtime's). ``"random"`` consumes the
        same seeded ``rng.choice`` stream as the pre-dispatch simulator, so
        seeded §7.8 replays are unchanged."""
        if len(self.nodes) == 1:
            return self.nodes[0], None
        return self._dispatcher.pick(self, fn_name)

    def _arrive(self, fn_name: str, arrival_t: float,
                deadline_s: Optional[float] = None, priority: int = 0,
                request_id: Optional[str] = None,
                max_retries: Optional[int] = None) -> None:
        fn = self.functions[fn_name]
        injected = False
        jitter_s = 0.0
        if self._fault_draws is not None:
            # draw FIRST, unconditionally: the stream position tracks
            # arrival counts (identical across drivers) — a shed/breaker
            # rejection must not shift later arrivals' draws. The jitter
            # draw rides its own {seed}:jitter:{fn} streams, so it never
            # perturbs the poison stream either way.
            injected = self._fault_draws.draw(fn_name, arrival_t)
            jitter_s = self._fault_draws.jitter(fn_name, arrival_t)
        if self.shedding is not None:
            p = self._shed_pressure()
            if self.shedding.should_shed(p, priority):
                self.shed_count += 1
                self._reject(fn, arrival_t, deadline_s, priority,
                             request_id, max_retries, "shed",
                             f"shed at pressure {p:.2f}")
                return
        # shed runs BEFORE the breaker: allow() claims half-open probe
        # slots, and a later rejection would leak the claimed slot
        if self._breaker_cfg is not None or self._breaker_overrides:
            br = self._breaker_for(fn_name)
            if br is not None and not br.allow():
                self.breaker_rejections += 1
                self._reject(fn, arrival_t, deadline_s, priority,
                             request_id, max_retries, "breaker",
                             "circuit open")
                return
        if self._control is not None:
            # control-plane arrivals: forecast accounting + the control
            # tick (autoscale/replan/drain-finalize) ride every arrival,
            # so an idle sim schedules no extra events and still halts
            self._control.note_arrival(fn_name)
            self._control_tick(arrival_t)
            if self.dispatch == "planned" and len(self.nodes) > 1:
                self._planned_arrive(fn, arrival_t, deadline_s, priority,
                                     request_id, max_retries, injected,
                                     jitter_s)
                return
        node, tier = self._dispatch_node(fn_name)
        rec = self._make_record(fn_name, arrival_t, deadline_s, priority,
                                request_id, max_retries, node, tier)
        self._launch(node, fn, rec, injected, jitter_s)

    def _make_record(self, fn_name: str, arrival_t: float,
                     deadline_s: Optional[float], priority: int,
                     request_id: Optional[str], max_retries: Optional[int],
                     node, tier) -> InvocationRecord:
        rec = InvocationRecord(
            request_id=request_id or f"{fn_name}@{arrival_t:.4f}",
            function=fn_name,
            system=self.policy.name, arrival_t=arrival_t,
            start_t=self.clock.now(),
            deadline_s=deadline_s, priority=priority,
            max_retries=max_retries,
            node_id=node.name, dispatch_tier=tier,
        )
        # canonical stage keys up front (stages a policy path skips read as
        # 0.0) — keeps the record structure identical to the threaded
        # runtime's, which the parity test in tests/test_api.py guards
        rec.stages.update(_STAGE_ZEROS)
        return rec

    def _launch(self, node, fn: SimFunction, rec: InvocationRecord,
                injected: bool, jitter_s: float = 0.0) -> None:
        self.inflight += 1
        if not node.healthy:
            # dispatch landed on a dead node (eviction off, or nothing
            # healthy left to evict onto): fail typed, never enqueue
            self.node_lost_count += 1
            self._fail_record(fn, rec, f"node {node.name} is down",
                              cls="node_lost")
            return
        machine = self._start_invocation(node, fn, rec, injected, jitter_s)
        if (self._hedging is not None
                and getattr(rec, "_hedge_pair", None) is None
                and self.policy.name.startswith("sage")):
            est = self._slowness.estimate(fn.name,
                                          self._hedging.min_samples)
            if est is not None:
                self.clock.schedule(est * self._hedging.delay_factor,
                                    self._hedge_fire, fn, rec, machine,
                                    kind=EventKind.TIMER)

    # ------------------------------------------------------------------
    # planned dispatch + work stealing (docs/planner.md)
    # ------------------------------------------------------------------
    def _planned_arrive(self, fn: SimFunction, arrival_t: float,
                        deadline_s: Optional[float], priority: int,
                        request_id: Optional[str],
                        max_retries: Optional[int], injected: bool,
                        jitter_s: float = 0.0) -> None:
        nodes = self.dispatchable_nodes()
        snaps = [self.node_snapshot(n, fn.name) for n in nodes]
        decision = self._control.route(fn.name, snaps)
        if decision[0] == "board":
            # queued-but-unstarted: the planned home (and every pick
            # alternative) is above the steal watermark, so the arrival
            # parks on the steal board; after board_delay_s the stealer
            # re-routes it with fresh snapshots (a landing away from the
            # home is a steal and charges the redispatch budget)
            home = nodes[decision[1]]
            self.clock.schedule_at(
                self.clock.now() + self._control.planner.cfg.board_delay_s,
                self._board_fire, fn, arrival_t, deadline_s, priority,
                request_id, max_retries, injected, home.name, jitter_s,
                kind=EventKind.TIMER)
            return
        _, idx, _hit = decision
        rec = self._make_record(fn.name, arrival_t, deadline_s, priority,
                                request_id, max_retries, nodes[idx],
                                snaps[idx].ro_tier)
        self._launch(nodes[idx], fn, rec, injected, jitter_s)

    def _board_fire(self, fn: SimFunction, arrival_t: float,
                    deadline_s: Optional[float], priority: int,
                    request_id: Optional[str], max_retries: Optional[int],
                    injected: bool, home_id: str,
                    jitter_s: float = 0.0) -> None:
        nodes = self.dispatchable_nodes()
        snaps = [self.node_snapshot(n, fn.name) for n in nodes]
        stole = False
        if max_retries is None or max_retries > 0:
            idx, stole = self._control.reroute(fn.name, snaps, home_id)
        else:
            # no redispatch budget: the boarded work must start on its
            # original home (same rule as crash re-dispatch fail-fast)
            idx = next((i for i, s in enumerate(snaps)
                        if s.node_id == home_id), None)
            if idx is None:  # home drained/evicted while boarded
                idx, _ = self._control.reroute(fn.name, snaps, home_id)
        rec = self._make_record(fn.name, arrival_t, deadline_s, priority,
                                request_id, max_retries, nodes[idx],
                                snaps[idx].ro_tier)
        if stole:
            rec.redispatches += 1
            self.redispatches += 1
        self._launch(nodes[idx], fn, rec, injected, jitter_s)

    def _control_tick(self, now: float) -> None:
        add, drain_ids = self._control.maybe_tick(now)
        for _ in range(add):
            self.add_node()
        for nid in drain_ids:
            self.drain_node(nid)
        if self._has_drains:
            self._try_finalize_drains()

    def _start_invocation(self, node, fn: SimFunction,
                          rec: InvocationRecord,
                          injected: bool = False, jitter_s: float = 0.0):
        """Instantiate the policy's invocation machine (fresh arrival or
        post-crash re-dispatch — the latter reuses the record, so latency
        spans the whole arrival-to-final-finish window). Returns the
        machine so the hedging layer can cancel a losing twin."""
        if self.policy.name.startswith("sage"):
            return SageInvocation(self, node, fn, rec, injected,
                                  jitter_s=jitter_s)
        if self.policy.pre_created_contexts:
            return DgsfInvocation(self, node, fn, rec, injected,
                                  jitter_s=jitter_s)
        return FixedInvocation(self, node, fn, rec, injected,
                               jitter_s=jitter_s)

    # ------------------------------------------------------------------
    # dynamic node pool (docs/planner.md)
    # ------------------------------------------------------------------
    def _ensure_control(self) -> None:
        if self._control is not None:
            return
        self._control = PlacementControl(
            [n.name for n in self.nodes], autoscale=self.autoscale,
            now=self.clock.now())
        for node in self.nodes:
            # active-invocation tracking feeds the drain idle check (the
            # same set crash re-dispatch uses)
            node.fault_tracking = True
        for fn in self.functions.values():
            self._control.register_function(fn.name,
                                            fn.ro_bytes + fn.ctx_bytes)

    def add_node(self) -> GPUNode:
        """Provision one cold node into the pool; every registered
        function is registered on it and dispatch may target it from the
        next arrival."""
        name = f"gpu{self._node_seq}"
        self._node_seq += 1
        live = next((n for n in self.nodes if not n.retired), None)
        node = GPUNode(
            self.policy, self.clock, name=name,
            scheduler=live.scheduler if live else "fifo",
            transfer=live.arbiter.mode if live else "run_to_completion",
            **self._node_kwargs)
        if self.record_mode == "aggregate":
            node.db.keep_history = False
            node.pcie.keep_history = False
        if self._compute is not None:
            node.compute_plane = ComputePlane(self._compute)
        if self.faults is not None or self._control is not None \
                or self._slowness is not None:
            node.fault_tracking = True
        for fn in self.functions.values():
            self._register_on_node(node, fn)
        self.nodes.append(node)
        if self._control is not None:
            self._control.node_provisioned(name, self.clock.now())
        return node

    def drain_node(self, name: str) -> None:
        """Start a graceful drain: the node takes no new placements and
        retires (exact teardown, node-seconds stop accruing) once its
        in-flight work completes."""
        node = self._node_by_name(name)
        if node.draining or node.retired:
            return
        node.draining = True
        self._has_drains = True
        if self._control is not None:
            self._control.node_draining(name)
        self._try_finalize_drains()

    def _try_finalize_drains(self) -> None:
        for node in self.nodes:
            if not (node.draining and not node.retired and node.is_idle()):
                continue
            if not node.fault_tracking and self.inflight:
                # the active set was never maintained on this node (manual
                # drain, no faults/control plane), so per-node idleness
                # cannot see a live invocation mid-setup or mid-compute —
                # only whole-sim quiescence proves the node is quiet
                continue
            node.finalize_drain()
            if self._control is not None:
                self._control.node_retired(node.name, self.clock.now())

    def placement_stats(self) -> Optional[Dict]:
        """Planner/stealer/autoscaler counters + the node-count timeline
        (None unless the control plane is on — docs/planner.md)."""
        if self._control is None:
            return None
        if self._has_drains:
            self._try_finalize_drains()
        return self._control.stats(self.clock.now())

    # ------------------------------------------------------------------
    # resilience control layer (docs/resilience.md)
    # ------------------------------------------------------------------
    def dispatchable_nodes(self) -> List[GPUNode]:
        """Nodes dispatch may target. Draining/retired nodes leave the
        candidate set (docs/planner.md); with ``eviction`` on, dead nodes
        are drained out while any healthy node remains. When nothing is
        draining and eviction is off this returns the SAME list object,
        so the seeded ``rng.choice`` stream is untouched."""
        nodes = self.nodes
        if self._has_drains:
            up = [n for n in nodes if not (n.draining or n.retired)]
            nodes = up or nodes
        if not self.eviction:
            return nodes
        healthy = [n for n in nodes if n.healthy]
        return healthy or nodes

    def set_function_breaker(self, fn_name: str, cfg: BreakerConfig) -> None:
        """Per-function breaker override (wins over the constructor-wide
        config); applies from the next arrival."""
        self._breaker_overrides[fn_name] = cfg
        self.breakers.pop(fn_name, None)

    def _breaker_for(self, fn_name: str) -> Optional[CircuitBreaker]:
        br = self.breakers.get(fn_name)
        if br is None:
            cfg = self._breaker_overrides.get(fn_name, self._breaker_cfg)
            if cfg is None:
                return None
            br = self.breakers[fn_name] = CircuitBreaker(cfg, self.clock.now)
        return br

    def _note_result(self, fn_name: str, ok: bool) -> None:
        br = self.breakers.get(fn_name)
        if br is not None:
            br.record(ok)

    def _shed_pressure(self) -> float:
        """Mean normalized loader pressure over healthy nodes (the shared
        :func:`~repro.core.faults.node_pressure` formula)."""
        nodes = [n for n in self.nodes
                 if n.healthy and not (n.draining or n.retired)] or self.nodes
        sat = self.shedding.saturation
        total = 0.0
        for n in nodes:
            total += node_pressure(n.pending_admission_count(),
                                   n.loader_queue_depth(),
                                   n.loader_threads, sat)
        return total / len(nodes)

    def _reject(self, fn: SimFunction, arrival_t: float,
                deadline_s: Optional[float], priority: int,
                request_id: Optional[str], max_retries: Optional[int],
                cls: str, reason: str) -> None:
        """Admission-gate rejection (shed / breaker): resolves immediately
        with a typed error record; never reaches a node and never feeds
        the breaker window (a breaker chewing on its own rejections would
        latch open forever)."""
        rec = InvocationRecord(
            request_id=request_id or f"{fn.name}@{arrival_t:.4f}",
            function=fn.name,
            system=self.policy.name, arrival_t=arrival_t,
            start_t=self.clock.now(),
            deadline_s=deadline_s, priority=priority,
            max_retries=max_retries,
        )
        rec.stages.update(_STAGE_ZEROS)
        self._fail_record(fn, rec, reason, cls=cls)

    def _node_lost(self, inv) -> None:
        """A live invocation's node crashed under it. With eviction on and
        a healthy node available, re-dispatch the SAME record through the
        normal dispatch path while budget remains (``max_retries=None`` =
        unlimited, matching the daemon's OOM-retry semantics; ``0`` =
        fail-fast); otherwise fail typed ``node_lost``."""
        fn, rec = inv.fn, inv.rec
        self.node_lost_count += 1
        pair = getattr(rec, "_hedge_pair", None)
        if pair is not None and not _rec_done(pair.twin(rec)):
            # the hedge twin is still live elsewhere: don't burn budget
            # re-dispatching this copy — drop it (the twin carries the
            # logical request; _fail_record does the dropped marking)
            self._fail_record(fn, rec, f"node {inv.node.name} crashed",
                              cls="node_lost")
            return
        if self.eviction and any(n.healthy for n in self.nodes) \
                and (rec.max_retries is None
                     or rec.redispatches < rec.max_retries):
            rec.redispatches += 1
            self.redispatches += 1
            node2, tier = self._dispatch_node(fn.name)
            rec.node_id = node2.name
            rec.dispatch_tier = tier
            # the injected-fault draw was consumed by the first attempt
            self._start_invocation(node2, fn, rec, False)
            return
        self._fail_record(fn, rec, f"node {inv.node.name} crashed",
                          cls="node_lost")

    # ------------------------------------------------------------------
    # tail tolerance: hedged redispatch + quarantine (docs/resilience.md)
    # ------------------------------------------------------------------
    def _hedge_fire(self, fn: SimFunction, rec: InvocationRecord,
                    machine) -> None:
        """The hedge timer elapsed: the invocation ran past its learned
        latency quantile. Launch ONE speculative duplicate on the best
        non-suspect node (first completion wins), charging the duplicate
        to the request's ``max_retries`` budget."""
        if _rec_done(rec) or getattr(rec, "_hedge_pair", None) is not None:
            return
        if rec.max_retries is not None \
                and rec.redispatches >= rec.max_retries:
            return
        cands = [n for n in self.dispatchable_nodes()
                 if n.healthy and n.name != rec.node_id
                 and not self._slowness.is_suspect(n.name)]
        if not cands:
            return
        snaps = [self.node_snapshot(n, fn.name) for n in cands]
        node2 = cands[choose_node("locality", snaps)]
        rec.redispatches += 1
        self.redispatches += 1
        clone = self._make_record(
            fn.name, rec.arrival_t, rec.deadline_s, rec.priority,
            rec.request_id, rec.max_retries, node2,
            node2.residency(fn.name)[0])
        clone.redispatches = rec.redispatches
        pair = _HedgePair(rec, clone)
        rec._hedge_pair = pair
        clone._hedge_pair = pair
        pair.machines[id(rec)] = machine
        self.hedges_launched += 1
        self.inflight += 1
        # the injected-fault/jitter draws were consumed by the primary
        pair.machines[id(clone)] = self._start_invocation(
            node2, fn, clone, False)

    def _tail_complete(self, node, fn: SimFunction,
                       rec: InvocationRecord) -> None:
        """Success hook from the invocation machines (only wired when the
        detector exists): feed the latency profiles, resolve a hedge pair
        (cancel the losing twin), and drive the quarantine machine."""
        self._slowness.observe_record(node.name, fn.name, rec.stages,
                                      rec.duration)
        pair = getattr(rec, "_hedge_pair", None)
        if pair is not None and not pair.resolved:
            pair.resolved = True
            if rec is pair.hedge:
                self.hedges_won += 1      # the duplicate beat the primary
            else:
                self.hedges_wasted += 1   # primary finished first anyway
            twin = pair.twin(rec)
            if not _rec_done(twin):
                # censored straggler evidence: the loser held its node at
                # least this long without finishing. Cancelled records
                # never complete, so once hedging starts winning the
                # suspicion signal would otherwise starve and quarantine
                # could never trigger on the node being hedged around.
                elapsed = self.clock.now() - twin.start_t
                self._slowness.observe(twin.node_id, "compute", elapsed)
                m = pair.machines.get(id(twin))
                if m is not None:
                    m.hedge_cancel()
                # the loser's node is judged on the censored sample too —
                # it never completes anything once hedging wins, so the
                # quarantine machine would otherwise never see it
                self._quarantine_note(twin.node_id, elapsed)
        self._quarantine_note(node.name, rec.stages.get("compute", 0.0))

    def _quarantine_note(self, node_name: str, compute_s: float) -> None:
        """Feed one node observation into the quarantine state machine and
        execute whatever action it returns through the drain/probe
        machinery."""
        if self._quarantine is None:
            return
        node = self._node_by_name(node_name)
        if node.retired or node.draining:
            return
        action = self._quarantine.note_completion(
            node_name, self.clock.now(), compute_s)
        if action == "quarantine":
            self.drain_node(node_name)
            probe_at = self._quarantine.next_probe_at()
            if probe_at is not None:
                self.clock.schedule_at(probe_at, self._probe_fire,
                                       kind=EventKind.TIMER)
        # "readmit" is resolved inside the controller; a node retired on
        # a slow canary is drained again, this time for good
        elif action == "retire":
            self.drain_node(node_name)

    def _probe_fire(self) -> None:
        """A quarantine cooldown elapsed: readmit each due node cold, in
        probation — its next ``canary_count`` completions are the canary
        set (half-open probing on live traffic)."""
        if self._quarantine is None:
            return
        for nid in self._quarantine.due_probes(self.clock.now()):
            self._readmit_node(nid)

    def _readmit_node(self, name: str) -> None:
        """Bring a drained/retired node back into the pool, cold — the
        same restore + DGSF re-pin path a post-crash restart runs."""
        node = self._node_by_name(name)
        if node.draining and not node.retired and node.is_idle():
            node.finalize_drain()  # still mid-drain: finish it first
        node.draining = False
        node.retired = False
        self._has_drains = any(n.draining or n.retired for n in self.nodes)
        node.restore()
        if self.policy.pre_created_contexts:
            for fn in self.functions.values():
                n = self.policy.pre_created_contexts
                while n > 1 and node.used + n * fn.ctx_bytes \
                        > 0.85 * node.capacity:
                    n -= 1
                node.dgsf_free[fn.name] = n
                node.dgsf_queue[fn.name] = []
                node.used += n * fn.ctx_bytes
        if self._control is not None:
            self._control.node_provisioned(node.name, self.clock.now())

    def _node_by_name(self, name: str) -> GPUNode:
        for n in self.nodes:
            if n.name == name:
                return n
        raise ValueError(f"unknown node {name!r} in fault plan")

    def _fault_nodes(self, name: Optional[str]) -> List[GPUNode]:
        return self.nodes if name is None else [self._node_by_name(name)]

    def _apply_fault(self, action: str, spec) -> None:
        """One scheduled fault event (EventKind.FAULT) firing."""
        if action == "crash":
            self._node_by_name(spec.node).crash()
        elif action == "restart":
            node = self._node_by_name(spec.node)
            node.restore()
            if self.policy.pre_created_contexts:
                # re-pin DGSF's permanent context pools, replaying the
                # same shrink-to-fit loop register() ran on the cold node
                for fn in self.functions.values():
                    n = self.policy.pre_created_contexts
                    while n > 1 and node.used + n * fn.ctx_bytes \
                            > 0.85 * node.capacity:
                        n -= 1
                    node.dgsf_free[fn.name] = n
                    node.dgsf_queue[fn.name] = []
                    node.used += n * fn.ctx_bytes
        elif action in ("degrade_on", "degrade_off"):
            for node in self._fault_nodes(spec.node):
                broker = node.db if spec.link == "db" else node.pcie
                if action == "degrade_on":
                    broker.apply_degradation(spec.factor)
                else:
                    broker.clear_degradation(spec.factor)
        elif action == "db_down":
            for node in self._fault_nodes(spec.node):
                node.db_down = True
        elif action == "db_up":
            for node in self._fault_nodes(spec.node):
                node.db_down = False
        elif action in ("slow_on", "slow_off"):
            # gray failure: the node stays healthy but everything on it
            # runs ``factor`` slower — kernels via slow_factor, transfers
            # via a symmetric degradation on both of its links
            node = self._node_by_name(spec.node)
            if action == "slow_on":
                node.slow_factor *= spec.factor
                node.db.apply_degradation(spec.factor)
                node.pcie.apply_degradation(spec.factor)
            else:
                node.slow_factor /= spec.factor
                node.db.clear_degradation(spec.factor)
                node.pcie.clear_degradation(spec.factor)
        elif action == "leak_on":
            node = self._node_by_name(spec.node)
            until = (spec.at_s + spec.duration_s
                     if spec.duration_s is not None else float("inf"))
            self._leak_tick(node, spec, until)
        elif action == "leak_off":
            self._node_by_name(spec.node).reclaim_leak()

    def _leak_tick(self, node, spec, until: float) -> None:
        """One MemoryLeak creep step: ``device_used`` rises with no owner
        every ``_LEAK_TICK_S`` while the window is open (a crash/teardown
        zeroes the leak and the healthy-check stops the chain)."""
        now = self.clock.now()
        if now >= until or not node.healthy or node.retired:
            return
        node.leak(int(spec.rate_bps * _LEAK_TICK_S))
        self.clock.schedule(_LEAK_TICK_S, self._leak_tick, node, spec,
                            until, kind=EventKind.FAULT)

    def resilience_stats(self) -> Dict[str, object]:
        """Control-layer counters (the sim twin of the runtime gateway's
        ``resilience_stats``)."""
        q = self._quarantine.stats() if self._quarantine is not None \
            else {"quarantines": 0, "readmits": 0}
        return {
            "shed": self.shed_count,
            "breaker_rejected": self.breaker_rejections,
            "node_lost": self.node_lost_count,
            "redispatches": self.redispatches,
            "node_crashes": sum(n.crashes for n in self.nodes),
            "node_drains": sum(1 for n in self.nodes
                               if n.draining or n.retired),
            "breaker_states": {f: b.state for f, b in self.breakers.items()},
            "hedges_launched": self.hedges_launched,
            "hedges_won": self.hedges_won,
            "hedges_wasted": self.hedges_wasted,
            "quarantines": q["quarantines"],
            "readmits": q["readmits"],
        }

    # ------------------------------------------------------------------
    def _fail_record(self, fn: SimFunction, rec: InvocationRecord,
                     reason: str, cls: str = "data_load") -> None:
        """Shared failure bookkeeping (the twin of ``Handle.wait()`` raising
        ``DataLoadError``): the invocation resolves with a typed error
        record instead of waiting forever. All policy paths go through
        here so the error-record format stays uniform. ``cls`` picks the
        error class/prefix (docs/resilience.md); admission-gate classes
        (shed/breaker) never feed the breaker window.

        Hedge-aware: a cancelled hedge loser (``cls == "hedged"``), or a
        twin that genuinely failed while its sibling is still live, is
        marked ``dropped`` — it never counts as a failure, never feeds the
        breaker, and ``slo_by_priority()``/``error_counts()`` skip it, so
        one logical request yields exactly one counted outcome."""
        dropped = cls == "hedged"
        if not dropped:
            pair = getattr(rec, "_hedge_pair", None)
            if pair is not None and not _rec_done(pair.twin(rec)):
                dropped = True  # the twin still carries the request
        if not dropped:
            self.failed += 1
        if rec.node_id:  # launched (a gate rejection never reached a node)
            self.inflight -= 1
        rec.dropped = dropped
        rec.error = f"{_ERROR_PREFIX.get(cls, 'DataLoadError')}: {fn.name}: {reason}"
        rec.error_class = cls
        rec.end_t = self.clock.now()
        self.telemetry.add(rec)
        if not dropped and self.breakers and cls not in ("shed", "breaker"):
            self._note_result(fn.name, False)

    # ------------------------------------------------------------------
    # thin wrappers kept for pre-refactor callers
    # ------------------------------------------------------------------
    def _finish(self, node, fn, rec, inst, release_bytes, extra_done=None):
        Completion(self, node, fn, rec, inst, release_bytes, extra_done)

    def _finish_with_cb(self, node, fn, rec, cb):
        CallbackCompletion(self, node, fn, rec, cb)

    # exit-ladder stage hooks shared by every SAGE instance on a node
    # (installed by sim.invocations.sage_instance)
    def _sage_demote(self, node, inst):
        if inst.has_ro_device:
            inst.has_ro_device = False
            inst.has_ro_host = True
            node.ro_state[inst.fn.name] = "host"
            node.touch_host(inst.fn.name)
            node.release(inst.fn.ro_bytes)

    def _sage_drop_ctx(self, node, inst):
        if inst.has_ctx:
            inst.has_ctx = False
            node.release(inst.fn.ctx_bytes)

    def _sage_drop_host(self, node, inst):
        inst.has_ro_host = False
        if node.ro_state[inst.fn.name] == "host":
            node.ro_state[inst.fn.name] = "none"
        if node.ro_state[inst.fn.name] == "none":
            node.drop_host_resident(inst.fn.name)

    # ------------------------------------------------------------------
    def mean_memory_bytes(self) -> float:
        """Cluster-total time-weighted mean device occupancy (streaming
        accumulators on each node — no sample list is retained)."""
        t_end = self.clock.now()
        total = 0.0
        for node in self.nodes:
            m = node.mean_memory_bytes(t_end)
            if m is not None:
                total += m
        return total


# ---------------------------------------------------------------------------
# deprecated aliases: the canonical trace generators moved to
# repro.api.workload (imported lazily — repro.api imports this module)
# ---------------------------------------------------------------------------


def poisson_arrivals(rate_per_s: float, duration_s: float,
                     rng: random.Random) -> List[float]:
    """Deprecated alias for :func:`repro.api.workload.poisson_arrivals`."""
    warnings.warn(
        "repro.core.simulator.poisson_arrivals moved to "
        "repro.api.workload.poisson_arrivals",
        DeprecationWarning, stacklevel=2)
    from repro.api.workload import poisson_arrivals as _impl
    return _impl(rate_per_s, duration_s, rng)


def maf_like_trace(
    functions: List[str], duration_s: float, seed: int = 0,
    mean_rpm: float = 12.0,
) -> List[Tuple[float, str]]:
    """Deprecated alias for :func:`repro.api.workload.maf_like_trace`."""
    warnings.warn(
        "repro.core.simulator.maf_like_trace moved to "
        "repro.api.workload.maf_like_trace",
        DeprecationWarning, stacklevel=2)
    from repro.api.workload import maf_like_trace as _impl
    return _impl(functions, duration_s, seed=seed, mean_rpm=mean_rpm)
