"""Virtual-time discrete-event twin of the runtime, for trace-scale
experiments (Figs 3, 10-14, 16, 17).

Runs the SAME policy decisions (SystemPolicy flags, ExitLadder stages,
read-only sharing, slot accounting, FCFS context pools) as the threaded
runtime, but with modeled durations (paper Table 2/4 profiles + fair-share
brokers) under a VirtualClock — two hours of MAF trace replay complete in
milliseconds, deterministically.

This module is the FACADE over the layered simulator package
(docs/simulator.md):

* engine — :mod:`repro.core.sim.kernel` (event heap) and
  :mod:`repro.core.sim.rng` (seeded streams);
* domain — :mod:`repro.core.sim.domain` (:class:`GPUNode`,
  :class:`SimInstance`, transfer-leg machines) and
  :mod:`repro.core.sim.invocations` (per-policy invocation lifecycles);
* policy — :mod:`repro.core.sim.policies` (admission + dispatch plugins,
  sharing the daemon's key formula and ``choose_node`` byte-for-byte).

Modeling choices (documented in DESIGN.md §2):
* GPU compute is FIFO (one kernel at a time) — consistent with the paper's
  Throughput_theo = T_period / T_comp definition;
* gpu_ctx creation = 285.1 ms (Table 4) and does not contend (paper §6.1:
  'context creation for function invocations does not interfere');
* db / PCIe paths are progressive-filling fair-share links (Fig 4's 34.9x
  contention emerges from these, not from a hard-coded factor).
"""
from __future__ import annotations

import random
import warnings
from typing import Dict, Iterable, List, Optional, Tuple

from repro.core.baselines import SystemPolicy, get_system
from repro.core.clock import VirtualClock
from repro.core.daemon import SCHEDULERS
from repro.core.dispatch import DISPATCH_POLICIES
from repro.core.sim.domain import (  # noqa: F401  (re-exported API)
    CONTAINER_S, CPU_CTX_S, GPU_CTX_S, RETURN_S, GPUNode, PendingReservation,
    SimFunction, SimInstance,
)
from repro.core.sim.invocations import (
    CallbackCompletion, Completion, DgsfInvocation, FixedInvocation,
    SageInvocation,
)
from repro.core.sim.kernel import EventKind
from repro.core.sim.metrics import AggregateTelemetry
from repro.core.sim.policies import dispatch_strategy
from repro.core.sim.rng import RngStreams
from repro.core.telemetry import STAGES, InvocationRecord, Telemetry
from repro.core.transfer import DEFAULT_CHUNK_BYTES

# back-compat: pre-refactor code imported the private name
_PendingReservation = PendingReservation

# prototype stage dict copied into every fresh record (stages are empty at
# that point, so the bulk update equals the old per-key setdefault loop)
_STAGE_ZEROS = {s: 0.0 for s in STAGES}


class Simulator:
    """Drives a cluster of :class:`GPUNode`s through a submitted trace.

    ``record_mode`` selects the telemetry sink: ``"full"`` (default)
    retains every :class:`InvocationRecord` in a classic
    :class:`Telemetry`; ``"aggregate"`` streams records through
    :class:`~repro.core.sim.metrics.AggregateTelemetry` (O(1) memory —
    the million-invocation replay mode, where broker transfer history is
    also disabled)."""

    def __init__(self, system: str | SystemPolicy = "sage", *, n_nodes: int = 1,
                 capacity: int = 40 << 30, host_capacity: int = 125 << 30,
                 exit_ttl: float = 30.0, seed: int = 0,
                 loader_threads: int = 4, load_timeout_s: float = 600.0,
                 scheduler: str = "fifo", dispatch: str = "random",
                 transfer: str = "run_to_completion",
                 chunk_bytes: int = DEFAULT_CHUNK_BYTES,
                 record_mode: str = "full"):
        if dispatch not in DISPATCH_POLICIES:
            raise ValueError(
                f"unknown dispatch {dispatch!r}; use one of {DISPATCH_POLICIES}")
        if record_mode not in ("full", "aggregate"):
            raise ValueError(
                f"unknown record_mode {record_mode!r}; use 'full' or 'aggregate'")
        self.policy = get_system(system) if isinstance(system, str) else system
        self.dispatch = dispatch
        self._dispatcher = dispatch_strategy(dispatch)
        self.clock = VirtualClock()
        self.nodes = [
            GPUNode(self.policy, self.clock, capacity=capacity,
                    host_capacity=host_capacity,
                    exit_ttl=exit_ttl, name=f"gpu{i}",
                    loader_threads=loader_threads, load_timeout_s=load_timeout_s,
                    scheduler=scheduler, transfer=transfer,
                    chunk_bytes=chunk_bytes)
            for i in range(n_nodes)
        ]
        self.record_mode = record_mode
        if record_mode == "aggregate":
            self.telemetry = AggregateTelemetry(seed=seed)
            for node in self.nodes:  # no per-transfer history either
                node.db.keep_history = False
                node.pcie.keep_history = False
        else:
            self.telemetry = Telemetry()
        self.functions: Dict[str, SimFunction] = {}
        self.rng = RngStreams(seed)
        # root stream = random.Random(seed): bit-compatible with the
        # pre-kernel Simulator._rng that seeded §7.8 replays consume
        self._rng = self.rng.root
        self.completed = 0
        self.failed = 0

    @property
    def scheduler(self) -> str:
        return self.nodes[0].scheduler

    def set_scheduler(self, scheduler: str) -> None:
        """Switch loader/admission ordering ("fifo"|"edf"); applies to
        events queued after the call."""
        if scheduler not in SCHEDULERS:
            raise ValueError(
                f"unknown scheduler {scheduler!r}; use one of {SCHEDULERS}")
        for node in self.nodes:
            node.scheduler = scheduler

    def set_dispatch(self, dispatch: str) -> None:
        """Switch the cluster dispatch policy; applies to arrivals
        dispatched after the call."""
        if dispatch not in DISPATCH_POLICIES:
            raise ValueError(
                f"unknown dispatch {dispatch!r}; use one of {DISPATCH_POLICIES}")
        self.dispatch = dispatch
        self._dispatcher = dispatch_strategy(dispatch)

    @property
    def transfer(self) -> str:
        return self.nodes[0].arbiter.mode

    def set_transfer(self, transfer: str) -> None:
        """Switch the transfer mode ("run_to_completion"|"preemptive");
        applies to chunks advanced after the call."""
        for node in self.nodes:
            node.arbiter.set_mode(transfer)

    def preemption_count(self) -> int:
        """Total link preemptions across nodes (the twin of the daemon's
        ``stats["preemptions"]``)."""
        return sum(n.arbiter.preemptions for n in self.nodes)

    # ------------------------------------------------------------------
    def register(self, fn: SimFunction) -> None:
        self.functions[fn.name] = fn
        for node in self.nodes:
            node.instances[fn.name] = []
            node.ro_state[fn.name] = "none"
            node.ro_ready_cbs[fn.name] = []
            if self.policy.pre_created_contexts:
                # DGSF pins contexts permanently; with many functions the
                # pool must shrink to fit (4 x 414 MB x 30 fns > 40 GB)
                n = self.policy.pre_created_contexts
                while n > 1 and node.used + n * fn.ctx_bytes > 0.85 * node.capacity:
                    n -= 1
                node.dgsf_free[fn.name] = n
                node.dgsf_queue[fn.name] = []
                node.used += n * fn.ctx_bytes  # permanent DGSF overhead

    def submit(self, fn_name: str, t: float, *,
               deadline_s: Optional[float] = None, priority: int = 0,
               request_id: Optional[str] = None,
               max_retries: Optional[int] = None) -> None:
        self.clock.schedule_at(
            t, self._arrive, fn_name, t, deadline_s, priority,
            request_id, max_retries, kind=EventKind.ARRIVAL)

    def replay_stream(self, events: Iterable) -> None:
        """Feed a (possibly huge / lazy) time-ordered arrival stream with
        at most ONE feeder event on the heap at a time — the
        million-invocation replay path, which never pre-schedules the whole
        trace. ``events`` yields :class:`~repro.api.workload.Arrival`-likes
        (``t``/``function``/``deadline_s``/``priority`` attributes) or
        ``(t, function)`` tuples; times must be non-decreasing."""
        self._feed_next(iter(events))

    def _feed_next(self, it) -> None:
        nxt = next(it, None)
        if nxt is None:
            return
        if isinstance(nxt, tuple):
            t, fn_name = nxt[0], nxt[1]
            deadline_s = nxt[2] if len(nxt) > 2 else None
            priority = nxt[3] if len(nxt) > 3 else 0
        else:
            t, fn_name = nxt.t, nxt.function
            deadline_s = getattr(nxt, "deadline_s", None)
            priority = getattr(nxt, "priority", None)
        self.clock.schedule_at(t, self._feed_fire, it, t, fn_name,
                               deadline_s, 0 if priority is None else priority,
                               kind=EventKind.FEED)

    def _feed_fire(self, it, t: float, fn_name: str,
                   deadline_s: Optional[float], priority: int) -> None:
        self._arrive(fn_name, t, deadline_s, priority, None, None)
        self._feed_next(it)

    def run(self, until: float = float("inf")) -> None:
        self.clock.run_until(until)

    # ------------------------------------------------------------------
    def _dispatch_node(self, fn_name: str):
        """(node, residency tier at dispatch) for one arrival. Single-node
        sims have no dispatch decision (tier None keeps their records
        identical to the single-node runtime's). ``"random"`` consumes the
        same seeded ``rng.choice`` stream as the pre-dispatch simulator, so
        seeded §7.8 replays are unchanged."""
        if len(self.nodes) == 1:
            return self.nodes[0], None
        return self._dispatcher.pick(self, fn_name)

    def _arrive(self, fn_name: str, arrival_t: float,
                deadline_s: Optional[float] = None, priority: int = 0,
                request_id: Optional[str] = None,
                max_retries: Optional[int] = None) -> None:
        node, tier = self._dispatch_node(fn_name)
        fn = self.functions[fn_name]
        rec = InvocationRecord(
            request_id=request_id or f"{fn_name}@{arrival_t:.4f}",
            function=fn_name,
            system=self.policy.name, arrival_t=arrival_t,
            start_t=self.clock.now(),
            deadline_s=deadline_s, priority=priority,
            max_retries=max_retries,
            node_id=node.name, dispatch_tier=tier,
        )
        # canonical stage keys up front (stages a policy path skips read as
        # 0.0) — keeps the record structure identical to the threaded
        # runtime's, which the parity test in tests/test_api.py guards
        rec.stages.update(_STAGE_ZEROS)
        if self.policy.name.startswith("sage"):
            SageInvocation(self, node, fn, rec)
        elif self.policy.pre_created_contexts:
            DgsfInvocation(self, node, fn, rec)
        else:
            FixedInvocation(self, node, fn, rec)

    # ------------------------------------------------------------------
    def _fail_record(self, fn: SimFunction, rec: InvocationRecord,
                     reason: str) -> None:
        """Shared failure bookkeeping (the twin of ``Handle.wait()`` raising
        ``DataLoadError``): the invocation resolves with a typed error
        record instead of waiting forever. All policy paths go through
        here so the error-record format stays uniform."""
        self.failed += 1
        rec.error = f"DataLoadError: {fn.name}: {reason}"
        rec.end_t = self.clock.now()
        self.telemetry.add(rec)

    # ------------------------------------------------------------------
    # thin wrappers kept for pre-refactor callers
    # ------------------------------------------------------------------
    def _finish(self, node, fn, rec, inst, release_bytes, extra_done=None):
        Completion(self, node, fn, rec, inst, release_bytes, extra_done)

    def _finish_with_cb(self, node, fn, rec, cb):
        CallbackCompletion(self, node, fn, rec, cb)

    # exit-ladder stage hooks shared by every SAGE instance on a node
    # (installed by sim.invocations.sage_instance)
    def _sage_demote(self, node, inst):
        if inst.has_ro_device:
            inst.has_ro_device = False
            inst.has_ro_host = True
            node.ro_state[inst.fn.name] = "host"
            node.touch_host(inst.fn.name)
            node.release(inst.fn.ro_bytes)

    def _sage_drop_ctx(self, node, inst):
        if inst.has_ctx:
            inst.has_ctx = False
            node.release(inst.fn.ctx_bytes)

    def _sage_drop_host(self, node, inst):
        inst.has_ro_host = False
        if node.ro_state[inst.fn.name] == "host":
            node.ro_state[inst.fn.name] = "none"
        if node.ro_state[inst.fn.name] == "none":
            node.drop_host_resident(inst.fn.name)

    # ------------------------------------------------------------------
    def mean_memory_bytes(self) -> float:
        """Cluster-total time-weighted mean device occupancy (streaming
        accumulators on each node — no sample list is retained)."""
        t_end = self.clock.now()
        total = 0.0
        for node in self.nodes:
            m = node.mean_memory_bytes(t_end)
            if m is not None:
                total += m
        return total


# ---------------------------------------------------------------------------
# deprecated aliases: the canonical trace generators moved to
# repro.api.workload (imported lazily — repro.api imports this module)
# ---------------------------------------------------------------------------


def poisson_arrivals(rate_per_s: float, duration_s: float,
                     rng: random.Random) -> List[float]:
    """Deprecated alias for :func:`repro.api.workload.poisson_arrivals`."""
    warnings.warn(
        "repro.core.simulator.poisson_arrivals moved to "
        "repro.api.workload.poisson_arrivals",
        DeprecationWarning, stacklevel=2)
    from repro.api.workload import poisson_arrivals as _impl
    return _impl(rate_per_s, duration_s, rng)


def maf_like_trace(
    functions: List[str], duration_s: float, seed: int = 0,
    mean_rpm: float = 12.0,
) -> List[Tuple[float, str]]:
    """Deprecated alias for :func:`repro.api.workload.maf_like_trace`."""
    warnings.warn(
        "repro.core.simulator.maf_like_trace moved to "
        "repro.api.workload.maf_like_trace",
        DeprecationWarning, stacklevel=2)
    from repro.api.workload import maf_like_trace as _impl
    return _impl(functions, duration_s, seed=seed, mean_rpm=mean_rpm)
