"""Sharing-aware dispatch scoring (docs/cluster.md, docs/planner.md).

SAGE's throughput win is read-only/context sharing *within* a node
(paper §5); random cluster dispatch throws most of it away — invocations
of one function scatter across nodes and every node redoes the db→host→
device data preparation. The dispatch policies here route an invocation
to the node where its function is already resident, falling back to the
least-pressured cold node when the hot node is saturated
(**spill-and-warm**: hot nodes absorb repeat traffic until pressure pushes
overflow to a cold node, which then warms up — residency is a preference,
never a pin).

Both cluster drivers consume this module: `ClusterRuntime.select_node`
builds one :class:`NodeSnapshot` per `SageRuntime` (from
`MemoryDaemon.residency()`/`pressure()`) and the `Simulator` twin builds
the same snapshot per `GPUNode`, so the scoring below is shared verbatim
and the runtime/sim parity test can compare per-node assignments 1:1.

``"planned"`` routes through the :class:`~repro.core.placement.planner
.PlacementPlanner` residency map instead of per-request scoring; it is
listed here so every knob-validation site accepts it, but
:func:`choose_node` itself only scores the per-request policies — the
planner calls back into it for its spill path.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List

DISPATCH_POLICIES = ("random", "locality", "least_loaded", "planned")

# residency tiers a snapshot can report for a function's read-only data.
# "loading" means an in-flight shareable load: an arrival routed there
# attaches to the stream already running (a shared hit), which is worth as
# much as device residency — it skips the db and host legs entirely.
TIERS = ("none", "host", "loading", "device")
TIER_SCORE = {"none": 0.0, "host": 1.0, "loading": 2.0, "device": 2.0}


@dataclass(frozen=True)
class NodeSnapshot:
    """One node's residency + pressure at dispatch time.

    Produced under the owning daemon's lock (O(per-function), never
    blocking on in-flight loads — docs/cluster.md has the contract);
    consumed by :func:`choose_node` and the placement planner.
    """

    node_id: str
    ro_tier: str            # best tier of the function's read-only data
    ro_bytes: int           # resident read-only bytes for the function
    device_free: int        # capacity - device_used
    device_capacity: int
    pending_admissions: int  # parked device-memory waiters
    loader_queue: int        # queued + in-flight loads on the loader pool
    loader_threads: int
    healthy: bool = True     # False once fault injection crashed the node
    # graded health from the SlownessDetector (docs/resilience.md, "Gray
    # failures"): 1.0 = no drift evidence, < 1.0 = the node's worst stage
    # EWMA runs hotter than the fleet median by that ratio. Stays 1.0
    # when slowness detection is off, so default scoring is bit-identical
    # to the binary-health seed.
    health_score: float = 1.0
    # idle fraction of the node's SM budget (docs/compute.md): < 1.0 only
    # when a shared compute plane is attached and busy. Stays 1.0 under
    # compute="exclusive", so default scoring is bit-identical to the seed.
    compute_free_frac: float = 1.0

    @property
    def queue_pressure(self) -> float:
        """Outstanding data-plane work per loader worker."""
        return (self.loader_queue + self.pending_admissions) / max(
            1, self.loader_threads)

    @property
    def mem_pressure(self) -> float:
        """Device-memory fullness in [0, 1]."""
        return 1.0 - self.device_free / max(1, self.device_capacity)


def locality_score(snap: NodeSnapshot) -> float:
    """Higher is better. Residency tier dominates (device/loading = 2,
    host = 1, cold = 0) so repeat traffic sticks to its warm node; the
    pressure terms make a saturated hot node lose to an idle cold one
    (~4 queued loads per worker, or a full device, erase a device-tier
    advantage) — that crossover point is the spill in spill-and-warm.
    A degraded ``health_score`` (slowness detection on) penalizes the
    node continuously: a 2x-slow node (score 0.5) loses a full residency
    tier, a suspect loses more — with the default score of 1.0 the term
    is exactly 0.0, so seed scoring is unchanged. The compute term
    (docs/compute.md) packs density-aware: a node whose SM budget is
    fully busy loses one residency tier, so small-function traffic
    spreads once a hot node's slices saturate — at the default
    ``compute_free_frac`` of 1.0 the term is exactly 0.0."""
    return (TIER_SCORE[snap.ro_tier]
            - 0.5 * snap.queue_pressure
            - snap.mem_pressure
            - 2.0 * (1.0 - snap.health_score)
            - 1.0 * (1.0 - snap.compute_free_frac))


def choose_node(policy: str, snapshots: List[NodeSnapshot]) -> int:
    """Index of the node ``policy`` dispatches to.

    Ties break EDF-compatibly: of equally-scored nodes, the one with the
    fewest parked admission waiters wins (the request joins the shortest
    EDF waiter heap, so a tight deadline queues behind the least work),
    then the shortest loader queue, then the lowest index (deterministic).
    """
    if policy == "least_loaded":
        return min(
            range(len(snapshots)),
            key=lambda i: (snapshots[i].queue_pressure,
                           snapshots[i].mem_pressure,
                           snapshots[i].pending_admissions, i),
        )
    if policy == "locality":
        return min(
            range(len(snapshots)),
            key=lambda i: (-locality_score(snapshots[i]),
                           snapshots[i].pending_admissions,
                           snapshots[i].loader_queue, i),
        )
    raise ValueError(
        f"unknown dispatch policy {policy!r}; use one of {DISPATCH_POLICIES}")
