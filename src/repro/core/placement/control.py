"""PlacementControl: the control-plane facade both drivers drive.

One object owns the three cooperating components of docs/planner.md —
the :class:`~repro.core.placement.planner.PlacementPlanner` residency
map, the work-stealing decisions (board/reroute below), and the
:class:`~repro.core.placement.autoscaler.Autoscaler` over the dynamic
node pool — plus the node-count timeline that prices the pool in
node-seconds. Every method is a pure decision over
:class:`~repro.core.placement.scoring.NodeSnapshot` lists and driver
timestamps, so `ClusterRuntime` and the `Simulator` share it
byte-for-byte; the drivers only *apply* the decisions (start an
invocation, park it, add a node, drain one).

Work stealing rides this split: `route()` boards an arrival whose
planned home is above the ``steal_watermark`` (queued-but-unstarted — no
bytes reserved, no machine started), and `reroute()` re-picks it after
``board_delay_s`` with fresh snapshots. Landing on a different node than
the original home is a *steal* and charges the request's
``max_retries``/``redispatches`` budget, exactly like a crash
re-dispatch (docs/resilience.md).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.placement.autoscaler import (
    AutoscaleConfig, Autoscaler, RateForecast, resolve_autoscale,
)
from repro.core.placement.planner import PlacementPlanner, PlannerConfig
from repro.core.placement.scoring import NodeSnapshot

DEFAULT_TICK_S = 1.0  # forecast cadence when autoscaling is off


class PlacementControl:
    def __init__(self, node_ids: Sequence[str], *,
                 autoscale: Optional[AutoscaleConfig] = None,
                 planner_cfg: Optional[PlannerConfig] = None,
                 now: float = 0.0):
        self.autoscale = resolve_autoscale(autoscale)
        self.planner = PlacementPlanner(planner_cfg)
        alpha = self.autoscale.ewma_alpha if self.autoscale else 0.3
        self.forecast = RateForecast(alpha)
        self.scaler = Autoscaler(self.autoscale) if self.autoscale else None
        self.tick_s = self.autoscale.tick_s if self.autoscale else DEFAULT_TICK_S
        # pool state: provisioned ⊇ active; draining nodes stay provisioned
        # (they still hold slots/bytes) but leave the placement-active set
        self._provisioned: List[str] = list(node_ids)
        self._draining: set = set()
        self._last_tick: Optional[float] = None
        # node-seconds integral + (t, provisioned_count) timeline
        self._timeline: List[Tuple[float, int]] = [(now, len(self._provisioned))]
        self._ns_accum = 0.0
        self._ns_t = now
        # work-stealer telemetry
        self.boards = 0
        self.steals = 0
        self.planner.set_nodes(self.active_nodes())

    def set_autoscale(self, autoscale) -> None:
        """Attach (or swap) the autoscaling policy mid-run — the spec
        adoption path. The forecast keeps its observed history; only the
        smoothing, tick cadence, and scaler change."""
        self.autoscale = resolve_autoscale(autoscale)
        if self.autoscale is None:
            self.scaler = None
            self.tick_s = DEFAULT_TICK_S
            return
        self.forecast.alpha = self.autoscale.ewma_alpha
        self.scaler = Autoscaler(self.autoscale)
        self.tick_s = self.autoscale.tick_s

    # ------------------------------------------------------------------
    # pool membership + node-seconds
    # ------------------------------------------------------------------
    def active_nodes(self) -> List[str]:
        return [nid for nid in self._provisioned if nid not in self._draining]

    def _mark(self, now: float) -> None:
        self._ns_accum += (now - self._ns_t) * len(self._provisioned)
        self._ns_t = now

    def node_provisioned(self, node_id: str, now: float) -> None:
        self._mark(now)
        if node_id not in self._provisioned:
            self._provisioned.append(node_id)
        self._draining.discard(node_id)
        self._timeline.append((now, len(self._provisioned)))
        self.planner.set_nodes(self.active_nodes())

    def node_draining(self, node_id: str) -> None:
        """The node stops taking placements immediately; it keeps costing
        node-seconds until the teardown retires it."""
        self._draining.add(node_id)
        self.planner.set_nodes(self.active_nodes())

    def node_retired(self, node_id: str, now: float) -> None:
        self._mark(now)
        if node_id in self._provisioned:
            self._provisioned.remove(node_id)
        self._draining.discard(node_id)
        self._timeline.append((now, len(self._provisioned)))
        self.planner.set_nodes(self.active_nodes())

    def node_seconds(self, now: float) -> float:
        return self._ns_accum + (now - self._ns_t) * len(self._provisioned)

    # ------------------------------------------------------------------
    # function lifecycle (planner churn signals)
    # ------------------------------------------------------------------
    def register_function(self, name: str, weight_bytes: int) -> None:
        self.planner.register_function(name, weight_bytes)

    def retire_function(self, name: str) -> None:
        self.planner.retire_function(name)

    # ------------------------------------------------------------------
    # routing + work stealing
    # ------------------------------------------------------------------
    def note_arrival(self, fn_name: str) -> None:
        self.forecast.note_arrival(fn_name)

    def route(self, fn_name: str, snapshots: List[NodeSnapshot],
              allow_board: bool = True):
        """``("start", idx, planned_hit)`` or ``("board", idx)`` — board
        means the planned target (and every alternative the pick
        considered) is above the steal watermark, so the arrival parks as
        queued-but-unstarted work for the stealer to re-route."""
        idx, hit = self.planner.pick(fn_name, snapshots)
        if (allow_board and snapshots[idx].queue_pressure
                >= self.planner.cfg.steal_watermark):
            self.boards += 1
            return ("board", idx)
        return ("start", idx, hit)

    def reroute(self, fn_name: str, snapshots: List[NodeSnapshot],
                home_id: str) -> Tuple[int, bool]:
        """Re-pick a boarded arrival with fresh snapshots; a landing away
        from the original home is a steal."""
        idx, _hit = self.planner.pick(fn_name, snapshots)
        stole = snapshots[idx].node_id != home_id
        if stole:
            self.steals += 1
        return idx, stole

    # ------------------------------------------------------------------
    # the control tick (piggybacked on arrivals by both drivers)
    # ------------------------------------------------------------------
    def maybe_tick(self, now: float) -> Tuple[int, List[str]]:
        """Run the control loop if a tick elapsed: fold arrival counts
        into the EWMA forecast, push rates to the planner (repairing the
        plan when replica targets drift), and — when autoscaling is on —
        return ``(nodes_to_add, [node_ids_to_drain])`` for the driver to
        apply. Ticks ride arrivals, so an idle system schedules nothing
        and virtual-time runs still terminate."""
        if self._last_tick is None:
            self._last_tick = now
            return 0, []
        dt = now - self._last_tick
        if dt < self.tick_s:
            return 0, []
        self._last_tick = now
        rates = self.forecast.tick(dt)
        drift = False
        for name, rate in rates.items():
            self.planner.set_rate(name, rate)
            homes = self.planner.plan.get(name)
            if homes is not None and len(homes) != self.planner._replicas(
                    name, max(1, len(self.planner._node_ids))):
                drift = True
        if drift:
            self.planner.replan()
        if self.scaler is None:
            return 0, []
        add, drains = self.scaler.decide(self.forecast.total(),
                                         len(self.active_nodes()))
        drain_ids: List[str] = []
        for _ in drains:
            cand = self.planner.drain_candidate()
            if cand is not None:
                drain_ids.append(cand)
                self.node_draining(cand)
        return add, drain_ids

    # ------------------------------------------------------------------
    # observability (docs/planner.md)
    # ------------------------------------------------------------------
    def stats(self, now: float) -> Dict:
        return {
            "planned_hits": self.planner.planned_hits,
            "planned_misses": self.planner.planned_misses,
            "hit_rate": round(self.planner.hit_rate(), 4),
            "replans": self.planner.replans,
            "boards": self.boards,
            "steals": self.steals,
            "scale_ups": self.scaler.scale_ups if self.scaler else 0,
            "scale_downs": self.scaler.scale_downs if self.scaler else 0,
            "target_nodes": (self.scaler.last_target if self.scaler
                             else len(self.active_nodes())),
            "active_nodes": len(self.active_nodes()),
            "provisioned_nodes": len(self._provisioned),
            "node_seconds": round(self.node_seconds(now), 6),
            "node_timeline": list(self._timeline),
        }
