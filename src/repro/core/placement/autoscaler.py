"""Predictive autoscaler: EWMA arrival forecast → node count (docs/planner.md).

HAS-GPU-style (PAPERS.md) hybrid scaling, reduced to the piece this
control plane needs: a per-function EWMA of observed arrival rates feeds
a cluster-wide capacity target, and hysteresis (consecutive-tick streaks
in each direction) keeps the pool from thrashing on diurnal noise. The
autoscaler only *decides*; the drivers own the mechanics of adding and
draining nodes (`ClusterRuntime.add_node`/`drain_node` and the simulator
twins), so the decision code is shared byte-for-byte.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple


@dataclass(frozen=True)
class AutoscaleConfig:
    """The ``autoscale=`` knob (Gateway/Simulator/ClusterRuntime/
    FunctionSpec). Frozen so spec adopt-or-refuse can compare by value."""

    min_nodes: int = 1
    max_nodes: int = 8
    node_rate_per_s: float = 8.0  # forecast arrivals/s one node absorbs
    tick_s: float = 1.0           # control-loop cadence (driver clock)
    ewma_alpha: float = 0.3       # forecast smoothing per tick
    headroom: float = 1.2         # capacity margin above the forecast
    up_ticks: int = 1             # streak before scaling up
    down_ticks: int = 3           # streak before draining (hysteresis)

    def __post_init__(self):
        if self.min_nodes < 1 or self.max_nodes < self.min_nodes:
            raise ValueError(
                f"autoscale bounds invalid: min={self.min_nodes} "
                f"max={self.max_nodes}")
        if self.node_rate_per_s <= 0 or self.tick_s <= 0:
            raise ValueError("node_rate_per_s and tick_s must be positive")
        if not 0.0 < self.ewma_alpha <= 1.0:
            raise ValueError("ewma_alpha must be in (0, 1]")


class RateForecast:
    """Per-function EWMA over per-tick arrival counts."""

    def __init__(self, alpha: float):
        self.alpha = alpha
        self.rates: Dict[str, float] = {}
        self._counts: Dict[str, int] = {}

    def note_arrival(self, fn_name: str) -> None:
        self._counts[fn_name] = self._counts.get(fn_name, 0) + 1

    def tick(self, dt_s: float) -> Dict[str, float]:
        """Fold the counts since the last tick into the EWMA; returns the
        updated per-function rates (arrivals/s)."""
        if dt_s <= 0:
            return self.rates
        a = self.alpha
        for name in set(self.rates) | set(self._counts):
            inst = self._counts.get(name, 0) / dt_s
            prev = self.rates.get(name)
            self.rates[name] = inst if prev is None else a * inst + (1 - a) * prev
        self._counts.clear()
        return self.rates

    def total(self) -> float:
        return math.fsum(self.rates.values())


class Autoscaler:
    """Hysteresis loop over the forecast: target = ceil(total_rate ×
    headroom / node_rate_per_s) clamped to [min_nodes, max_nodes]; the
    pool only moves after ``up_ticks``/``down_ticks`` consecutive ticks
    agree on the direction, and drains go one node per tick (gentle —
    each drain must finish its teardown before the next)."""

    def __init__(self, cfg: AutoscaleConfig):
        self.cfg = cfg
        self._up_streak = 0
        self._down_streak = 0
        self.scale_ups = 0
        self.scale_downs = 0
        self.last_target = cfg.min_nodes

    def decide(self, total_rate: float,
               active_nodes: int) -> Tuple[int, List[str]]:
        """``(nodes_to_add, ['drain'])`` for this tick. ``active_nodes``
        counts placement-active nodes (provisioned and not draining)."""
        cfg = self.cfg
        target = max(cfg.min_nodes, min(cfg.max_nodes, math.ceil(
            total_rate * cfg.headroom / cfg.node_rate_per_s)))
        self.last_target = target
        if target > active_nodes:
            self._down_streak = 0
            self._up_streak += 1
            if self._up_streak >= cfg.up_ticks:
                self._up_streak = 0
                self.scale_ups += 1
                return target - active_nodes, []
            return 0, []
        self._up_streak = 0
        if target < active_nodes and active_nodes > cfg.min_nodes:
            self._down_streak += 1
            if self._down_streak >= cfg.down_ticks:
                self._down_streak = 0
                self.scale_downs += 1
                return 0, ["drain"]
            return 0, []
        self._down_streak = 0
        return 0, []


def resolve_autoscale(autoscale) -> Optional[AutoscaleConfig]:
    """Normalize the knob: None (off), an AutoscaleConfig, or a mapping of
    AutoscaleConfig fields (the ergonomic literal form)."""
    if autoscale is None or isinstance(autoscale, AutoscaleConfig):
        return autoscale
    if isinstance(autoscale, dict):
        return AutoscaleConfig(**autoscale)
    raise ValueError(
        f"autoscale must be None, an AutoscaleConfig, or a dict of its "
        f"fields; got {type(autoscale).__name__}")
