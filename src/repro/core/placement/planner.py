"""PlacementPlanner: the function→node residency map (docs/planner.md).

``dispatch="locality"`` is per-request greedy: each arrival scores every
node and the residency map *emerges* from wherever traffic happened to
spill. Under function churn that map fragments — one function ends up
warm on many nodes (paying the cold load on each) while other nodes sit
idle. The planner inverts this: it *computes* the residency map up front
— greedy bin-packing of function working sets by ``bytes × arrival
rate`` onto the active nodes, deterministic tie-breaks — and dispatch
routes to the planned home, spilling through the shared
:func:`~repro.core.placement.scoring.choose_node` scoring only when the
home set is saturated or gone.

The plan is repaired incrementally on churn signals: function
register/retire, node membership changes (autoscaler add/drain, health
eviction of a crashed node), and a sustained planned-miss rate over the
recent dispatch window. All decisions are pure functions of
:class:`~repro.core.placement.scoring.NodeSnapshot` lists plus planner
state, so both drivers share this code byte-for-byte.
"""
from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.placement.scoring import NodeSnapshot, choose_node


@dataclass(frozen=True)
class PlannerConfig:
    """Knobs for the residency map and its repair triggers."""

    rate_floor: float = 0.05      # arrivals/s assumed for a never-seen fn
    replica_rate: float = 8.0     # extra home per this many arrivals/s
    spill_pressure: float = 4.0   # home queue_pressure where the pick spills
    steal_watermark: float = 6.0  # queue_pressure that boards new arrivals
    board_delay_s: float = 0.05   # how long boarded work parks before re-route
    replan_miss_rate: float = 0.5  # sustained miss fraction forcing a replan
    miss_window: int = 64         # dispatches per miss-rate evaluation window


class PlacementPlanner:
    """Owns the plan (``function -> tuple(home node ids)``) and the churn
    counters that decide when to recompute it. Arrival-rate estimates are
    fed by the control loop's EWMA forecast (`set_rate`)."""

    def __init__(self, cfg: Optional[PlannerConfig] = None):
        self.cfg = cfg or PlannerConfig()
        self._weight_bytes: Dict[str, int] = {}
        self._rates: Dict[str, float] = {}
        self._node_ids: List[str] = []
        self.plan: Dict[str, Tuple[str, ...]] = {}
        # telemetry (docs/planner.md "Observability")
        self.planned_hits = 0
        self.planned_misses = 0
        self.replans = 0
        self._window: deque = deque(maxlen=self.cfg.miss_window)

    # ------------------------------------------------------------------
    # churn signals
    # ------------------------------------------------------------------
    def register_function(self, name: str, weight_bytes: int) -> None:
        """Function registered: give it a home immediately."""
        self._weight_bytes[name] = int(weight_bytes)
        self.replan()

    def retire_function(self, name: str) -> None:
        """Function retired: free its planned share."""
        self._weight_bytes.pop(name, None)
        self._rates.pop(name, None)
        self.replan()

    def set_nodes(self, node_ids: Sequence[str]) -> None:
        """Membership change (add/drain/evict): repair the plan onto the
        surviving placement-active nodes."""
        ids = list(node_ids)
        if ids != self._node_ids:
            self._node_ids = ids
            self.replan()

    def set_rate(self, name: str, rate_per_s: float) -> None:
        """Forecast update from the control loop's EWMA (no replan here —
        the tick decides when the drift is worth repairing)."""
        self._rates[name] = rate_per_s

    # ------------------------------------------------------------------
    # the plan
    # ------------------------------------------------------------------
    def _weight(self, name: str) -> float:
        """Bin-packing weight: working-set bytes × forecast arrival rate.
        The rate floor keeps a cold function mapped (it still needs a
        home for its first arrival)."""
        rate = max(self._rates.get(name, 0.0), self.cfg.rate_floor)
        return self._weight_bytes.get(name, 0) * rate

    def _replicas(self, name: str, n_nodes: int) -> int:
        """Hot functions get extra homes so one node's loader pool is not
        the throughput ceiling: one replica per ``replica_rate`` arrivals/s,
        capped at the active node count."""
        rate = self._rates.get(name, 0.0)
        return max(1, min(n_nodes, 1 + int(rate / self.cfg.replica_rate)))

    def replan(self) -> None:
        """Greedy bin-packing, heaviest function first. Deterministic:
        functions sort by (-weight, name); each replica lands on the
        least-loaded node, ties broken by node id. Incremental in spirit —
        the full recompute is O(F·N log N) over dicts the planner already
        holds, so 'repair' and 'recompute' coincide at this scale."""
        self.replans += 1
        self._window.clear()
        nodes = list(self._node_ids)
        if not nodes:
            self.plan = {}
            return
        load = {nid: 0.0 for nid in nodes}
        plan: Dict[str, Tuple[str, ...]] = {}
        for name in sorted(self._weight_bytes,
                           key=lambda n: (-self._weight(n), n)):
            k = self._replicas(name, len(nodes))
            homes = sorted(nodes, key=lambda nid: (load[nid], nid))[:k]
            share = self._weight(name) / k
            for nid in homes:
                load[nid] += share
            plan[name] = tuple(homes)
        self.plan = plan

    # ------------------------------------------------------------------
    # the pick (shared byte-for-byte by both drivers)
    # ------------------------------------------------------------------
    def pick(self, fn_name: str,
             snapshots: List[NodeSnapshot]) -> Tuple[int, bool]:
        """Index into ``snapshots`` for one arrival of ``fn_name`` plus
        whether the pick was a *planned hit* (landed on a home node).

        The least-pressured healthy home below ``spill_pressure`` wins
        (ties: home order, which the replan sorted by load). A saturated
        or missing home set spills through the shared locality scoring —
        a miss. Sustained misses (> ``replan_miss_rate`` over the last
        ``miss_window`` dispatches) mean the plan no longer matches the
        traffic, so the planner repairs it."""
        by_id = {s.node_id: i for i, s in enumerate(snapshots)}
        best: Optional[Tuple[float, int]] = None
        homes = self.plan.get(fn_name, ())
        for rank, nid in enumerate(homes):
            i = by_id.get(nid)
            if i is None or not snapshots[i].healthy:
                continue
            s = snapshots[i]
            if s.queue_pressure >= self.cfg.spill_pressure:
                continue
            if best is None or (s.queue_pressure, rank) < best:
                best = (s.queue_pressure, rank)
                best_idx = i
        if best is not None:
            self._note(hit=True)
            return best_idx, True
        idx = choose_node("locality", snapshots)
        self._note(hit=False)
        return idx, False

    def _note(self, hit: bool) -> None:
        if hit:
            self.planned_hits += 1
        else:
            self.planned_misses += 1
        self._window.append(hit)
        if (len(self._window) == self.cfg.miss_window
                and self._window.count(False)
                > self.cfg.replan_miss_rate * self.cfg.miss_window):
            self.replan()  # clears the window

    def hit_rate(self) -> float:
        total = self.planned_hits + self.planned_misses
        return self.planned_hits / total if total else 0.0

    def drain_candidate(self) -> Optional[str]:
        """The node the autoscaler should drain: the one carrying the
        least planned weight (deterministic tie-break by id)."""
        if not self._node_ids:
            return None
        load = {nid: 0.0 for nid in self._node_ids}
        for name, homes in self.plan.items():
            if not homes:
                continue
            share = self._weight(name) / len(homes)
            for nid in homes:
                if nid in load:
                    load[nid] += share
        return min(self._node_ids, key=lambda nid: (load[nid], nid))

    def total_rate(self) -> float:
        return math.fsum(self._rates.values())
