"""The cluster control plane (docs/planner.md).

``repro.core.placement`` is the one home for every cluster-level
dispatch decision, shared byte-for-byte by the threaded
``ClusterRuntime`` and the virtual-time ``Simulator``:

* :mod:`.scoring` — per-request policies (``random``/``locality``/
  ``least_loaded``): :class:`NodeSnapshot` + :func:`choose_node`,
  refactored here from the old ``repro.core.dispatch`` module (which
  remains as a re-export shim).
* :mod:`.planner` — ``dispatch="planned"``: the
  :class:`PlacementPlanner` function→node residency map (greedy
  bin-packing by bytes × arrival rate, incremental repair on churn).
* :mod:`.autoscaler` — the ``autoscale=`` knob: per-function EWMA
  arrival forecast → target node count with hysteresis.
* :mod:`.control` — :class:`PlacementControl`, the facade the drivers
  call (routing, work stealing, control ticks, node-seconds timeline).
"""
from repro.core.placement.autoscaler import (  # noqa: F401
    AutoscaleConfig, Autoscaler, RateForecast, resolve_autoscale,
)
from repro.core.placement.control import PlacementControl  # noqa: F401
from repro.core.placement.planner import (  # noqa: F401
    PlacementPlanner, PlannerConfig,
)
from repro.core.placement.scoring import (  # noqa: F401
    DISPATCH_POLICIES, TIER_SCORE, TIERS, NodeSnapshot, choose_node,
    locality_score,
)
