"""Seeded fault-injection plane + resilience control primitives.

Shared by the threaded runtime (``repro.core.runtime``) and the
discrete-event simulator (``repro.core.simulator``): a :class:`FaultPlan`
is a *pure description* — typed, frozen specs plus a seed — and each
backend materialises it independently:

* the simulator turns :meth:`FaultPlan.events` into ``EventKind.FAULT``
  heap entries on the virtual clock;
* the runtime gateway arms wall-clock timers at ``t0 + at_s * pace``
  against the same event list.

Per-function loader faults are *drawn*, not scheduled: both backends call
:meth:`FaultPlan.make_draws` once and then draw exactly once per arrival
(before any breaker/shed gate, so the stream position is identical on
both drivers even when the control layer rejects the request). The draw
streams are named ``{seed}:loader:{fn}`` — independent of the §7.8 root
``RngStreams`` stream, so enabling faults never perturbs seeded arrival
or dispatch sequences.

The control side lives here too: :class:`CircuitBreaker`
(closed→open→half-open, docs/resilience.md has the state machine) and
:class:`SheddingConfig` (priority-aware watermark shedding). Both are
clock-agnostic — the sim passes its virtual ``clock.now``, the runtime
``time.monotonic`` — so one implementation serves both drivers.

Defaults everywhere are *off*: with ``faults=None`` no stream is created,
no draw is made, and both drivers are bit-identical to the seeded golden
traces (tests/test_sim_golden.py guards this).
"""
from __future__ import annotations

import math
import random
import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.daemon import NodeLostError  # re-export: typed crash error
from repro.core.telemetry import ERROR_CLASSES, classify_error  # re-export

__all__ = [
    "NodeCrash",
    "LinkDegradation",
    "LoaderFault",
    "DbFlap",
    "SlowNode",
    "LoaderJitter",
    "MemoryLeak",
    "FaultPlan",
    "FaultDraws",
    "BreakerConfig",
    "CircuitBreaker",
    "SheddingConfig",
    "ShedError",
    "BreakerOpenError",
    "NodeLostError",
    "ERROR_CLASSES",
    "classify_error",
]


# ----------------------------------------------------------------------
# fault specs (frozen descriptions; no behavior)
# ----------------------------------------------------------------------
@dataclass(frozen=True)
class NodeCrash:
    """Node ``node`` dies at ``at_s`` (workload time). Everything in
    flight on it fails with :class:`NodeLostError`; accounting resets to
    empty. With ``restart_after_s`` the node rejoins (cold) that many
    seconds later."""
    node: str
    at_s: float
    restart_after_s: Optional[float] = None

    def __post_init__(self):
        if self.at_s < 0:
            raise ValueError("NodeCrash.at_s must be >= 0")
        if self.restart_after_s is not None and self.restart_after_s <= 0:
            raise ValueError("NodeCrash.restart_after_s must be > 0")


@dataclass(frozen=True)
class LinkDegradation:
    """Multiply link bandwidth by ``factor`` over ``[at_s, at_s +
    duration_s)``. ``link`` is ``"db"`` or ``"pcie"``; ``node=None``
    degrades that link on every node (a shared-storage brownout)."""
    at_s: float
    duration_s: float
    factor: float
    link: str = "db"
    node: Optional[str] = None

    def __post_init__(self):
        if self.link not in ("db", "pcie"):
            raise ValueError(f"LinkDegradation.link must be db|pcie, got {self.link!r}")
        if not (0.0 < self.factor < 1.0):
            raise ValueError("LinkDegradation.factor must be in (0, 1)")
        if self.duration_s <= 0:
            raise ValueError("LinkDegradation.duration_s must be > 0")


@dataclass(frozen=True)
class LoaderFault:
    """Each arrival of ``function`` inside ``[start_s, end_s)`` fails its
    db load leg with probability ``probability`` (a poisoned datum /
    flaky object store). Drawn per-arrival from the plan's dedicated
    stream — deterministic given the seed."""
    function: str
    probability: float
    start_s: float = 0.0
    end_s: float = math.inf

    def __post_init__(self):
        if not (0.0 <= self.probability <= 1.0):
            raise ValueError("LoaderFault.probability must be in [0, 1]")


@dataclass(frozen=True)
class DbFlap:
    """The db link on ``node`` (or every node) goes hard-down over
    ``[at_s, at_s + duration_s)``: loads needing the db leg fail fast
    with a typed error instead of degrading."""
    at_s: float
    duration_s: float
    node: Optional[str] = None

    def __post_init__(self):
        if self.duration_s <= 0:
            raise ValueError("DbFlap.duration_s must be > 0")


# ----------------------------------------------------------------------
# gray-failure specs (docs/resilience.md, "Gray failures"): the node is
# alive and passing health checks but slow — the tail-tolerance layer
# (repro.core.slowness) is what detects and mitigates these.
# ----------------------------------------------------------------------
@dataclass(frozen=True)
class SlowNode:
    """Node ``node`` runs ``factor``x slower over ``[at_s, at_s +
    duration_s)`` (``duration_s=None`` = until the end of the run): its
    kernel service time is multiplied by ``factor`` and its db/pcie
    loader bandwidth divided by it. The node stays *healthy* — binary
    eviction never fires; only slowness detection sees it."""
    node: str
    at_s: float
    factor: float
    duration_s: Optional[float] = None

    def __post_init__(self):
        if self.at_s < 0:
            raise ValueError("SlowNode.at_s must be >= 0")
        if self.factor <= 1.0:
            raise ValueError("SlowNode.factor must be > 1")
        if self.duration_s is not None and self.duration_s <= 0:
            raise ValueError("SlowNode.duration_s must be > 0")


@dataclass(frozen=True)
class LoaderJitter:
    """Each arrival of ``function`` inside ``[start_s, end_s)`` pays an
    extra heavy-tailed delay on its private load leg: ``scale_s *
    (U^(-1/alpha) - 1)`` with U drawn per-arrival from the plan's
    dedicated ``{seed}:jitter:{fn}`` stream (Pareto tail; smaller
    ``alpha`` = heavier tail). Deterministic given the seed, identical on
    both drivers."""
    function: str
    scale_s: float
    alpha: float = 2.0
    start_s: float = 0.0
    end_s: float = math.inf

    def __post_init__(self):
        if self.scale_s <= 0:
            raise ValueError("LoaderJitter.scale_s must be > 0")
        if self.alpha <= 0:
            raise ValueError("LoaderJitter.alpha must be > 0")


@dataclass(frozen=True)
class MemoryLeak:
    """Device memory on ``node`` leaks at ``rate_bps`` bytes/second over
    ``[at_s, at_s + duration_s)`` (``duration_s=None`` = forever):
    ``device_used`` creeps up, shrinking admission headroom and pushing
    the node toward OOM backpressure without any crash. The leak is
    reclaimed exactly when the window closes or the node is torn down."""
    node: str
    at_s: float
    rate_bps: float
    duration_s: Optional[float] = None

    def __post_init__(self):
        if self.at_s < 0:
            raise ValueError("MemoryLeak.at_s must be >= 0")
        if self.rate_bps <= 0:
            raise ValueError("MemoryLeak.rate_bps must be > 0")
        if self.duration_s is not None and self.duration_s <= 0:
            raise ValueError("MemoryLeak.duration_s must be > 0")


class FaultDraws:
    """Stateful per-function loader-fault draw streams. Each backend gets
    its OWN instance (``plan.make_draws()``) so runtime and sim consume
    identical sequences independently. ``draw(fn, t)`` advances the
    stream exactly once per call regardless of ``t`` (stream positions
    must track *arrival counts*, which match across drivers, not window
    membership, which could drift with float timing). Jitter draws
    (:class:`LoaderJitter`) follow the same contract on independent
    ``{seed}:jitter:{fn}`` streams."""

    def __init__(self, seed: int, specs: Tuple[LoaderFault, ...],
                 jitter_specs: Tuple["LoaderJitter", ...] = ()):
        self._specs: Dict[str, List[LoaderFault]] = {}
        for s in specs:
            self._specs.setdefault(s.function, []).append(s)
        self._streams = {
            fn: random.Random(f"{seed}:loader:{fn}") for fn in self._specs
        }
        self._jitter_specs: Dict[str, List[LoaderJitter]] = {}
        for j in jitter_specs:
            self._jitter_specs.setdefault(j.function, []).append(j)
        self._jitter_streams = {
            fn: random.Random(f"{seed}:jitter:{fn}")
            for fn in self._jitter_specs
        }

    def draw(self, function: str, t: float) -> bool:
        """True iff this arrival's db load leg should fail. Always draws
        when the function has any LoaderFault spec."""
        specs = self._specs.get(function)
        if not specs:
            return False
        u = self._streams[function].random()
        return any(s.start_s <= t < s.end_s and u < s.probability
                   for s in specs)

    def jitter(self, function: str, t: float) -> float:
        """Extra load-leg seconds for this arrival (0.0 outside every
        window). Always draws when the function has any LoaderJitter spec
        — window membership must not drift the stream position."""
        specs = self._jitter_specs.get(function)
        if not specs:
            return 0.0
        u = self._jitter_streams[function].random()
        extra = 0.0
        for s in specs:
            if s.start_s <= t < s.end_s:
                # inverse-CDF Pareto tail from the single uniform draw
                extra += s.scale_s * (max(u, 1e-12) ** (-1.0 / s.alpha) - 1.0)
        return extra


@dataclass(frozen=True)
class FaultPlan:
    """An immutable, seeded fault schedule. ``events()`` returns the
    scheduled (non-draw) faults as sorted ``(t, kind, payload)`` tuples
    with kinds ``crash | restart | degrade_on | degrade_off | db_down |
    db_up | slow_on | slow_off | leak_on | leak_off``; ``make_draws()``
    returns a fresh :class:`FaultDraws` for the per-arrival loader-fault
    and jitter streams."""
    specs: Tuple = ()
    seed: int = 0

    def __post_init__(self):
        object.__setattr__(self, "specs", tuple(self.specs))
        for s in self.specs:
            if not isinstance(s, (NodeCrash, LinkDegradation, LoaderFault,
                                  DbFlap, SlowNode, LoaderJitter, MemoryLeak)):
                raise TypeError(f"unknown fault spec {type(s).__name__}")

    @property
    def loader_faults(self) -> Tuple[LoaderFault, ...]:
        return tuple(s for s in self.specs if isinstance(s, LoaderFault))

    @property
    def loader_jitters(self) -> Tuple[LoaderJitter, ...]:
        return tuple(s for s in self.specs if isinstance(s, LoaderJitter))

    def events(self) -> List[Tuple[float, str, object]]:
        ev: List[Tuple[float, str, object]] = []
        for s in self.specs:
            if isinstance(s, NodeCrash):
                ev.append((s.at_s, "crash", s))
                if s.restart_after_s is not None:
                    ev.append((s.at_s + s.restart_after_s, "restart", s))
            elif isinstance(s, LinkDegradation):
                ev.append((s.at_s, "degrade_on", s))
                ev.append((s.at_s + s.duration_s, "degrade_off", s))
            elif isinstance(s, DbFlap):
                ev.append((s.at_s, "db_down", s))
                ev.append((s.at_s + s.duration_s, "db_up", s))
            elif isinstance(s, SlowNode):
                ev.append((s.at_s, "slow_on", s))
                if s.duration_s is not None:
                    ev.append((s.at_s + s.duration_s, "slow_off", s))
            elif isinstance(s, MemoryLeak):
                ev.append((s.at_s, "leak_on", s))
                if s.duration_s is not None:
                    ev.append((s.at_s + s.duration_s, "leak_off", s))
        ev.sort(key=lambda e: (e[0], e[1]))
        return ev

    def make_draws(self) -> FaultDraws:
        return FaultDraws(self.seed, self.loader_faults, self.loader_jitters)


class ShedError(RuntimeError):
    """Request rejected by the load shedder (strict-mode runtime raise;
    the record carries ``error_class == "shed"``)."""


class BreakerOpenError(RuntimeError):
    """Request rejected by an open circuit breaker (strict-mode runtime
    raise; the record carries ``error_class == "breaker"``)."""


# ----------------------------------------------------------------------
# circuit breaker (per-function, closed -> open -> half-open)
# ----------------------------------------------------------------------
@dataclass(frozen=True)
class BreakerConfig:
    """Per-function circuit-breaker policy. The breaker opens when, over
    the last ``window`` outcomes (and at least ``min_requests`` of them),
    the failure fraction reaches ``failure_threshold``; it stays open for
    ``cooldown_s``, then admits ``half_open_probes`` probe requests — one
    probe failure reopens it, all probes succeeding closes it."""
    failure_threshold: float = 0.5
    window: int = 20
    min_requests: int = 5
    cooldown_s: float = 5.0
    half_open_probes: int = 2

    def __post_init__(self):
        if not (0.0 < self.failure_threshold <= 1.0):
            raise ValueError("failure_threshold must be in (0, 1]")
        if self.window < 1 or self.min_requests < 1:
            raise ValueError("window and min_requests must be >= 1")
        if self.cooldown_s <= 0 or self.half_open_probes < 1:
            raise ValueError("cooldown_s must be > 0, half_open_probes >= 1")


class CircuitBreaker:
    """One function's breaker. ``clock`` is any ``() -> float`` — virtual
    time in the sim, ``time.monotonic`` in the runtime — so the state
    machine is identical on both drivers. Thread-safe (the runtime feeds
    outcomes from worker done-callbacks)."""

    __slots__ = ("cfg", "_clock", "_lock", "_state", "_outcomes",
                 "_opened_at", "_probes_inflight", "_probes_ok",
                 "transitions")

    def __init__(self, cfg: BreakerConfig, clock):
        self.cfg = cfg
        self._clock = clock
        self._lock = threading.Lock()
        self._state = "closed"
        self._outcomes: List[bool] = []  # sliding window, True = failure
        self._opened_at = 0.0
        self._probes_inflight = 0
        self._probes_ok = 0
        self.transitions: List[Tuple[float, str]] = []

    @property
    def state(self) -> str:
        with self._lock:
            return self._state

    def _transition(self, state: str) -> None:
        self._state = state
        self.transitions.append((self._clock(), state))

    def allow(self) -> bool:
        """Gate one request. In half-open state this *claims* a probe
        slot, so callers must report the outcome via record()."""
        with self._lock:
            if self._state == "closed":
                return True
            now = self._clock()
            if self._state == "open":
                if now - self._opened_at < self.cfg.cooldown_s:
                    return False
                self._transition("half_open")
                self._probes_inflight = 0
                self._probes_ok = 0
            # half-open: admit up to half_open_probes concurrent probes
            if self._probes_inflight >= self.cfg.half_open_probes:
                return False
            self._probes_inflight += 1
            return True

    def record(self, ok: bool) -> None:
        """Feed one admitted request's outcome."""
        with self._lock:
            if self._state == "half_open":
                self._probes_inflight = max(0, self._probes_inflight - 1)
                if not ok:
                    self._opened_at = self._clock()
                    self._transition("open")
                    return
                self._probes_ok += 1
                if self._probes_ok >= self.cfg.half_open_probes:
                    self._transition("closed")
                    self._outcomes.clear()
                return
            if self._state == "open":
                return  # stale outcome from before the trip
            self._outcomes.append(not ok)
            if len(self._outcomes) > self.cfg.window:
                del self._outcomes[:len(self._outcomes) - self.cfg.window]
            n = len(self._outcomes)
            if n >= self.cfg.min_requests:
                fails = sum(self._outcomes)
                if fails / n >= self.cfg.failure_threshold:
                    self._opened_at = self._clock()
                    self._transition("open")


# ----------------------------------------------------------------------
# priority-aware load shedding
# ----------------------------------------------------------------------
@dataclass(frozen=True)
class SheddingConfig:
    """Watermark shedding over normalized loader pressure. Pressure is
    the mean over *healthy* nodes of ``min(1, (pending admissions +
    loader queue) / (saturation * loader_threads))``. At or above
    ``watermark`` requests with ``priority <= loose_priority_max`` are
    shed; at or above ``hard_watermark`` everything is shed. Loose
    classes are sacrificed first — the tight-class SLO under overload is
    the benchmark headline (benchmarks/chaos.py)."""
    watermark: float = 0.7
    hard_watermark: float = 0.95
    loose_priority_max: int = 0
    saturation: float = 8.0

    def __post_init__(self):
        if not (0.0 < self.watermark <= self.hard_watermark <= 1.0):
            raise ValueError("need 0 < watermark <= hard_watermark <= 1")
        if self.saturation <= 0:
            raise ValueError("saturation must be > 0")

    def should_shed(self, pressure: float, priority: int) -> bool:
        if pressure >= self.hard_watermark:
            return True
        return pressure >= self.watermark and priority <= self.loose_priority_max


def node_pressure(pending_admissions: int, loader_queue: int,
                  loader_threads: int, saturation: float) -> float:
    """One node's normalized shed pressure in [0, 1] (shared by both
    drivers so the shed decision sequence matches)."""
    cap = max(1.0, saturation * max(1, loader_threads))
    return min(1.0, (pending_admissions + loader_queue) / cap)
