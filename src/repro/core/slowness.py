"""Gray-failure tolerance primitives (docs/resilience.md, "Gray failures").

PR 7's resilience layer is binary — a node is crashed or healthy — but the
sharing-aware dispatch concentrates a function's traffic on the node where
its read-only data is resident, so one slow-but-alive node (degraded PCIe,
jittery loader, leaking memory) silently drags the tail of every function
homed there. This module is the shared tail-tolerance layer both drivers
consume byte-for-byte:

* :class:`EwmaDetector` — the single EWMA slowness primitive (the training
  loop's ``StragglerWatchdog`` is a thin wrapper over it);
* :class:`SlownessDetector` — per-node per-stage EWMA + P² p95 profiles,
  scoring nodes *suspect* when a stage drifts past ``factor x`` the fleet
  median for ``min_samples`` consecutive observations, and grading
  ``NodeSnapshot.health_score`` for dispatch;
* :class:`HedgeConfig` / :class:`QuarantineConfig` — the knob surfaces
  (``hedging=`` / ``quarantine=`` accept a config, a kwargs dict, or
  ``True``), normalized via :func:`resolve_hedging` /
  :func:`resolve_quarantine`;
* :class:`QuarantineController` — the drain -> cooldown -> canary-probation
  -> readmit-or-retire state machine (breaker-style half-open probing,
  applied to nodes instead of functions).

Everything here is passive bookkeeping: the drivers own time, scheduling,
and the drain/readmit mechanics, so virtual-time and wall-time replays run
the identical decision logic.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.core.sim.metrics import P2Quantile

__all__ = [
    "EwmaDetector",
    "SlownessDetector",
    "HedgeConfig",
    "QuarantineConfig",
    "HedgedError",
    "resolve_hedging",
    "resolve_quarantine",
    "QuarantineController",
    "HEDGE_STAT_KEYS",
]

# resilience_stats() keys this layer contributes on BOTH drivers
# (tests/test_faults.py::test_resilience_stats_backend_key_parity)
HEDGE_STAT_KEYS = ("hedges_launched", "hedges_won", "hedges_wasted",
                   "quarantines", "readmits")


class HedgedError(RuntimeError):
    """A hedge loser: the invocation was superseded by its faster twin.

    Never surfaces from ``Invocation.wait()`` — the winning twin's result
    is the request's outcome; the loser's record is marked ``dropped`` with
    ``error_class == "hedged"``.
    """


class EwmaDetector:
    """One EWMA stream with a multiplicative straggler threshold.

    ``observe(value)`` returns True when ``value > factor * ewma`` (the
    ewma *before* this observation — a straggler must not drag the
    baseline it is judged against). This is the shared primitive behind
    both the serving-side :class:`SlownessDetector` streams and the
    training loop's ``StragglerWatchdog``.
    """

    __slots__ = ("factor", "alpha", "ewma", "count")

    def __init__(self, factor: float = 2.5, alpha: float = 0.2):
        if factor <= 1.0:
            raise ValueError(f"factor must be > 1, got {factor}")
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        self.factor = factor
        self.alpha = alpha
        self.ewma: Optional[float] = None
        self.count = 0

    def observe(self, value: float) -> bool:
        """Feed one observation; True if it is a straggler vs the EWMA."""
        self.count += 1
        flagged = self.ewma is not None and value > self.factor * self.ewma
        if self.ewma is None:
            self.ewma = value
        else:
            self.ewma = self.alpha * value + (1.0 - self.alpha) * self.ewma
        return flagged


def _median(vals: List[float]) -> float:
    s = sorted(vals)
    n = len(s)
    return s[n // 2] if n % 2 else 0.5 * (s[n // 2 - 1] + s[n // 2])


class _Stage:
    __slots__ = ("ewma", "count", "p95")

    def __init__(self, quantile: float):
        self.ewma: Optional[float] = None
        self.count = 0
        self.p95 = P2Quantile(quantile)


class _DurationWindow:
    """Exact quantile over the last ``window`` samples.

    The hedge estimate cannot use a streaming P² sketch: the first samples
    a function ever sees are its cold loads, and P² markers seeded seconds
    high stay high for hundreds of warm samples (the parabolic update
    moves marker *positions* one step per sample, not marker heights), so
    the hedge timer would never fire. A bounded ring forgets the cold
    start once warm traffic displaces it.
    """

    __slots__ = ("window", "count", "_buf", "_idx")

    def __init__(self, window: int = 128):
        self.window = window
        self.count = 0
        self._buf: List[float] = []
        self._idx = 0

    def add(self, value: float) -> None:
        self.count += 1
        if len(self._buf) < self.window:
            self._buf.append(value)
        else:
            self._buf[self._idx] = value
            self._idx = (self._idx + 1) % self.window

    def quantile(self, q: float) -> float:
        s = sorted(self._buf)
        return s[min(int(q * len(s)), len(s) - 1)]


class SlownessDetector:
    """Per-node per-stage latency profiles + fleet-relative suspicion.

    Rides the existing telemetry flow: each finalized record feeds
    ``observe(node, stage, value)`` per stage (both drivers call
    :meth:`observe_record`). A node is **suspect** when some stage's EWMA
    exceeds ``factor x`` the fleet median of that stage's per-node EWMAs
    for ``min_samples`` consecutive observations (both the node's stream
    and at least one peer must have ``min_samples`` observations first —
    a one-node fleet has no median to drift from).

    ``health_score(node)`` grades the same signal continuously in
    ``(0, 1]`` for dispatch scoring: 1.0 with no evidence of drift,
    ``median / ewma`` (clamped to 1.0) once the node's worst stage runs
    hotter than the fleet.
    """

    # stages fed from records; "load" is cpu_data + gpu_data (+ gpu_ctx)
    STAGES = ("load", "compute")

    def __init__(self, factor: float = 2.5, alpha: float = 0.2,
                 min_samples: int = 8, quantile: float = 0.95):
        if factor <= 1.0:
            raise ValueError(f"factor must be > 1, got {factor}")
        if min_samples < 1:
            raise ValueError(f"min_samples must be >= 1, got {min_samples}")
        self.factor = factor
        self.alpha = alpha
        self.min_samples = min_samples
        self.quantile = quantile
        self._stages: Dict[Tuple[str, str], _Stage] = {}
        self._streak: Dict[Tuple[str, str], int] = {}
        # per-function total-duration window (hedge launch estimates)
        self._durations: Dict[str, _DurationWindow] = {}
        self.observations = 0

    # -- feeding -------------------------------------------------------
    def _stage(self, node_id: str, stage: str) -> _Stage:
        st = self._stages.get((node_id, stage))
        if st is None:
            st = self._stages[(node_id, stage)] = _Stage(self.quantile)
        return st

    def _peer_median(self, node_id: str, stage: str) -> Optional[float]:
        """Fleet median of the stage EWMA over *mature* streams (>=
        min_samples), excluding ``node_id`` so a slow node cannot drag its
        own baseline. None until at least one mature peer exists."""
        peers = [s.ewma for (n, sg), s in self._stages.items()
                 if sg == stage and n != node_id
                 and s.count >= self.min_samples and s.ewma is not None]
        if not peers:
            return None
        return _median(peers)

    def observe(self, node_id: str, stage: str, value: float) -> bool:
        """Feed one stage observation; True if it breaches the fleet
        threshold (the breach streak, not one flag, makes a suspect)."""
        self.observations += 1
        st = self._stage(node_id, stage)
        st.count += 1
        st.p95.add(value)
        if st.ewma is None:
            st.ewma = value
        else:
            st.ewma = self.alpha * value + (1.0 - self.alpha) * st.ewma
        med = self._peer_median(node_id, stage)
        key = (node_id, stage)
        if (med is not None and med > 0.0
                and st.count >= self.min_samples
                and st.ewma > self.factor * med):
            self._streak[key] = self._streak.get(key, 0) + 1
            return True
        self._streak[key] = 0
        return False

    def observe_record(self, node_id: str, function: str,
                       stages: Dict[str, float], duration: float) -> None:
        """Feed one finalized successful record (both drivers' call site).

        The per-function duration sketch describes what a *healthy* node
        delivers, so a currently-suspect node's samples are excluded —
        otherwise a slow node's stragglers drag the hedge quantile up
        until the timer always fires just after the straggler finishes
        and no hedge ever launches."""
        self.observe(node_id, "compute", stages.get("compute", 0.0))
        load = (stages.get("cpu_data", 0.0) + stages.get("gpu_data", 0.0)
                + stages.get("gpu_ctx", 0.0))
        if load > 0.0:
            self.observe(node_id, "load", load)
        if self.is_suspect(node_id):
            return
        d = self._durations.get(function)
        if d is None:
            d = self._durations[function] = _DurationWindow()
        d.add(duration)

    def is_slow_sample(self, node_id: str, stage: str, value: float) -> bool:
        """One-shot straggler check for a canary: is this raw sample past
        ``factor x`` the fleet median? (No streak — a probation node has a
        freshly reset stream and cannot wait ``min_samples``.)"""
        med = self._peer_median(node_id, stage)
        return med is not None and med > 0.0 and value > self.factor * med

    def reset_node(self, node_id: str) -> None:
        """Forget a node's streams (quarantine wipes the evidence — a
        readmitted node is judged on post-readmission behavior only)."""
        for key in [k for k in self._stages if k[0] == node_id]:
            del self._stages[key]
        for key in [k for k in self._streak if k[0] == node_id]:
            del self._streak[key]

    # -- verdicts ------------------------------------------------------
    def is_suspect(self, node_id: str) -> bool:
        """Sustained drift: some stage breached for >= min_samples
        consecutive observations."""
        return any(n == node_id and streak >= self.min_samples
                   for (n, _sg), streak in self._streak.items())

    def suspects(self) -> List[str]:
        return sorted({n for (n, _sg), streak in self._streak.items()
                       if streak >= self.min_samples})

    def health_score(self, node_id: str) -> float:
        """Graded health in (0, 1]; 1.0 absent evidence of drift."""
        score = 1.0
        for stage in self.STAGES:
            st = self._stages.get((node_id, stage))
            if st is None or st.ewma is None or st.ewma <= 0.0 \
                    or st.count < self.min_samples:
                continue
            med = self._peer_median(node_id, stage)
            if med is None or med <= 0.0:
                continue
            score = min(score, med / st.ewma)
        return score

    def estimate(self, function: str,
                 min_samples: Optional[int] = None) -> Optional[float]:
        """Hedge-launch latency estimate: the function's duration quantile
        once enough observations back it (``HedgeConfig.min_samples`` at
        the hedging call sites); None before that."""
        need = self.min_samples if min_samples is None else min_samples
        d = self._durations.get(function)
        if d is None or d.count < need:
            return None
        return d.quantile(self.quantile)


# ---------------------------------------------------------------------------
# knob surfaces
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class HedgeConfig:
    """Deadline-aware hedged redispatch (docs/resilience.md).

    An invocation still unfinished ``hedge_quantile`` into its learned
    latency distribution launches ONE speculative duplicate on the best
    non-suspect node; first completion wins, the loser is cancelled
    byte-exactly and its record marked ``dropped``/``hedged``. The
    duplicate is charged to the request's ``max_retries`` budget.
    """

    hedge_quantile: float = 0.95  # launch when p_q estimate elapses
    min_samples: int = 10         # per-function observations before hedging
    delay_factor: float = 1.0     # multiplier on the estimate

    def __post_init__(self):
        if not 0.0 < self.hedge_quantile < 1.0:
            raise ValueError(
                f"hedge_quantile must be in (0, 1), got {self.hedge_quantile}")
        if self.min_samples < 1:
            raise ValueError(
                f"min_samples must be >= 1, got {self.min_samples}")
        if self.delay_factor <= 0.0:
            raise ValueError(
                f"delay_factor must be > 0, got {self.delay_factor}")


@dataclass(frozen=True)
class QuarantineConfig:
    """Suspect-node quarantine (docs/resilience.md).

    Detector thresholds (``factor``/``min_samples``/``alpha``) define a
    sustained suspect; a suspect is drained (PR-8 ``drain_node`` path),
    held out for ``cooldown_s``, then readmitted **cold in probation**:
    its first ``canary_count`` completions are judged one-shot against the
    fleet median — any slow canary retires the node, all-clean readmits
    it fully (breaker-style half-open, per node).
    """

    factor: float = 2.5      # stage EWMA vs fleet-median threshold
    alpha: float = 0.2       # EWMA smoothing
    min_samples: int = 8     # consecutive breaches to declare a suspect
    cooldown_s: float = 5.0  # drain -> probe wait (workload seconds)
    canary_count: int = 3    # probation completions that must come back clean

    def __post_init__(self):
        if self.factor <= 1.0:
            raise ValueError(f"factor must be > 1, got {self.factor}")
        if self.min_samples < 1:
            raise ValueError(
                f"min_samples must be >= 1, got {self.min_samples}")
        if self.cooldown_s <= 0.0:
            raise ValueError(
                f"cooldown_s must be > 0, got {self.cooldown_s}")
        if self.canary_count < 1:
            raise ValueError(
                f"canary_count must be >= 1, got {self.canary_count}")


def resolve_hedging(value) -> Optional[HedgeConfig]:
    """Normalize ``hedging=True|dict|HedgeConfig|None`` to a config."""
    if value is None or value is False:
        return None
    if value is True:
        return HedgeConfig()
    if isinstance(value, HedgeConfig):
        return value
    if isinstance(value, dict):
        return HedgeConfig(**value)
    raise TypeError(
        f"hedging must be True, a dict, or a HedgeConfig, "
        f"got {type(value).__name__}")


def resolve_quarantine(value) -> Optional[QuarantineConfig]:
    """Normalize ``quarantine=True|dict|QuarantineConfig|None``."""
    if value is None or value is False:
        return None
    if value is True:
        return QuarantineConfig()
    if isinstance(value, QuarantineConfig):
        return value
    if isinstance(value, dict):
        return QuarantineConfig(**value)
    raise TypeError(
        f"quarantine must be True, a dict, or a QuarantineConfig, "
        f"got {type(value).__name__}")


def make_detector(hedging: Optional[HedgeConfig],
                  quarantine: Optional[QuarantineConfig]) -> SlownessDetector:
    """One shared detector per driver, parameterized by whichever knob is
    on (quarantine owns the suspicion thresholds, hedging the estimate
    quantile)."""
    q = quarantine or QuarantineConfig()
    quantile = hedging.hedge_quantile if hedging is not None else 0.95
    return SlownessDetector(factor=q.factor, alpha=q.alpha,
                            min_samples=q.min_samples, quantile=quantile)


class QuarantineController:
    """Per-node drain -> cooldown -> probation -> readmit/retire machine.

    Passive: the driver feeds completions (:meth:`note_completion`) and
    asks for due probes (:meth:`due_probes`); the returned actions
    ("quarantine" / "probe" / "readmit" / "retire") are executed by the
    driver through its own drain/restore machinery, so virtual-time and
    wall-time replays share the decision logic exactly.
    """

    ACTIVE, QUARANTINED, PROBATION, RETIRED = (
        "active", "quarantined", "probation", "retired")

    def __init__(self, cfg: QuarantineConfig, detector: SlownessDetector):
        self.cfg = cfg
        self.detector = detector
        self.quarantines = 0
        self.readmits = 0
        self._state: Dict[str, str] = {}
        self._probe_at: Dict[str, float] = {}
        self._canaries: Dict[str, int] = {}

    def state(self, node_id: str) -> str:
        return self._state.get(node_id, self.ACTIVE)

    def note_completion(self, node_id: str, now: float,
                        compute_s: float) -> Optional[str]:
        """Feed one successful completion *after* the detector was fed.
        Returns the action the driver must take: ``"quarantine"`` (drain
        the node now), ``"readmit"`` (probation passed — fully readmit),
        ``"retire"`` (a canary came back slow — retire for good), or None.
        """
        st = self.state(node_id)
        if st == self.ACTIVE:
            if self.detector.is_suspect(node_id):
                self._state[node_id] = self.QUARANTINED
                self._probe_at[node_id] = now + self.cfg.cooldown_s
                self.quarantines += 1
                # wipe the evidence: probation judges post-readmit behavior
                self.detector.reset_node(node_id)
                return "quarantine"
            return None
        if st == self.PROBATION:
            if self.detector.is_slow_sample(node_id, "compute", compute_s):
                self._state[node_id] = self.RETIRED
                return "retire"
            left = self._canaries.get(node_id, self.cfg.canary_count) - 1
            if left <= 0:
                self._state[node_id] = self.ACTIVE
                self._canaries.pop(node_id, None)
                self.readmits += 1
                return "readmit"
            self._canaries[node_id] = left
            return None
        return None

    def due_probes(self, now: float) -> List[str]:
        """Quarantined nodes whose cooldown elapsed: the driver readmits
        each cold and the node enters probation (canary half-open)."""
        due = [n for n, t in self._probe_at.items()
               if now >= t and self.state(n) == self.QUARANTINED]
        for n in due:
            self._state[n] = self.PROBATION
            self._canaries[n] = self.cfg.canary_count
            del self._probe_at[n]
        return due

    def next_probe_at(self) -> Optional[float]:
        """Earliest pending cooldown expiry (drivers schedule a timer)."""
        pending = [t for n, t in self._probe_at.items()
                   if self.state(n) == self.QUARANTINED]
        return min(pending) if pending else None

    def stats(self) -> Dict[str, int]:
        return {"quarantines": self.quarantines, "readmits": self.readmits}
