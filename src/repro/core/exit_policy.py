"""Multi-stage resource exit (paper §6.3, Fig 9).

After an invocation completes, an instance's resources are released in
stages, each holding for a TTL (paper: 30 s per stage; each stage's interval
equals the previous one):

  stage 1: GPU context + read-only device data held   (warmest)
  stage 2: GPU context held; read-only data cached to host RAM
  stage 3: GPU context dropped; host data + CPU context held
  stage 4: host data dropped; container held
  stage 5: destroyed (cold)

Stages are evaluated *lazily* from (now - completion time), which makes the
ladder identical under the real clock and the virtual clock; side-effecting
transitions (freeing device memory, dropping the executable) are applied by
``advance`` exactly once per crossed boundary.

A warm hit at stage k skips every setup stage the paper's Table 4 shows
hidden at that stage; ``stage_skips`` maps stage -> skipped setup stages.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

DEFAULT_TTL = 30.0  # seconds per stage (paper §6.3)

# setup stages skipped on a warm hit at each ladder stage (Table 4 semantics)
stage_skips: Dict[int, Tuple[str, ...]] = {
    1: ("container_create", "cpu_ctx", "cpu_data", "gpu_ctx", "gpu_data"),
    2: ("container_create", "cpu_ctx", "cpu_data", "gpu_ctx"),  # re-PCIe gpu_data
    3: ("container_create", "cpu_ctx", "cpu_data"),  # re-create ctx, re-PCIe
    4: ("container_create", "cpu_ctx"),  # re-read db, re-create ctx
}


@dataclass
class ExitLadder:
    """Per function-instance ladder state."""

    ttls: Tuple[float, float, float, float] = (DEFAULT_TTL,) * 4
    completion_t: Optional[float] = None  # None while running / before first run
    applied_stage: int = 0  # last stage whose exit actions ran (0 = active)
    # actions[stage] runs when the ladder *leaves* the previous stage
    on_enter: Dict[int, Callable[[], None]] = field(default_factory=dict)
    # absolute time the NEXT stage boundary is crossed — ``advance`` is a
    # no-op before it. inf while running (stage pinned at 0) and once
    # destroyed. Cache safety: ``ttls`` is only reassigned at instance
    # creation, before the first ``on_complete``, so a cached boundary can
    # never be computed from superseded TTLs.
    _next_t: float = field(default=float("inf"), repr=False)

    def stage_at(self, now: float) -> int:
        """1..4 = warm ladder stage; 5 = destroyed; 0 = currently running."""
        if self.completion_t is None:
            return 0
        dt = now - self.completion_t
        acc = 0.0
        for i, ttl in enumerate(self.ttls, start=1):
            acc += ttl
            if dt < acc:
                return i
        return 5

    def advance(self, now: float) -> int:
        """Apply any exit actions for newly-entered stages; return stage.

        Fast path: nodes re-scan every idle ladder on each completion
        (``_advance_ladders``), so the overwhelmingly common call finds no
        boundary crossed — it returns the memoized stage without touching
        ``stage_at``. Time is monotone under both clocks, so the applied
        stage can only grow between calls.
        """
        if now < self._next_t:
            return self.applied_stage
        s = self.stage_at(now)
        if s == 0:
            return 0
        for k in range(max(self.applied_stage + 1, 2), s + 1):
            cb = self.on_enter.get(k)
            if cb:
                cb()
        self.applied_stage = max(self.applied_stage, s)
        if s >= 5:
            self._next_t = float("inf")
        else:
            self._next_t = self.completion_t + sum(self.ttls[:s])
        return s

    def on_complete(self, now: float) -> None:
        self.completion_t = now
        self.applied_stage = 1  # stage 1 holds everything: no action needed
        self._next_t = now + self.ttls[0]

    def on_reuse(self, now: float) -> int:
        """A new invocation arrived: stop the exit, report the stage it hit
        (after applying any pending transitions)."""
        s = self.advance(now)
        self.completion_t = None
        self.applied_stage = 0
        self._next_t = float("inf")
        return s
