"""Builders turning model-zoo architectures into serverless GPUFunctions.

The real runtime serves *actual* reduced models: the GPU context is a real
``jax.jit(...).lower(...).compile()`` executable, weights are a real pytree
fetched from the database, compute is the real forward pass. Declared sizes
(A100-scale, from paper Table 2 profiles or the arch's true byte count)
drive the brokered transfer times and memory accounting.
"""
from __future__ import annotations

import math
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.configs.base import ModelConfig
from repro.core.engine import GPUFunction
from repro.core.profiles import MB, FunctionProfile
from repro.core.request import Data, DataType, Request
from repro.data.database import Database
from repro.models import forward, init_params


def make_model_function(
    db: Database,
    fn_name: str,
    arch: str = "qwen3-8b",
    *,
    batch: int = 1,
    seq: int = 16,
    profile: Optional[FunctionProfile] = None,
    declared_ro_bytes: Optional[int] = None,
    seed: int = 0,
) -> GPUFunction:
    """Build an inference GPUFunction backed by a reduced ``arch`` model."""
    cfg = ARCHS[arch].reduced()
    params = init_params(cfg, jax.random.PRNGKey(seed))
    real_bytes = sum(x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(params))
    ro_bytes = declared_ro_bytes or (
        int(profile.read_only_mb * MB) if profile else real_bytes
    )
    weights_key = f"{fn_name}/weights"
    db.put(weights_key, params, size=ro_bytes)

    param_shapes = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params
    )
    tok_shape = jax.ShapeDtypeStruct((batch, seq), jnp.int32)

    def context_builder():
        # the 'GPU context': a real AOT compile (shape-only, no data — the
        # knowability property that makes parallel setup possible)
        fwd = lambda p, t: forward(cfg, p, {"tokens": t})[0]
        return jax.jit(fwd).lower(param_shapes, tok_shape).compile()

    def handler(shim, request: Request):
        w = shim.sage_load_to_gpu(weights_key)
        x = shim.sage_load_to_gpu(request.in_data[1].key)
        logits = shim.launch_kernel(shim.gpu_ctx, w, x)
        out_key = f"{fn_name}/out/{request.uuid}"
        shim.sage_dump_to_db(out_key, np.asarray(logits[:, -1, :8]))
        return out_key

    return GPUFunction(
        name=fn_name,
        handler=handler,
        context_builder=context_builder,
        read_only={weights_key: ro_bytes},
        writable_hint=int(profile.writable_mb * MB) if profile else batch * seq * 4,
        compute_s_hint=(profile.compute_ms / 1e3) if profile else 0.0,
    )


def make_request(
    db: Database,
    fn: GPUFunction,
    *,
    batch: int = 1,
    seq: int = 16,
    input_bytes: int = 4 * MB,
    vocab: int = 256,
    seed: int = 0,
) -> Request:
    """A request whose metadata declares everything loadable (Fig 8)."""
    tokens = np.random.default_rng(seed).integers(0, vocab, (batch, seq), dtype=np.int32)
    req = Request(function_name=fn.name)
    in_key = f"{fn.name}/in/{req.uuid}"
    db.put(in_key, jnp.asarray(tokens), size=input_bytes)
    ro_key = next(iter(fn.read_only))
    req.in_data = [
        Data(key=ro_key, size=fn.read_only[ro_key], dtype=DataType.READ_ONLY),
        Data(key=in_key, size=input_bytes, dtype=DataType.WRITABLE),
    ]
    return req
