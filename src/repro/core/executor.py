"""Kernel executor (paper §5.2.2): receives kernel calls from the taxon
shim, verifies with the memory daemon that all operand data is resident on
device, then launches. This is the correctness barrier that makes the
parallelized cold setup safe.

Failure contract: if a daemon loader failed (or was cancelled), resolving
the operand raises :class:`DataLoadError` out of ``launch`` — the launch
never blocks on an entry whose loader is already dead. ``wait_timeout``
additionally bounds waits on *live* loads (None = unbounded, the daemon's
own load deadline is the backstop)."""
from __future__ import annotations

import threading
from typing import Any, Dict, Optional, Tuple

from repro.core.daemon import DataLoadError, Handle


class KernelExecutor:
    def __init__(self, clock=None, wait_timeout: Optional[float] = None):
        self.clock = clock
        self.wait_timeout = wait_timeout
        self._lock = threading.Lock()
        self.launched = 0
        self.wait_time = 0.0  # time spent blocked on data readiness

    def _resolve(self, x):
        if isinstance(x, Handle):
            return x.wait(self.wait_timeout)
        return x

    def launch(self, fn, args: Tuple, kwargs: Dict) -> Any:
        import time as _t

        t0 = _t.monotonic()
        rargs = [self._resolve(a) for a in args]
        rkwargs = {k: self._resolve(v) for k, v in kwargs.items()}
        waited = _t.monotonic() - t0
        with self._lock:
            self.wait_time += waited
            self.launched += 1
        return fn(*rargs, **rkwargs)
