"""Data-loading path model: fair-share bandwidth brokers.

The paper's Fig 4 shows concurrent invocations suffer 34.9x data-loading
slowdowns because they contend on disk, network, and PCIe. We model each
path as a progressively-filled fair-share link: all active transfers split
the bandwidth equally; completion times are recomputed on every arrival/
departure (max-min fairness with identical demands).

Two drivers share this implementation:
* the threaded runtime calls :meth:`transfer` (blocking; sleeps real time),
* the discrete-event simulator calls :meth:`sim_transfer` (virtual time via
  callbacks).

Hardware constants calibrated from the paper's Table 4 (resnet50): CPU data
109.6 MB in 67.2 ms -> ~1.63 GB/s database path; GPU data 109.6 MB in
21.7 ms -> ~5.05 GB/s effective PCIe.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional

from repro.core.clock import EventKind, RealClock, VirtualClock
from repro.core.transfer import TransferStream

# calibrated from paper Table 4 (see module docstring)
DB_BANDWIDTH = 1.63e9     # bytes/s: database -> host (disk+network)
PCIE_BANDWIDTH = 5.05e9   # bytes/s: host -> device
# TPU adaptation: host -> HBM on v5e rides PCIe gen4-class links too; the
# same broker models it (constant overridable per deployment).


class BandwidthBroker:
    """Fair-share link. Thread-safe blocking mode + virtual-time mode.

    ``concurrency_penalty`` models sub-linear aggregate bandwidth under
    concurrent streams (HDD seek thrash on the paper's 2 TB HDD database
    path, Table 3): aggregate = bw / (1 + p*(n-1)).
    """

    def __init__(self, bandwidth: float, clock=None, name: str = "link",
                 concurrency_penalty: float = 0.0, max_streams: int = 32):
        self.bw = float(bandwidth)
        # degradation tracking (docs/resilience.md): ``bw`` is always
        # ``base_bw * degradation``, so fault windows compose and restore
        # exactly, and the transfer pacing layer (LinkArbiter.chunk_hint)
        # can read the current health factor off the link
        self.base_bw = float(bandwidth)
        self.degradation = 1.0
        self.penalty = float(concurrency_penalty)
        self.max_streams = max_streams  # connection-pool bound (FIFO queue)
        self._waitq: list = []
        self.clock = clock or RealClock()
        self.name = name
        # per-transfer contention history (bytes, observed, solo). Disable
        # for trace-scale replays: a million-invocation run would retain
        # millions of tuples nobody reads (record_mode="aggregate" flips it)
        self.keep_history = True
        self._epoch = 0
        self._lock = threading.Condition()
        self._active: Dict[int, list] = {}  # id -> [remaining_bytes]
        self._seq = 0
        self._last_t = self.clock.now()
        # stats
        self.total_bytes = 0.0
        self.total_busy_time = 0.0
        self.max_concurrency = 0

    # ------------------------------------------------------------------
    def _drain(self, now: float) -> None:
        """Advance all active transfers to ``now`` (equal split)."""
        n = len(self._active)
        if n:
            rate = self.bw / n / (1.0 + self.penalty * (n - 1))
            dt = max(now - self._last_t, 0.0)
            for ent in self._active.values():
                ent[0] -= rate * dt
            self.total_busy_time += dt
        self._last_t = now

    def _next_finish(self) -> Optional[float]:
        if not self._active:
            return None
        n = len(self._active)
        rate = self.bw / n / (1.0 + self.penalty * (n - 1))
        rem = min(ent[0] for ent in self._active.values())
        return max(rem, 0.0) / rate

    # ------------------------------------------------------------------
    # blocking (threaded runtime)
    # ------------------------------------------------------------------
    def transfer(self, nbytes: float, *, scale: float = 1.0) -> float:
        """Block until ``nbytes`` have 'moved' under fair sharing.

        ``scale`` < 1 lets tests compress modeled time. Returns the modeled
        duration."""
        if nbytes <= 0:
            return 0.0
        with self._lock:
            now = self.clock.now()
            self._drain(now)
            self._seq += 1
            tid = self._seq
            self._active[tid] = [float(nbytes) * scale]
            self.total_bytes += nbytes
            self.max_concurrency = max(self.max_concurrency, len(self._active))
            self._lock.notify_all()
            t0 = now
            while True:
                now = self.clock.now()
                self._drain(now)
                if self._active[tid][0] <= 1e-9:
                    del self._active[tid]
                    self._lock.notify_all()
                    return now - t0
                n = len(self._active)
                eta = self._active[tid][0] / (self.bw / n / (1.0 + self.penalty * (n - 1)))
                self._lock.wait(timeout=min(eta, 0.05))

    # ------------------------------------------------------------------
    # chunked streams (preemptible transfer engine, core/transfer.py)
    # ------------------------------------------------------------------
    def open_stream(self, nbytes: float, *, scale: float = 1.0) -> TransferStream:
        """Open a chunked, preemptible stream over this link. The stream's
        ``advance``/``sim_advance`` calls ride the same fair-share
        machinery as :meth:`transfer`/:meth:`sim_transfer`; ``pause`` /
        ``resume`` / ``cancel`` keep byte accounting exact (only moved
        bytes are charged). A single full-size advance is byte- and
        time-identical to one blocking :meth:`transfer` call."""
        return TransferStream(self, nbytes, scale=scale)

    # ------------------------------------------------------------------
    # virtual time (simulator)
    # ------------------------------------------------------------------
    def sim_transfer(self, nbytes: float, done: Callable[[], None]) -> None:
        """Virtual-time transfer; ``done`` fires at completion. Requires a
        VirtualClock."""
        assert isinstance(self.clock, VirtualClock)
        now = self.clock.now()
        self._drain(now)
        if self.max_streams and len(self._active) >= self.max_streams:
            # connection pool exhausted: FIFO-queue the transfer (without a
            # bound, unbounded streams + seek penalty collapse the link)
            self._waitq.append((nbytes, done))
            return
        self._seq += 1
        tid = self._seq
        t0 = now

        def done_and_record():
            if self.keep_history:
                # contention history: (bytes, observed duration, solo duration)
                self.history.append((nbytes, self.clock.now() - t0, nbytes / self.bw))
            if done is not None:
                done()
            while self._waitq and len(self._active) < self.max_streams:
                nb, cb = self._waitq.pop(0)
                self.sim_transfer(nb, cb)

        self._active[tid] = [float(nbytes), done_and_record]
        self.total_bytes += nbytes
        self.max_concurrency = max(self.max_concurrency, len(self._active))
        self._reschedule()

    @property
    def history(self):
        if not hasattr(self, "_history"):
            self._history = []
        return self._history

    def mean_slowdown(self) -> float:
        """Observed contention factor (the paper's Fig 4 metric)."""
        h = [(d / s) for _, d, s in self.history if s > 0]
        return sum(h) / len(h) if h else 1.0

    def _reschedule(self) -> None:
        """(Re)arm the next-completion event (a typed TRANSFER event with
        the epoch riding the event args — no per-reschedule closure)."""
        nf = self._next_finish()
        if nf is None:
            return
        self._epoch += 1
        self.clock.schedule(max(nf, 0.0), self._fire, self._epoch,
                            kind=EventKind.TRANSFER)

    def _fire(self, epoch: int) -> None:
        if epoch != self._epoch:  # superseded by a later arrival
            return
        now = self.clock.now()
        self._drain(now)
        # 0.5-byte slack: guarantees progress even when float error
        # leaves a sliver after the projected finish time
        finished = [t for t, ent in self._active.items() if ent[0] <= 0.5]
        if not finished and self._active:
            # force the minimum-remaining transfer out (progress guard)
            tmin = min(self._active, key=lambda t: self._active[t][0])
            if self._active[tmin][0] <= 1.0:
                finished = [tmin]
        for t in finished:
            ent = self._active.pop(t)
            if len(ent) > 1 and ent[1] is not None:
                ent[1]()
        self._reschedule()

    # ------------------------------------------------------------------
    # fault injection hooks (docs/resilience.md)
    # ------------------------------------------------------------------
    def _rerate(self) -> None:
        """Apply ``base_bw * degradation`` mid-run with exact in-flight
        accounting: active transfers are drained to now at the OLD rate
        first, so completed progress is preserved; in virtual time the
        next-completion event is re-armed at the new rate (the epoch guard
        retires the stale one). Threaded transfers recompute their rate
        every wait slice and need only a wake-up. Caller holds the lock."""
        self._drain(self.clock.now())
        self.bw = self.base_bw * self.degradation
        if isinstance(self.clock, VirtualClock):
            self._reschedule()
        else:
            self._lock.notify_all()

    def set_bandwidth(self, bandwidth: float) -> None:
        """Change the link's BASE rate mid-run (any active degradation
        factor stays applied on top)."""
        with self._lock:
            self.base_bw = float(bandwidth)
            self._rerate()

    def apply_degradation(self, factor: float) -> None:
        """Compound a degradation window onto the link (``degrade_on``):
        overlapping windows multiply, exactly like the pre-tracking
        ``set_bandwidth(bw * factor)`` chains, but the base rate is never
        lost to float drift on restore."""
        if factor <= 0.0:
            raise ValueError(f"degradation factor must be > 0, got {factor}")
        with self._lock:
            self.degradation *= float(factor)
            self._rerate()

    def clear_degradation(self, factor: Optional[float] = None) -> None:
        """End a degradation window (``degrade_off``): divide ``factor``
        back out, or reset to healthy with no argument. In-flight chunked
        streams pick the restored rate up mid-stream — completed bytes
        stay charged at the degraded rate."""
        with self._lock:
            if factor is None:
                self.degradation = 1.0
            else:
                self.degradation /= float(factor)
                if abs(self.degradation - 1.0) < 1e-12:
                    self.degradation = 1.0  # exact restore for one window
            self._rerate()

    def reset(self) -> None:
        """Drop every in-flight and queued transfer WITHOUT firing their
        completions (node crash: the invocations they belonged to are
        failed by the crash path, and a completion landing afterwards
        would resurrect freed state). The epoch bump retires any
        already-scheduled completion event. Virtual-time only: the
        threaded driver's crash path cancels loads at the daemon's
        checkpoints instead (a blocking transfer must drain its own
        active slot)."""
        assert isinstance(self.clock, VirtualClock)
        with self._lock:
            self._drain(self.clock.now())
            self._active.clear()
            self._waitq.clear()
            self._epoch += 1
            self._lock.notify_all()

    # ------------------------------------------------------------------
    def solo_time(self, nbytes: float) -> float:
        """Uncontended transfer time (the Fig-2 'solo-run' reference)."""
        return nbytes / self.bw

    def contention_factor(self) -> float:
        """Observed mean slowdown proxy: max concurrency seen."""
        return float(self.max_concurrency)


@dataclass
class DataPaths:
    """The three contended paths of §3.2.2."""

    db: BandwidthBroker
    pcie: BandwidthBroker

    @classmethod
    def make(cls, clock=None, db_bw: float = DB_BANDWIDTH, pcie_bw: float = PCIE_BANDWIDTH,
             db_seek_penalty: float = 0.06):
        return cls(
            db=BandwidthBroker(db_bw, clock, "db",
                               concurrency_penalty=db_seek_penalty),
            pcie=BandwidthBroker(pcie_bw, clock, "pcie"),
        )
