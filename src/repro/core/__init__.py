from repro.core.baselines import SYSTEMS, SystemPolicy, get_system  # noqa: F401
from repro.core.daemon import DataLoadError, OutOfDeviceMemory  # noqa: F401
from repro.core.dispatch import DISPATCH_POLICIES, NodeSnapshot  # noqa: F401
from repro.core.engine import FunctionEngine, GPUFunction  # noqa: F401
from repro.core.request import Data, DataType, Request  # noqa: F401
from repro.core.runtime import ClusterRuntime, SageRuntime  # noqa: F401
