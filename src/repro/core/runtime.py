"""SageRuntime: the node-level serverless runtime (paper Fig 5).

``SageInit`` wires the four modules — per-function engines, taxon shim,
unified memory daemon, kernel executor — over a device; ``SageRun``
processes one invocation end-to-end. The same runtime object runs any
``SystemPolicy`` (SAGE or the baselines), which is how every benchmark
compares systems on identical mechanism code.

This is the *real* threaded runtime: context creation is an actual
``jax.jit`` compile, data movement is an actual ``device_put`` (with the
fair-share brokers modeling A100-scale transfer times), compute is the
actual jitted model. The virtual-time twin for trace-scale experiments is
``core.simulator``.
"""
from __future__ import annotations

import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Dict, List, Optional

from repro.core.baselines import SystemPolicy, get_system
from repro.core.clock import RealClock
from repro.core.compute import (
    ThreadedComputePlane, empty_compute_stats, resolve_compute,
)
from repro.core.daemon import SCHEDULERS, MemoryDaemon
from repro.core.datapath import DataPaths
from repro.core.placement import (
    DISPATCH_POLICIES, NodeSnapshot, PlacementControl, choose_node,
    resolve_autoscale,
)
from repro.core.engine import FunctionEngine, GPUFunction
from repro.core.executor import KernelExecutor
from repro.core.request import Request
from repro.core.telemetry import InvocationRecord, Telemetry
from repro.data.database import Database


class SageRuntime:
    def __init__(
        self,
        policy: SystemPolicy | str = "sage",
        *,
        database: Optional[Database] = None,
        device_capacity: int = 40 << 30,
        host_capacity: int = 125 << 30,
        time_scale: float = 1.0,
        exit_ttl: float = 30.0,
        max_workers: int = 32,
        serialize_compute: bool = True,
        loader_threads: int = 4,
        load_timeout_s: float = 30.0,
        scheduler: str = "fifo",
        transfer: str = "run_to_completion",
        chunk_bytes: Optional[int] = None,
        node_id: str = "gpu0",
        compute=None,
    ):
        self.policy = get_system(policy) if isinstance(policy, str) else policy
        self.node_id = node_id  # telemetry attribution (ClusterRuntime names)
        self.clock = RealClock()
        self.db = database or Database()
        self.paths = DataPaths.make(self.clock)
        self.daemon = MemoryDaemon(
            self.paths, self.db, device_capacity=device_capacity,
            host_capacity=host_capacity,
            clock=self.clock, time_scale=time_scale,
            loader_threads=loader_threads, load_timeout_s=load_timeout_s,
            # deadline-aware ("edf") or arrival-order ("fifo") load/admission
            # scheduling — consumed by the daemon's loader queue and OOM
            # admission wait (docs/dataplane.md)
            scheduler=scheduler,
            # chunked-stream transfer mode: "preemptive" lets an in-flight
            # loose load yield the link to a tighter queued one between
            # chunks; the default reproduces atomic run-to-completion
            # transfers (docs/dataplane.md, "Transfer scheduling")
            transfer=transfer,
            **({} if chunk_bytes is None else {"chunk_bytes": chunk_bytes}),
            # the bounded pool is SAGE's unified-daemon machinery; baseline
            # platforms load per-invocation (ungated), same as the sim twin
            pooled=self.policy.name.startswith("sage"),
        )
        self.executor = KernelExecutor(self.clock)
        self.telemetry = Telemetry()
        self.engines: Dict[str, FunctionEngine] = {}
        self.time_scale = time_scale
        self.exit_ttl = exit_ttl
        self._pool = ThreadPoolExecutor(max_workers=max_workers)
        self._compute_lock = threading.Lock() if serialize_compute else None
        # shared compute plane (docs/compute.md): when on, the whole-node
        # handler lock is replaced by the fractional slice budget (+
        # optional same-function batching). The handler wrapper consults
        # ``self._plane`` at CALL time, so set_compute() applies to
        # functions registered before it.
        self._compute = resolve_compute(compute)
        self._plane = (ThreadedComputePlane(self._compute, self.clock)
                       if self._compute is not None else None)
        self.daemon.set_evictable_provider(self._evictable)
        self._initialized = False
        # fault-injection health (docs/resilience.md): a crashed node
        # fast-fails everything with NodeLostError until restore()
        self.healthy = True
        self.crashes = 0
        # gray failure (docs/resilience.md, "Gray failures"): a SlowNode
        # window multiplies this node's service time — the engine leg is
        # stretched by a measured-dt sleep in sage_run, the transfer legs
        # by the gateway degrading both of this node's links. 1.0 (the
        # default) multiplies by exactly 1 and sleeps exactly 0.
        self.slow_factor = 1.0
        # dynamic node pool (docs/planner.md): a draining node takes no
        # new placements; once its in-flight work finishes it is retired
        # via the same teardown path a crash uses. ``_inflight`` counts
        # submitted-but-unfinished invocations (the drain idle check).
        self.draining = False
        self.retired = False
        self._inflight = 0

    # ------------------------------------------------------------------
    def _evictable(self):
        out = []
        for e in self.engines.values():
            out.extend(e.evictable_entries())
        return out

    # ------------------------------------------------------------------
    # public API (paper §4.2)
    # ------------------------------------------------------------------
    def sage_init(self) -> None:
        """Initialize the runtime (API parity with the paper's SageInit)."""
        self._initialized = True

    def register_function(self, fn: GPUFunction) -> None:
        fn = self._wrap_compute(fn)
        self.engines[fn.name] = FunctionEngine(
            fn, self.policy, self.daemon, self.executor, self.clock,
            time_scale=self.time_scale, exit_ttl=self.exit_ttl,
        )

    def _wrap_compute(self, fn: GPUFunction) -> GPUFunction:
        """One GPU: by default kernel executions serialize under the
        whole-node lock (matches Throughput_theo = 1/T_comp). With a
        shared compute plane attached (docs/compute.md) the handler runs
        under a fractional slice grant instead, optionally batched with
        concurrent same-function arrivals. The wrapper reads
        ``self._plane`` per call, so ``set_compute`` applies to functions
        registered before it; it wraps only the handler's compute."""
        inner = fn.handler
        runtime = self

        def handler(shim, request):
            plane = runtime._plane
            if plane is not None:
                return plane.run(wrapped, inner, shim, request)
            lock = runtime._compute_lock
            if lock is not None:
                with lock:
                    return inner(shim, request)
            return inner(shim, request)

        import dataclasses

        wrapped = dataclasses.replace(fn, handler=handler)
        return wrapped

    def sage_run(self, request: Request) -> Any:
        """Blocking invocation (the paper's SageRun)."""
        assert self._initialized, "call sage_init() first"
        if request.arrival_t is None:
            # stamp the request too (not only the record): EDF admission
            # derives the absolute deadline from arrival_t + deadline_s,
            # and an unstamped request would re-base it at every stage
            request.arrival_t = self.clock.now()
        eng = self.engines[request.function_name]
        rec = InvocationRecord(
            request_id=request.uuid, function=request.function_name,
            system=self.policy.name,
            # None-sentinel: an explicit arrival_t of 0.0 is a real arrival
            # time and must not be clobbered by the clock
            arrival_t=self.clock.now() if request.arrival_t is None
            else request.arrival_t,
            start_t=self.clock.now(),
            deadline_s=request.deadline_s, priority=request.priority,
            max_retries=request.max_retries,
            node_id=self.node_id, dispatch_tier=request.dispatch_tier,
            redispatches=request.redispatches,
        )
        try:
            result = eng.invoke(request, rec)
            if self.slow_factor > 1.0:
                # SlowNode gray failure: stretch the measured COMPUTE leg
                # (the load legs are already slowed by the fault's link
                # degradations; stretching wall elapsed instead would
                # multiply slot/admission queue waits too and feed back
                # into an unbounded backlog)
                extra = (rec.stages.get("compute", 0.0)
                         * (self.slow_factor - 1.0))
                self.clock.sleep(extra)
                # account the stretch where it was served — the per-node
                # latency profiler reads stage timings, not durations
                rec.stages["compute"] = rec.stages.get("compute", 0.0) + extra
            rec.result = result
            return result
        except Exception as exc:
            # data-plane/handler failure: record it (telemetry `error` field)
            # and re-raise so the caller's Future carries the exception —
            # the runtime pool thread is freed either way, never deadlocked
            rec.error = f"{type(exc).__name__}: {exc}"
            raise
        finally:
            rec.end_t = self.clock.now()
            self.telemetry.add(rec)

    def submit(self, request: Request) -> Future:
        if request.arrival_t is None:
            request.arrival_t = self.clock.now()
        self._inflight += 1
        fut = self._pool.submit(self.sage_run, request)
        fut.add_done_callback(self._submit_done)
        return fut

    def _submit_done(self, _fut) -> None:
        self._inflight -= 1

    # ------------------------------------------------------------------
    # fault injection (docs/resilience.md)
    # ------------------------------------------------------------------
    def crash(self, reason: str = "node crashed") -> None:
        """Kill this node: every in-flight and future invocation fails
        with a typed :class:`~repro.core.daemon.NodeLostError`, all
        instances are torn down, and device/host accounting rolls back to
        zero (the data-plane invariant tests assert the exact rollback).
        Idempotent; :meth:`restore` brings the node back cold."""
        if not self.healthy:
            return
        self.healthy = False
        self.crashes += 1
        # order matters: the daemon flips dead first so loads blocked in
        # admission/loader waits fail typed, then instance teardown
        # releases the exact context/slot/private bytes each engine holds
        self.daemon.crash(reason)
        for eng in self.engines.values():
            for inst in list(eng.instances):
                eng._destroy(inst)

    def restore(self) -> None:
        """Rejoin after a crash — cold: nothing resident, empty pool."""
        if self.healthy:
            return
        self.daemon.restore()
        self.healthy = True

    # ------------------------------------------------------------------
    # dynamic node pool: graceful drain (docs/planner.md)
    # ------------------------------------------------------------------
    def is_idle(self) -> bool:
        return self._inflight == 0

    def drain_teardown(self) -> None:
        """Retire a drained node once idle: the SAME teardown a crash
        runs (daemon teardown + engine instance destroy — exact
        context/slot/byte release, docs/resilience.md), but graceful:
        nothing is in flight, so no invocation fails and the crash
        counters stay untouched."""
        if self.retired:
            return
        assert self.is_idle(), f"drain_teardown on busy node {self.node_id}"
        self.retired = True
        self.daemon.crash("node drained")
        for eng in self.engines.values():
            for inst in list(eng.instances):
                eng._destroy(inst)

    # ------------------------------------------------------------------
    @property
    def scheduler(self) -> str:
        return self.daemon.scheduler

    def set_scheduler(self, scheduler: str) -> None:
        """Switch loader/admission ordering ("fifo"|"edf"); applies to jobs
        and waiters enqueued after the call."""
        if scheduler not in SCHEDULERS:
            raise ValueError(
                f"unknown scheduler {scheduler!r}; use one of {SCHEDULERS}")
        self.daemon.scheduler = scheduler

    @property
    def transfer(self) -> str:
        return self.daemon.transfer

    def set_transfer(self, transfer: str) -> None:
        """Switch the transfer mode ("run_to_completion"|"preemptive");
        applies to chunks advanced after the call."""
        self.daemon.set_transfer(transfer)

    def set_compute(self, compute) -> None:
        """Enable (or swap) the shared compute plane — the spec adoption
        path (docs/compute.md). Applies to handler calls entered after
        the call; ``"exclusive"``/None restores the whole-node lock."""
        self._compute = resolve_compute(compute)
        self._plane = (ThreadedComputePlane(self._compute, self.clock)
                       if self._compute is not None else None)

    def compute_stats(self) -> Dict[str, object]:
        """Compute-plane counters (key parity with the sim twin's
        ``compute_stats`` — docs/compute.md)."""
        if self._plane is None:
            return empty_compute_stats("exclusive", 0)
        return self._plane.stats()

    def dispatch_snapshot(self, function: str,
                          health_score: float = 1.0) -> NodeSnapshot:
        """This node's residency/pressure for ``function`` at dispatch
        time (docs/cluster.md): one cheap read per counter group, never
        blocking on in-flight loads. ``health_score`` carries the
        SlownessDetector's grade when slowness detection is on
        (docs/resilience.md) — the default 1.0 scores identically to the
        binary-health seed."""
        tier, ro_bytes = self.daemon.residency(function)
        return NodeSnapshot(node_id=self.node_id, ro_tier=tier,
                            ro_bytes=ro_bytes, healthy=self.healthy,
                            health_score=health_score,
                            compute_free_frac=(
                                self._plane.free_fraction()
                                if self._plane is not None else 1.0),
                            **self.daemon.pressure())

    def memory_usage(self) -> Dict[str, int]:
        return {
            "device_used": self.daemon.device_used,
            "context_bytes": self.daemon.context_bytes_used,
            "host_used": self.daemon.host_used,
        }

    def shutdown(self) -> None:
        self._pool.shutdown(wait=True)
        self.daemon.shutdown()


# ---------------------------------------------------------------------------
# Cluster runtime: N nodes + pluggable dispatch (paper §7.8 ran "random";
# "locality"/"least_loaded" are the sharing-aware policies of docs/cluster.md)
# ---------------------------------------------------------------------------


class ClusterRuntime:
    """SAGE's node-level optimizations are orthogonal to cluster scheduling;
    ``dispatch="random"`` mirrors the paper's 4-node experiment bit-for-bit
    (same seeded stream as the seed repo), while ``"locality"`` routes each
    invocation to the node where its function's read-only data is already
    resident — spilling to the least-pressured cold node under load."""

    def __init__(self, n_nodes: int = 4, seed: int = 0,
                 dispatch: str = "random", eviction: bool = False,
                 autoscale=None, **node_kwargs):
        import random

        if dispatch not in DISPATCH_POLICIES:
            raise ValueError(
                f"unknown dispatch {dispatch!r}; use one of {DISPATCH_POLICIES}")
        self._node_kwargs = dict(node_kwargs)
        self.nodes = [SageRuntime(node_id=f"gpu{i}", **node_kwargs)
                      for i in range(n_nodes)]
        self._node_seq = n_nodes
        self._rng = random.Random(seed)
        self.dispatch = dispatch
        # health-checked eviction (docs/resilience.md): when on, dispatch
        # drains crashed nodes — off keeps the seeded stream bit-identical
        self.eviction = eviction
        # placement control plane (docs/planner.md); inert by default
        self.autoscale = resolve_autoscale(autoscale)
        self._control: Optional[PlacementControl] = None
        self._control_lock = threading.Lock()
        self._has_drains = False
        self._initialized = False
        self._make_fns: List = []  # for registering on autoscaled joiners
        self._fn_weights: Dict[str, int] = {}  # planner working-set bytes
        # gateway hook: called with the new node after add_node wires it
        # (the gateway lowers its registered specs onto the joiner there)
        self.on_node_added = None
        # gateway hook (docs/resilience.md): ``node_id -> float`` grading
        # from the gateway's SlownessDetector; None keeps the seed's
        # binary-health snapshots (health_score=1.0 scores identically)
        self.health_score = None
        if dispatch == "planned" or self.autoscale is not None:
            self._ensure_control()

    def sage_init(self):
        self._initialized = True
        for n in self.nodes:
            n.sage_init()

    def register_function(self, make_fn) -> None:
        """``make_fn(node_idx)`` builds a per-node GPUFunction (each node
        needs its own compiled context). Kept for the dynamic pool: a
        node added later replays every registered builder."""
        self._make_fns.append(make_fn)
        fns = [make_fn(i) for i in range(len(self.nodes))]
        for n, fn in zip(self.nodes, fns):
            n.register_function(fn)
        if fns:
            self.note_function(fns[0].name, fns[0].total_bytes())

    def note_function(self, name: str, weight_bytes: int) -> None:
        """Planner churn signal for a function registered directly on the
        nodes (the gateway's spec-lowering path bypasses
        :meth:`register_function`): the planner gives it a home using
        ``weight_bytes`` as its working-set size."""
        self._fn_weights[name] = int(weight_bytes)
        if self._control is not None:
            self._control.register_function(name, weight_bytes)

    def retire_function(self, fn_name: str) -> None:
        """Churn signal (docs/planner.md): the planner frees the
        function's planned share; resident state ages out via the exit
        ladders. The engines stay registered so in-flight work finishes."""
        self._fn_weights.pop(fn_name, None)
        if self._control is not None:
            self._control.retire_function(fn_name)

    def set_autoscale(self, autoscale) -> None:
        """Enable (or swap) predictive autoscaling mid-run — the spec
        adoption path (docs/planner.md)."""
        self.autoscale = resolve_autoscale(autoscale)
        with self._control_lock:
            if self.autoscale is None:
                if self._control is not None:
                    self._control.set_autoscale(None)
                return
            self._ensure_control()
            self._control.set_autoscale(self.autoscale)

    # ------------------------------------------------------------------
    # dynamic node pool (docs/planner.md)
    # ------------------------------------------------------------------
    def _now(self) -> float:
        return self.nodes[0].clock.now() if self.nodes else 0.0

    def _ensure_control(self) -> None:
        if self._control is not None:
            return
        self._control = PlacementControl(
            [n.node_id for n in self.nodes], autoscale=self.autoscale,
            now=self._now())
        for name, wb in self._fn_weights.items():
            self._control.register_function(name, wb)

    def add_node(self) -> SageRuntime:
        """Provision one cold node: every registered function builder is
        replayed onto it and dispatch may target it immediately."""
        node = SageRuntime(node_id=f"gpu{self._node_seq}",
                           **self._node_kwargs)
        self._node_seq += 1
        # a later set_compute carries over to joiners (same contract as
        # the sim's add_node re-reading scheduler/transfer from a live node)
        live = next((n for n in self.nodes if not n.retired), None)
        if live is not None and live._compute is not node._compute \
                and (live._compute is not None or node._compute is not None):
            node.set_compute(live._compute)
        idx = len(self.nodes)
        if self._initialized:
            node.sage_init()
        for make_fn in self._make_fns:
            node.register_function(make_fn(idx))
        self.nodes.append(node)
        if self._control is not None:
            self._control.node_provisioned(node.node_id, self._now())
        if self.on_node_added is not None:
            self.on_node_added(idx, node)
        return node

    def drain_node(self, node_id) -> None:
        """Start a graceful drain (``node_id``: name or index): no new
        placements; the node retires — exact teardown, same path as a
        crash — once its in-flight invocations finish."""
        node = (self.nodes[node_id] if isinstance(node_id, int)
                else next(n for n in self.nodes if n.node_id == node_id))
        if node.draining or node.retired:
            return
        node.draining = True
        self._has_drains = True
        if self._control is not None:
            self._control.node_draining(node.node_id)
        self._try_finalize_drains()

    def _try_finalize_drains(self) -> None:
        for node in self.nodes:
            if node.draining and not node.retired and node.is_idle():
                node.drain_teardown()
                if self._control is not None:
                    self._control.node_retired(node.node_id, self._now())

    def _maybe_tick(self) -> None:
        """The control tick, piggybacked on dispatch (same contract as
        the sim twin: ticks ride arrivals, so an idle cluster runs no
        control thread)."""
        add, drain_ids = self._control.maybe_tick(self._now())
        for _ in range(add):
            self.add_node()
        for nid in drain_ids:
            self.drain_node(nid)
        if self._has_drains:
            self._try_finalize_drains()

    def placement_stats(self) -> Optional[Dict]:
        """Planner/stealer/autoscaler counters + the node-count timeline
        (None unless the control plane is on — docs/planner.md)."""
        if self._control is None:
            return None
        with self._control_lock:
            if self._has_drains:
                self._try_finalize_drains()
            return self._control.stats(self._now())

    # ------------------------------------------------------------------
    def dispatchable_indices(self):
        """Node indices dispatch may target. Draining/retired nodes
        leave the candidate set; otherwise the full range unless eviction
        is on AND some node is down — so with everything at defaults the
        seeded random stream consumes the exact same
        ``randrange(len(nodes))`` call as the seed repo."""
        if self._has_drains:
            idxs = [i for i, n in enumerate(self.nodes)
                    if not (n.draining or n.retired)
                    and (n.healthy or not self.eviction)]
            return idxs if idxs else range(len(self.nodes))
        if not self.eviction:
            return range(len(self.nodes))
        idxs = [i for i, n in enumerate(self.nodes) if n.healthy]
        return idxs if idxs else range(len(self.nodes))

    def _snap(self, node: SageRuntime, function_name: str) -> NodeSnapshot:
        """One dispatch snapshot, graded by the gateway's slowness
        detector when attached (docs/resilience.md)."""
        hs = self.health_score
        if hs is None:
            return node.dispatch_snapshot(function_name)
        return node.dispatch_snapshot(function_name,
                                      health_score=hs(node.node_id))

    def _planned_pick(self, function_name: str):
        """Shared planner pick: ``(idx, tier, snaps_by_idx)`` — the SAME
        ``PlacementPlanner.pick`` the simulator calls."""
        idxs = list(self.dispatchable_indices())
        snaps = [self._snap(self.nodes[i], function_name)
                 for i in idxs]
        pick, _hit = self._control.planner.pick(function_name, snaps)
        return idxs[pick], snaps[pick].ro_tier, (idxs, snaps)

    def select_node(self, function_name: str):
        """Pick the target node for one invocation of ``function_name``;
        returns ``(node_idx, residency_tier_at_dispatch)``. ``"random"``
        consumes the same seeded stream as the original ``rng.choice``
        dispatch, so seeded §7.8 replays are unchanged."""
        if self.dispatch == "planned" or self._control is not None:
            with self._control_lock:
                self._ensure_control()
                self._control.note_arrival(function_name)
                self._maybe_tick()
                if self.dispatch == "planned":
                    idx, tier, _ = self._planned_pick(function_name)
                    return idx, tier
        idxs = self.dispatchable_indices()
        if self.dispatch == "random":
            if len(idxs) == len(self.nodes):
                idx = self._rng.randrange(len(self.nodes))
            else:
                idx = idxs[self._rng.randrange(len(idxs))]
            return idx, self.nodes[idx].daemon.residency(function_name)[0]
        snaps = {i: self._snap(self.nodes[i], function_name)
                 for i in idxs}
        order = list(snaps)
        pick = choose_node(self.dispatch, [snaps[i] for i in order])
        idx = order[pick]
        return idx, snaps[idx].ro_tier

    def submit(self, request: Request) -> Future:
        """Dispatch + submit. With ``dispatch="planned"`` this is also
        the work-stealer's runtime entry: an arrival whose planned home
        is above the steal watermark parks (queued-but-unstarted) and is
        re-routed with fresh snapshots after ``board_delay_s`` — landing
        away from the home is a steal and charges the request's
        ``max_retries`` redispatch budget, like a crash re-dispatch."""
        if self.dispatch == "planned" and self._control is not None:
            with self._control_lock:
                self._control.note_arrival(request.function_name)
                self._maybe_tick()
                idxs = list(self.dispatchable_indices())
                snaps = [self._snap(self.nodes[i], request.function_name)
                         for i in idxs]
                decision = self._control.route(request.function_name, snaps)
                if decision[0] == "board":
                    home_id = self.nodes[idxs[decision[1]]].node_id
                    outer: Future = Future()
                    timer = threading.Timer(
                        self._control.planner.cfg.board_delay_s,
                        self._board_fire, args=(request, home_id, outer))
                    timer.daemon = True
                    timer.start()
                    return outer
                idx = idxs[decision[1]]
                request.dispatch_tier = snaps[decision[1]].ro_tier
                return self.nodes[idx].submit(request)
        idx, tier = self.select_node(request.function_name)
        request.dispatch_tier = tier
        return self.nodes[idx].submit(request)

    def _board_fire(self, request: Request, home_id: str,
                    outer: Future) -> None:
        """Drain one boarded request: re-route with fresh snapshots and
        chain the inner future into the one the submitter already holds."""
        with self._control_lock:
            idxs = list(self.dispatchable_indices())
            snaps = [self._snap(self.nodes[i], request.function_name)
                     for i in idxs]
            budget = request.max_retries is None or request.max_retries > 0
            if budget:
                pick, stole = self._control.reroute(
                    request.function_name, snaps, home_id)
            else:
                pick = next((k for k, s in enumerate(snaps)
                             if s.node_id == home_id), None)
                stole = False
                if pick is None:  # home drained/evicted while boarded
                    pick, _ = self._control.reroute(
                        request.function_name, snaps, home_id)
            if stole:
                request.redispatches += 1
            request.dispatch_tier = snaps[pick].ro_tier
            inner = self.nodes[idxs[pick]].submit(request)

        def _chain(f: Future) -> None:
            exc = f.exception()
            if exc is not None:
                outer.set_exception(exc)
            else:
                outer.set_result(f.result())

        inner.add_done_callback(_chain)

    @property
    def scheduler(self) -> str:
        return self.nodes[0].scheduler

    def set_scheduler(self, scheduler: str) -> None:
        for n in self.nodes:
            n.set_scheduler(scheduler)

    def set_dispatch(self, dispatch: str) -> None:
        """Switch the dispatch policy; applies to subsequent submits."""
        if dispatch not in DISPATCH_POLICIES:
            raise ValueError(
                f"unknown dispatch {dispatch!r}; use one of {DISPATCH_POLICIES}")
        self.dispatch = dispatch

    @property
    def transfer(self) -> str:
        return self.nodes[0].transfer

    def set_transfer(self, transfer: str) -> None:
        for n in self.nodes:
            n.set_transfer(transfer)

    def set_compute(self, compute) -> None:
        for n in self.nodes:
            n.set_compute(compute)

    def compute_stats(self) -> Dict[str, object]:
        """Compute-plane counters aggregated over nodes (key parity with
        the sim's ``compute_stats`` — docs/compute.md)."""
        per_node = [n.compute_stats() for n in self.nodes]
        if not per_node or all(s["mode"] == "exclusive" for s in per_node):
            return empty_compute_stats("exclusive", 0)
        out = next(s for s in per_node if s["mode"] == "shared")
        out = dict(mode="shared", slices=out["slices"], grants=0,
                   contended_grants=0, batches=0, batched=0)
        for s in per_node:
            if s["mode"] != "shared":
                continue
            out["grants"] += s["grants"]
            out["contended_grants"] += s["contended_grants"]
            out["batches"] += s["batches"]
            out["batched"] += s["batched"]
        return out

    @property
    def telemetry(self) -> Telemetry:
        t = Telemetry()
        for n in self.nodes:
            # public snapshot(): consistent copy under the node's lock —
            # pool threads may still be add()ing while a caller merges
            for rec in n.telemetry.snapshot():
                t.add(rec)  # keeps the merged view's find() index populated
        return t

    def shutdown(self):
        for n in self.nodes:
            n.shutdown()
