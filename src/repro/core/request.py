"""Request/Data structures (paper §5.1, Fig 8).

The key insight SAGE builds on: *the data a GPU function needs is knowable
from request metadata before execution*. ``Data`` carries the database key,
size, and read-write attribute; the engine hands the request's data list to
the memory daemon ahead of execution so loading overlaps context creation.
"""
from __future__ import annotations

import enum
import itertools
import uuid
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional


class DataType(enum.Enum):
    READ_ONLY = "ReadOnly"   # shareable across invocations (weights, tables)
    WRITABLE = "Writable"    # per-invocation (inputs, activations, outputs)


@dataclass
class Data:
    """Positional details of one external datum (Fig 8a)."""

    key: str                 # database key
    size: int                # bytes
    dtype: DataType = DataType.READ_ONLY
    device: str = "gpu"      # destination tier
    data_hptr: Any = None    # host-side payload (filled by the daemon)
    data_dptr: Any = None    # device-side handle (filled by the daemon)

    @property
    def read_only(self) -> bool:
        return self.dtype is DataType.READ_ONLY


_seq = itertools.count()


@dataclass
class Request:
    """One function invocation (Fig 8b).

    ``arrival_t`` uses ``None`` as the not-yet-arrived sentinel so a
    legitimate arrival at t=0.0 is preserved (the runtime stamps the clock
    only when the field is ``None``). ``deadline_s``/``priority`` carry
    per-request SLO metadata end-to-end; both drivers record them on the
    ``InvocationRecord`` (scheduling on them is a ROADMAP item).
    """

    function_name: str
    in_data: List[Data] = field(default_factory=list)
    out_data: List[Data] = field(default_factory=list)
    payload: Dict[str, Any] = field(default_factory=dict)  # small inline args
    uuid: str = field(default_factory=lambda: f"req-{next(_seq)}-{uuid.uuid4().hex[:6]}")
    arrival_t: Optional[float] = None
    deadline_s: Optional[float] = None   # SLO: seconds from arrival to finish
    priority: int = 0                    # higher = more urgent; orders loads
    #                                      and admission under scheduler="edf"
    # OOM-admission retry budget: how many backpressure re-attempts the
    # daemon may make before failing typed. None (default) keeps the flat
    # load_timeout_s behavior; 0 = fail-fast on the first OOM.
    max_retries: Optional[int] = None
    # stamped by the cluster dispatcher: the function's residency tier on
    # the chosen node at dispatch time (telemetry attribution only)
    dispatch_tier: Optional[str] = None
    # times this request was re-routed after dispatch (crash re-dispatch
    # or a work-steal off a saturated planned home — docs/planner.md);
    # shares the max_retries budget and lands on the record
    redispatches: int = 0
    # fault injection (docs/resilience.md): the gateway's seeded
    # per-arrival loader-fault draw landed True — the daemon poisons the
    # entries this request creates, so its db leg fails typed after
    # consuming bandwidth. Always False on the default path.
    fault_injected: bool = False
    # gray-failure injection (docs/resilience.md, "Gray failures"): extra
    # seconds the daemon stalls this request's db load leg (the gateway's
    # seeded LoaderJitter draw). Always 0.0 on the default path.
    jitter_s: float = 0.0
    # hedged redispatch (docs/resilience.md): a ``threading.Event`` the
    # gateway sets when this request's twin wins the race. The engine
    # checks it at its setup checkpoints and aborts with HedgedError —
    # cooperative, so every abort path still runs the byte-exact release
    # chain. None (default) is never checked.
    hedge_cancel: Any = None

    def loadable(self) -> List[Data]:
        """Data the daemon can prepare *before* execution (the knowability
        property): everything listed in in_data."""
        return list(self.in_data)
