"""Unified memory daemon (paper §4.1, §5, §6).

One daemon per device. It owns all device memory, performs *proactive* data
loading (the parallelized-setup half of SAGE), and implements read-only
memory sharing (the throughput half):

* ``prepare(request)`` starts async loads for every ``Data`` the request
  declares (knowability) — database -> host over the db path, host -> device
  over the PCIe path, both fair-share brokered;
* read-only entries are content-addressed by (function, key): the first
  invocation loads, the rest attach (refcount) — this is what removes the
  34.9x data-path contention;
* the multi-stage exit ladder calls ``demote_to_host`` / ``drop_host`` to
  walk cached entries down the tiers (device -> host -> gone).

TPU adaptation note (DESIGN.md §2): CUDA-IPC cross-process sharing becomes
single-broker buffer-handle sharing — the daemon owns ``jax.Array``s and
invocations hold references. Capacity accounting uses the declared A100-scale
sizes (``Data.size``) while payloads are real (reduced) arrays, so the
admission/eviction logic is exercised truthfully on CPU.
"""
from __future__ import annotations

import enum
import threading
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.clock import RealClock
from repro.core.datapath import DataPaths
from repro.core.request import Data, DataType, Request

GPU_CONTEXT_BYTES = 414 * 1024 * 1024  # paper §1/§3: 414 MB per GPU context


class Tier(enum.Enum):
    LOADING_HOST = "loading_host"
    HOST = "host"
    LOADING_DEV = "loading_dev"
    DEVICE = "device"
    DROPPED = "dropped"


@dataclass
class Entry:
    """One shared (or private) datum tracked by the daemon."""

    function: str
    key: str
    size: int
    read_only: bool
    tier: Tier = Tier.LOADING_HOST
    refcount: int = 0
    host_obj: Any = None
    dev_obj: Any = None
    ready = None  # threading.Event, set when on device
    last_used: float = 0.0

    def __post_init__(self):
        self.ready = threading.Event()


class Handle:
    """What the taxon shim hands the function for a memory call — resolved
    by the kernel executor right before launch (§5.2.2)."""

    def __init__(self, entry: Entry, daemon: "MemoryDaemon"):
        self.entry = entry
        self.daemon = daemon

    def is_ready(self) -> bool:
        return self.entry.ready.is_set()

    def wait(self, timeout: Optional[float] = None) -> Any:
        if not self.entry.ready.wait(timeout):
            raise TimeoutError(f"data {self.entry.key} not ready")
        return self.entry.dev_obj

    @property
    def size(self) -> int:
        return self.entry.size


class OutOfDeviceMemory(RuntimeError):
    pass


class MemoryDaemon:
    """Threaded real-mode daemon (virtual-time policy twin lives in
    ``core.simulator``; both share this module's accounting semantics)."""

    def __init__(
        self,
        paths: DataPaths,
        database,
        *,
        device_capacity: int = 40 << 30,  # A100-40GB (v5e would be 16 GiB)
        host_capacity: int = 125 << 30,
        clock=None,
        loader_threads: int = 4,
        time_scale: float = 1.0,
    ):
        self.paths = paths
        self.db = database
        self.clock = clock or RealClock()
        self.capacity = device_capacity
        self.host_capacity = host_capacity
        self.time_scale = time_scale
        self._lock = threading.RLock()
        self._entries: Dict[Tuple[str, str, Optional[str]], Entry] = {}
        self.device_used = 0
        self.host_used = 0
        self.context_bytes_used = 0
        self._evictable_cb: Optional[Callable[[], List["Entry"]]] = None
        self.stats = {"shared_hits": 0, "loads": 0, "bytes_loaded": 0,
                      "host_promotions": 0, "evictions": 0}

    # ------------------------------------------------------------------
    # device memory accounting (contexts + data)
    # ------------------------------------------------------------------
    def _reserve_device(self, nbytes: int) -> None:
        with self._lock:
            if self.device_used + nbytes > self.capacity:
                freed = self._evict(nbytes - (self.capacity - self.device_used))
                if self.device_used + nbytes > self.capacity:
                    raise OutOfDeviceMemory(
                        f"need {nbytes}, used {self.device_used}/{self.capacity} "
                        f"(freed {freed})"
                    )
            self.device_used += nbytes

    def _release_device(self, nbytes: int) -> None:
        with self._lock:
            self.device_used -= nbytes

    def reserve_context(self, nbytes: int = GPU_CONTEXT_BYTES) -> None:
        self._reserve_device(nbytes)
        with self._lock:
            self.context_bytes_used += nbytes

    def release_context(self, nbytes: int = GPU_CONTEXT_BYTES) -> None:
        self._release_device(nbytes)
        with self._lock:
            self.context_bytes_used -= nbytes

    def set_evictable_provider(self, cb: Callable[[], List[Entry]]) -> None:
        """Lesson-3 cache policy: the runtime tells the daemon which cached
        (stage-1/2, refcount-0) entries may be evicted for new arrivals."""
        self._evictable_cb = cb

    def _evict(self, need: int) -> int:
        freed = 0
        if not self._evictable_cb:
            return 0
        victims = sorted(self._evictable_cb(), key=lambda e: e.last_used)
        for e in victims:
            if freed >= need:
                break
            if e.refcount == 0 and e.tier is Tier.DEVICE:
                e.tier = Tier.DROPPED
                e.ready.clear()
                e.dev_obj = None
                self.device_used -= e.size
                freed += e.size
                self.stats["evictions"] += 1
        return freed

    # ------------------------------------------------------------------
    # prepare / attach (the proactive, parallel half)
    # ------------------------------------------------------------------
    def prepare(self, request: Request, *, system_shares_ro: bool = True) -> Dict[str, Handle]:
        """Start async loads for every declared datum; return handles now.

        Read-only data is deduplicated across invocations of the same
        function iff ``system_shares_ro`` (SAGE yes; baselines no)."""
        handles: Dict[str, Handle] = {}
        for d in request.loadable():
            shared = d.read_only and system_shares_ro
            ekey = (request.function_name, d.key, None if shared else request.uuid)
            with self._lock:
                e = self._entries.get(ekey)
                if e is not None and e.tier is not Tier.DROPPED:
                    e.refcount += 1
                    e.last_used = self.clock.now()
                    self.stats["shared_hits"] += 1
                    handles[d.key] = Handle(e, self)
                    if e.tier is Tier.HOST:
                        # promote host -> device (PCIe only; no db re-read):
                        # stage-2 warm hit of the exit ladder
                        e.tier = Tier.LOADING_DEV
                        self.stats["host_promotions"] += 1
                        threading.Thread(
                            target=self._load_dev, args=(e,), daemon=True
                        ).start()
                    continue
                e = Entry(
                    function=request.function_name, key=d.key, size=d.size,
                    read_only=shared, refcount=1,
                )
                e.last_used = self.clock.now()
                self._entries[ekey] = e
                self.stats["loads"] += 1
                self.stats["bytes_loaded"] += d.size
                handles[d.key] = Handle(e, self)
            threading.Thread(target=self._load_full, args=(e,), daemon=True).start()
        return handles

    def _load_full(self, e: Entry) -> None:
        # database -> host (db path contention)
        payload = self.db.fetch(e.key, self.paths.db, scale=self.time_scale)
        with self._lock:
            e.host_obj = payload
            self.host_used += e.size
            e.tier = Tier.HOST
        self._load_dev(e)

    def _load_dev(self, e: Entry) -> None:
        # host -> device (PCIe path contention)
        self.paths.pcie.transfer(e.size, scale=self.time_scale)
        self._reserve_device(e.size)
        dev = self.db.to_device(e.host_obj)
        with self._lock:
            e.dev_obj = dev
            e.tier = Tier.DEVICE
        e.ready.set()

    # ------------------------------------------------------------------
    # explicit allocation (cudaMalloc-style via the shim)
    # ------------------------------------------------------------------
    def alloc(self, request: Request, key: str, nbytes: int) -> Handle:
        self._reserve_device(nbytes)
        e = Entry(function=request.function_name, key=key, size=nbytes,
                  read_only=False, tier=Tier.DEVICE, refcount=1)
        e.last_used = self.clock.now()
        e.ready.set()
        with self._lock:
            self._entries[(request.function_name, key, request.uuid)] = e
        return Handle(e, self)

    # ------------------------------------------------------------------
    # release / exit-ladder actions
    # ------------------------------------------------------------------
    def release(self, request: Request, handles: Dict[str, Handle]) -> None:
        """Invocation finished: writable data freed; read-only refcount--
        (entries stay cached on device for the exit ladder to manage)."""
        with self._lock:
            for h in handles.values():
                e = h.entry
                e.refcount -= 1
                e.last_used = self.clock.now()
                if not e.read_only and e.refcount <= 0:
                    if e.tier is Tier.DEVICE:
                        self.device_used -= e.size
                    if e.host_obj is not None:
                        self.host_used -= e.size
                    e.tier = Tier.DROPPED
                    e.dev_obj = e.host_obj = None

    def function_entries(self, function: str) -> List[Entry]:
        with self._lock:
            return [e for (f, _, _), e in self._entries.items() if f == function]

    def demote_to_host(self, function: str) -> int:
        """Exit stage 2: cached read-only device copies -> host RAM."""
        n = 0
        with self._lock:
            for e in self.function_entries(function):
                if e.read_only and e.refcount == 0 and e.tier is Tier.DEVICE:
                    e.tier = Tier.HOST
                    e.dev_obj = None
                    e.ready.clear()
                    self.device_used -= e.size
                    n += e.size
        return n

    def drop_host(self, function: str) -> int:
        """Exit stage 4: host copies dropped."""
        n = 0
        with self._lock:
            for e in self.function_entries(function):
                if e.read_only and e.refcount == 0 and e.tier in (Tier.HOST, Tier.DEVICE):
                    if e.tier is Tier.DEVICE:
                        self.device_used -= e.size
                    self.host_used -= e.size
                    e.tier = Tier.DROPPED
                    e.dev_obj = e.host_obj = None
                    e.ready.clear()
                    n += e.size
        return n

    def evictable_entries(self, function: str) -> List[Entry]:
        return [
            e for e in self.function_entries(function)
            if e.read_only and e.refcount == 0 and e.tier is Tier.DEVICE
        ]
