"""Unified memory daemon (paper §4.1, §5, §6).

One daemon per device. It owns all device memory, performs *proactive* data
loading (the parallelized-setup half of SAGE), and implements read-only
memory sharing (the throughput half):

* ``prepare(request)`` starts async loads for every ``Data`` the request
  declares (knowability) — database -> host over the db path, host -> device
  over the PCIe path, both fair-share brokered;
* read-only entries are content-addressed by (function, key): the first
  invocation loads, the rest attach (refcount) — this is what removes the
  34.9x data-path contention;
* the multi-stage exit ladder calls ``demote_to_host`` / ``drop_host`` to
  walk cached entries down the tiers (device -> host -> gone).

Loading runs on a **bounded loader pool** sized by ``loader_threads`` (the
db/PCIe paths never see more concurrent streams than workers), and every
loader failure is **propagated**, not swallowed: an exception inside a load
is captured on the entry and re-raised as :class:`DataLoadError` from every
``Handle.wait()``. Device admission inside a load retries with backpressure
(waiting for releases/evictions) up to ``load_timeout_s`` before failing.
``release()`` of a still-loading writable entry cancels the load; the loader
rolls back its own accounting, so ``device_used``/``host_used`` never leak.
The host tier is admission-controlled too: past ``host_capacity`` the daemon
evicts refcount-0 HOST entries, then fails the load with a typed error.

Scheduling is SLO-aware when ``scheduler="edf"``: both the loader queue and
the OOM-admission wait are ordered by ``(priority desc, absolute deadline,
arrival)`` — under backpressure the waiter with the tightest remaining slack
is admitted first instead of whoever wakes first (HAS-GPU/FaaSTube-style
deadline-driven transfer scheduling). The default ``"fifo"`` keeps strict
arrival order. See docs/dataplane.md for the full contract.

With ``transfer="preemptive"`` the transfer legs themselves become
preemptible: every load leg is a chunked :class:`~repro.core.transfer.
TransferStream`, and between chunks the :class:`~repro.core.transfer.
LinkArbiter` checks whether a strictly tighter ``(priority, deadline)``
class is waiting on the loader queue. If so, the in-flight stream pauses
(completed bytes kept), its continuation re-queues under its own key, and
the worker it held picks up the tighter job — an in-flight loose 8 GB load
yields the link to a 50 MB tight-deadline load mid-transfer instead of
holding it run-to-completion. The default ``"run_to_completion"`` drives
each leg as one full-size advance, reproducing the pre-stream behavior
bit-for-bit.

TPU adaptation note (DESIGN.md §2): CUDA-IPC cross-process sharing becomes
single-broker buffer-handle sharing — the daemon owns ``jax.Array``s and
invocations hold references. Capacity accounting uses the declared A100-scale
sizes (``Data.size``) while payloads are real (reduced) arrays, so the
admission/eviction logic is exercised truthfully on CPU.
"""
from __future__ import annotations

import enum
import heapq
import itertools
import math
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.clock import RealClock
from repro.core.datapath import DataPaths
from repro.core.request import Data, DataType, Request
from repro.core.transfer import (
    DEFAULT_CHUNK_BYTES, TRANSFER_MODES, LinkArbiter, TransferStream,
)

GPU_CONTEXT_BYTES = 414 * 1024 * 1024  # paper §1/§3: 414 MB per GPU context

SCHEDULERS = ("fifo", "edf")

# Admission key: (-priority, absolute deadline, arrival seq). Comparing two
# keys at the same instant orders by remaining slack (EDF); the seq makes
# every key unique so heaps never compare payloads.
AdmissionKey = Tuple[int, float, int]


class Tier(enum.Enum):
    LOADING_HOST = "loading_host"
    HOST = "host"
    LOADING_DEV = "loading_dev"
    DEVICE = "device"
    DROPPED = "dropped"
    FAILED = "failed"


@dataclass
class Entry:
    """One shared (or private) datum tracked by the daemon."""

    function: str
    key: str
    size: int
    read_only: bool
    tier: Tier = Tier.LOADING_HOST
    refcount: int = 0
    host_obj: Any = None
    dev_obj: Any = None
    ready = None  # threading.Event, set when on device OR failed/cancelled
    last_used: float = 0.0
    error: Optional[BaseException] = None
    cancelled: bool = False
    # exact accounting flags: which counters this entry currently holds.
    # Rollback (failure/cancel/release) consults these instead of inferring
    # from tier, which is what used to race the loader into leaking bytes.
    host_accounted: bool = False
    dev_reserved: bool = False
    # SLO metadata for deadline-aware scheduling: tightest requester wins
    # (shared entries tighten on every attach). ``deadline_at`` is absolute,
    # on the daemon clock's timeline; None means no deadline.
    priority: int = 0
    deadline_at: Optional[float] = None
    # OOM-admission retry budget (Request.max_retries). None = retry until
    # load_timeout_s (the flat-deadline behavior); shared entries keep the
    # most generous requester's budget.
    max_retries: Optional[int] = None
    # the daemon map key this entry is registered under, so terminal
    # transitions (DROPPED/FAILED) can drop it from _entries/_fn_index
    ekey: Optional[Tuple[str, str, Optional[str]]] = None
    # bytes_loaded/loads are counted when the load COMPLETES (a failed or
    # cancelled load moved nothing the caller can use); this flag keeps a
    # host->device re-promotion from double-counting the entry.
    stats_counted: bool = False
    # fault injection (docs/resilience.md): a poisoned entry's db leg
    # fails AFTER consuming its db bandwidth (the fault costs the link
    # what a real corrupt fetch would)
    poisoned: bool = False
    # gray-failure injection: extra seconds the db leg stalls while
    # HOLDING its loader slot (Request.jitter_s — the LoaderJitter draw);
    # consumed once, so a preempted leg's continuation never re-stalls
    jitter_s: float = 0.0
    # resumable loader state machine: "db" (db->host leg, incl. host
    # admission) or "pcie" (host->device leg, incl. device admission). A
    # preempted leg re-queues _load_full, which dispatches on this phase so
    # the continuation resumes mid-chain without re-running finished legs.
    load_phase: str = "db"
    # the chunked streams driving each leg; progress (moved bytes) survives
    # pause/resume, and cancel freezes it (byte-exact link accounting)
    db_stream: Optional[TransferStream] = None
    pcie_stream: Optional[TransferStream] = None

    # how much of the streams' preemption/stall totals has already been
    # attributed to SOME record (claim-once: concurrent sharers of one
    # entry must not each report the same pause — parity with the sim
    # twin, which attributes a pause to the loading record only)
    attributed_preemptions: int = 0
    attributed_stalled_s: float = 0.0

    def __post_init__(self):
        self.ready = threading.Event()

    # transfer telemetry (per-record preemptions/stalled_s attribution)
    def transfer_preemptions(self) -> int:
        return sum(s.preemptions for s in (self.db_stream, self.pcie_stream)
                   if s is not None)

    def transfer_stalled_s(self) -> float:
        return sum(s.stalled_s for s in (self.db_stream, self.pcie_stream)
                   if s is not None)


class OutOfDeviceMemory(RuntimeError):
    pass


class DataLoadError(RuntimeError):
    """A declared datum could not be brought to device: database fault,
    device admission past the deadline, or cancellation. Raised from
    ``Handle.wait()`` (and therefore ``KernelExecutor.launch``) so callers
    fail fast instead of blocking forever on a dead loader."""

    def __init__(self, key: str, reason: str, cause: Optional[BaseException] = None):
        super().__init__(f"load of {key!r} failed: {reason}")
        self.key = key
        self.reason = reason
        self.cause = cause


class NodeLostError(DataLoadError):
    """The node serving this entry crashed (fault injection or health
    eviction, docs/resilience.md). Subclasses :class:`DataLoadError` so
    every existing typed-error path handles it; carries its own type name
    so telemetry classifies it ``node_lost`` and the gateway's eviction
    layer knows the failure is re-dispatchable."""


class _LoadCancelled(Exception):
    """Internal: the entry was released while its load was in flight."""


class Handle:
    """What the taxon shim hands the function for a memory call — resolved
    by the kernel executor right before launch (§5.2.2)."""

    def __init__(self, entry: Entry, daemon: "MemoryDaemon"):
        self.entry = entry
        self.daemon = daemon

    def is_ready(self) -> bool:
        return self.entry.ready.is_set() and self.entry.error is None

    def wait(self, timeout: Optional[float] = None) -> Any:
        if not self.entry.ready.wait(timeout):
            raise TimeoutError(f"data {self.entry.key} not ready")
        err = self.entry.error
        if err is not None:
            if isinstance(err, DataLoadError):
                raise err
            raise DataLoadError(self.entry.key, str(err), err)
        return self.entry.dev_obj

    @property
    def size(self) -> int:
        return self.entry.size


class LoaderPool:
    """Fixed-size pool of loader workers over a **priority queue**. Bounds
    db/PCIe concurrency to ``size`` and exposes the observed high-water mark
    so tests (and the virtual-time twin) can assert the bound holds.

    Jobs are popped in :data:`AdmissionKey` order — with FIFO keys this is
    exactly the old arrival-order queue; with EDF keys the queued job with
    the highest priority / tightest deadline runs next. Ordering applies to
    *queued* jobs only: a job already running on a worker is never
    preempted."""

    def __init__(self, size: int):
        self.size = max(1, int(size))
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._heap: List[Tuple[AdmissionKey, Callable[[], None]]] = []
        self._threads: List[threading.Thread] = []
        self._started = False
        self._shutdown = False
        self.in_flight = 0
        self.max_in_flight = 0

    @property
    def depth(self) -> int:
        """Queued + running jobs (the dispatch-pressure signal)."""
        with self._lock:
            return len(self._heap) + self.in_flight

    def head_key(self) -> Optional[AdmissionKey]:
        """The tightest QUEUED job's key (the link arbiter's demand signal;
        ``None`` when no job waits for a worker)."""
        with self._lock:
            return self._heap[0][0] if self._heap else None

    def submit(self, job: Callable[[], None], key: AdmissionKey) -> None:
        with self._cv:
            if not self._shutdown and not self._started:
                self._started = True
                for i in range(self.size):
                    t = threading.Thread(
                        target=self._worker, name=f"sage-loader-{i}", daemon=True
                    )
                    t.start()
                    self._threads.append(t)
            down = self._shutdown
            if not down:
                # enqueue while still holding the lock: a concurrent
                # shutdown() would otherwise wake every worker into exit
                # first and park this job forever
                heapq.heappush(self._heap, (key, job))
                self._cv.notify()
        if down:
            # pool already shut down: degrade to a synchronous load so the
            # waiter still resolves — never park a job no worker will run
            job()

    def _worker(self) -> None:
        while True:
            with self._cv:
                while not self._heap and not self._shutdown:
                    self._cv.wait()
                if not self._heap:
                    return  # shutdown and fully drained
                _, job = heapq.heappop(self._heap)
                self.in_flight += 1
                self.max_in_flight = max(self.max_in_flight, self.in_flight)
            try:
                job()
            finally:
                with self._lock:
                    self.in_flight -= 1

    def shutdown(self) -> None:
        with self._cv:
            if self._shutdown:
                return
            self._shutdown = True
            self._cv.notify_all()


class MemoryDaemon:
    """Threaded real-mode daemon (virtual-time policy twin lives in
    ``core.simulator``; both share this module's accounting semantics)."""

    def __init__(
        self,
        paths: DataPaths,
        database,
        *,
        device_capacity: int = 40 << 30,  # A100-40GB (v5e would be 16 GiB)
        host_capacity: int = 125 << 30,
        clock=None,
        loader_threads: int = 4,
        load_timeout_s: float = 30.0,
        pooled: bool = True,
        time_scale: float = 1.0,
        scheduler: str = "fifo",
        transfer: str = "run_to_completion",
        chunk_bytes: int = DEFAULT_CHUNK_BYTES,
    ):
        if scheduler not in SCHEDULERS:
            raise ValueError(f"unknown scheduler {scheduler!r}; use one of {SCHEDULERS}")
        if transfer not in TRANSFER_MODES:
            raise ValueError(
                f"unknown transfer mode {transfer!r}; use one of {TRANSFER_MODES}")
        self.paths = paths
        self.db = database
        self.clock = clock or RealClock()
        self.capacity = device_capacity
        self.host_capacity = host_capacity
        self.time_scale = time_scale
        self.loader_threads = loader_threads
        self.load_timeout_s = load_timeout_s
        self.scheduler = scheduler
        # SAGE's unified daemon bounds loading on the worker pool; baseline
        # platforms (FixedGSL/DGSF) have no such daemon — each invocation
        # streams in its own container — so the runtime constructs their
        # daemon with pooled=False (matching the simulator twin and keeping
        # the Fig-4 contention regime reproducible).
        self.pooled = pooled
        self._lock = threading.RLock()
        self._mem_free = threading.Condition(self._lock)
        self._pool = LoaderPool(loader_threads)
        # link arbiter: demand = the tightest job waiting for a loader
        # worker. Preemption only ever fires for pooled (SAGE) daemons —
        # thread-per-load baselines keep the pool queue empty, so the
        # demand signal is always None there. (docs/dataplane.md)
        self.arbiter = LinkArbiter(transfer, chunk_bytes,
                                   demand=self._pool.head_key)
        self._entries: Dict[Tuple[str, str, Optional[str]], Entry] = {}
        # per-function index over _entries, maintained on every insert —
        # function_entries/demote/drop/evictable and the dispatch residency
        # snapshot are O(that function's entries), not O(all entries)
        self._fn_index: Dict[str, Dict[Tuple[str, str, Optional[str]], Entry]] = {}
        self.device_used = 0
        self.host_used = 0
        self.context_bytes_used = 0
        self._evictable_cb: Optional[Callable[[], List["Entry"]]] = None
        self._key_seq = itertools.count()
        # device-admission waiters, (AdmissionKey, nbytes), ordered by key:
        # under OOM backpressure the head waiter is served first (tightest
        # slack under "edf", arrival order under "fifo") instead of whoever
        # wakes first; later waiters may only BACKFILL free bytes no waiter
        # ahead of them could use
        self._waiters: List[Tuple[AdmissionKey, int]] = []
        self.stats = {"shared_hits": 0, "loads": 0, "bytes_loaded": 0,
                      "host_promotions": 0, "evictions": 0,
                      "host_evictions": 0, "load_failures": 0,
                      "load_cancellations": 0, "oom_retries": 0,
                      "preemptions": 0, "node_crashes": 0}
        # fault-injection state (docs/resilience.md): ``dead`` fails every
        # new prepare/admission with a typed NodeLostError and aborts
        # in-flight loads; ``db_down`` fails db-leg loads fast. Both are
        # driven by the resilience plane (repro.core.faults) — never set
        # on the default path.
        self.dead = False
        self.dead_reason = ""
        self.db_down = False
        # MemoryLeak injection (docs/resilience.md, "Gray failures"):
        # ownerless device bytes creeping up under the injector's timer.
        # Always 0 on the default path; reclaim gives them back exactly.
        self.leaked_bytes = 0

    @property
    def max_inflight_loads(self) -> int:
        return self._pool.max_in_flight

    @property
    def transfer(self) -> str:
        return self.arbiter.mode

    def set_transfer(self, transfer: str) -> None:
        """Switch the transfer mode ("run_to_completion"|"preemptive");
        applies to chunks advanced after the call (an in-flight stream
        simply stops/starts observing yield points)."""
        self.arbiter.set_mode(transfer)

    def claim_transfer_attribution(self, handles: Dict[str, "Handle"]
                                   ) -> Tuple[int, float]:
        """(preemptions, stalled_s) of ``handles``'s entries not yet
        attributed to any record. Each pause/stall is claimed exactly
        once across concurrent sharers of an entry (whoever finishes
        first), so Telemetry totals match ``stats["preemptions"]`` and
        the sim twin's loading-record-only convention."""
        p_total, s_total = 0, 0.0
        with self._lock:
            for h in handles.values():
                e = h.entry
                p, s = e.transfer_preemptions(), e.transfer_stalled_s()
                dp = p - e.attributed_preemptions
                ds = s - e.attributed_stalled_s
                if dp > 0:
                    p_total += dp
                    e.attributed_preemptions = p
                if ds > 0:
                    s_total += ds
                    e.attributed_stalled_s = s
        return p_total, s_total

    def shutdown(self) -> None:
        self._pool.shutdown()

    # ------------------------------------------------------------------
    # fault injection: node crash / restore (docs/resilience.md)
    # ------------------------------------------------------------------
    def crash(self, reason: str = "node crashed") -> None:
        """Kill the node: every tracked entry fails with a typed
        :class:`NodeLostError` and its accounting rolls back exactly.
        In-flight loaders are *cancelled* (their next checkpoint aborts
        and rolls back their own bytes — the same no-leak path release()
        uses); terminal entries are failed in place. Contexts/slots held
        by engines are NOT touched here — ``SageRuntime.crash`` destroys
        the instances through the engine's own release paths."""
        with self._lock:
            if self.dead:
                return
            self.dead = True
            self.dead_reason = reason
            self.stats["node_crashes"] += 1
            for e in list(self._entries.values()):
                if e.tier in (Tier.LOADING_HOST, Tier.LOADING_DEV):
                    # pre-set the typed error, THEN cancel: _abort only
                    # fills error when it is None, so the loader's
                    # rollback keeps NodeLostError (not "cancelled")
                    if e.error is None:
                        e.error = NodeLostError(e.key, reason)
                    e.cancelled = True
                else:
                    self._rollback_accounting(e)
                    e.tier = Tier.FAILED
                    self._unindex_entry(e)
                    if e.error is None:
                        e.error = NodeLostError(e.key, reason)
                    e.ready.set()
            # leaked bytes have no owning entry — the teardown reclaims
            # them here (the sim twin's _teardown zeroes them the same way)
            self.device_used -= self.leaked_bytes
            self.leaked_bytes = 0
            self._mem_free.notify_all()

    def restore(self) -> None:
        """Node rejoins (cold: the crash already emptied every tier)."""
        with self._lock:
            self.dead = False
            self.dead_reason = ""

    # ------------------------------------------------------------------
    # fault injection: memory-leak creep (docs/resilience.md)
    # ------------------------------------------------------------------
    def inject_leak(self, nbytes: int) -> None:
        """One MemoryLeak tick: ``device_used`` creeps up with no owning
        entry, squeezing admission headroom (no notify — pressure only
        rises from a leak)."""
        with self._lock:
            if self.dead:
                return
            self.leaked_bytes += nbytes
            self.device_used += nbytes

    def reclaim_leak(self) -> None:
        """Leak window closed (or injector torn down): give the bytes
        back exactly and wake parked admission waiters."""
        with self._lock:
            freed, self.leaked_bytes = self.leaked_bytes, 0
            if freed:
                self.device_used -= freed
                self._mem_free.notify_all()

    # ------------------------------------------------------------------
    # per-function entry index (function_entries, exit ladder, residency)
    # ------------------------------------------------------------------
    def _index_entry(self, ekey: Tuple[str, str, Optional[str]],
                     e: Entry) -> None:
        """Insert into _entries AND the per-function index (call with the
        lock held). A re-prepare of a DROPPED/FAILED key replaces the old
        entry in both maps, so the two views never diverge."""
        e.ekey = ekey
        self._entries[ekey] = e
        self._fn_index.setdefault(ekey[0], {})[ekey] = e

    def _unindex_entry(self, e: Entry) -> None:
        """Remove a terminally DROPPED/FAILED entry from both maps (call
        with the lock held) so the per-function index stays bounded by the
        LIVE entries — dispatch calls ``residency()`` on every node per
        arrival, and dead uuid-keyed writable entries would otherwise
        accumulate one per request forever. Identity-guarded: a key
        re-prepared since never deletes its replacement. Outstanding
        ``Handle``s keep their direct reference to the dead entry."""
        k = e.ekey
        if k is None or self._entries.get(k) is not e:
            return
        del self._entries[k]
        per_fn = self._fn_index.get(k[0])
        if per_fn is not None:
            per_fn.pop(k, None)
            if not per_fn:
                del self._fn_index[k[0]]

    # ------------------------------------------------------------------
    # dispatch snapshot (docs/cluster.md): cheap residency/pressure reads
    # ------------------------------------------------------------------
    def residency(self, function: str) -> Tuple[str, int]:
        """(best tier, resident bytes) of ``function``'s read-only data:
        ``"device"`` > ``"loading"`` (an in-flight load a new invocation
        can attach to) > ``"host"`` > ``"none"``. Takes the daemon lock,
        walks only the per-function index, and never blocks on in-flight
        loads (loaders hold the lock only at accounting checkpoints)."""
        best, nbytes = 0, 0
        rank = {Tier.HOST: 1, Tier.LOADING_HOST: 2, Tier.LOADING_DEV: 2,
                Tier.DEVICE: 3}
        with self._lock:
            for e in self._fn_index.get(function, {}).values():
                r = rank.get(e.tier, 0)
                if not e.read_only or r == 0:
                    continue
                nbytes += e.size
                best = max(best, r)
        return ("none", "host", "loading", "device")[best], nbytes

    def pressure(self) -> Dict[str, int]:
        """Dispatch-pressure counters (NodeSnapshot fields minus identity/
        residency); one lock acquisition, O(1)."""
        with self._lock:
            return {
                "device_free": max(self.capacity - self.device_used, 0),
                "device_capacity": self.capacity,
                "pending_admissions": len(self._waiters),
                "loader_queue": self._pool.depth if self.pooled else 0,
                "loader_threads": self.loader_threads,
            }

    # ------------------------------------------------------------------
    # SLO-aware admission keys
    # ------------------------------------------------------------------
    def request_slo(self, request: Request) -> Tuple[int, Optional[float]]:
        """(priority, absolute deadline) of a request on this daemon's clock
        timeline (``arrival_t + deadline_s``; arrival falls back to now)."""
        if request.deadline_s is None:
            return request.priority, None
        base = request.arrival_t if request.arrival_t is not None \
            else self.clock.now()
        return request.priority, base + request.deadline_s

    def _admission_key(self, priority: int = 0,
                       deadline_at: Optional[float] = None) -> AdmissionKey:
        seq = next(self._key_seq)
        if self.scheduler == "edf":
            return (-int(priority),
                    math.inf if deadline_at is None else float(deadline_at),
                    seq)
        return (0, 0.0, seq)  # fifo: pure arrival order

    def _entry_key(self, e: Entry) -> AdmissionKey:
        return self._admission_key(e.priority, e.deadline_at)

    def _submit_load(self, job: Callable[[], None],
                     key: AdmissionKey) -> None:
        if self.pooled:
            self._pool.submit(job, key)
        else:
            threading.Thread(target=job, daemon=True).start()

    # ------------------------------------------------------------------
    # device memory accounting (contexts + data)
    # ------------------------------------------------------------------
    def _reserve_device(self, nbytes: int) -> None:
        with self._lock:
            if self.device_used + nbytes > self.capacity:
                freed = self._evict(nbytes - (self.capacity - self.device_used))
                if self.device_used + nbytes > self.capacity:
                    raise OutOfDeviceMemory(
                        f"need {nbytes}, used {self.device_used}/{self.capacity} "
                        f"(freed {freed})"
                    )
            self.device_used += nbytes

    def _release_device(self, nbytes: int) -> None:
        with self._lock:
            self.device_used -= nbytes
            self._mem_free.notify_all()

    def _reserve_device_blocking(
        self, nbytes: int, deadline: float, entry: Optional[Entry] = None,
        key: Optional[AdmissionKey] = None,
        max_retries: Optional[int] = None,
    ) -> None:
        """Admission with backpressure: on OOM, wait for releases/evictions
        (``_mem_free`` is notified by every release) and retry until the
        deadline, then re-raise :class:`OutOfDeviceMemory`. Aborts promptly
        with :class:`_LoadCancelled` if ``entry`` gets cancelled meanwhile.

        Waiters are ordered by ``key`` (:data:`AdmissionKey`): the head of
        the waiter heap is served first, so freed memory goes to the
        tightest-slack waiter under ``scheduler="edf"`` (and to strict
        arrival order under ``"fifo"``) instead of whichever thread happens
        to wake first. A non-head waiter may only **backfill**: it admits
        itself (without eviction) when the currently free bytes are of no
        use to anyone ahead of it, so a huge parked head never makes a
        small request time out while memory sits idle. No starvation
        either way: every wait is bounded by ``load_timeout_s``.

        ``deadline`` is on ``time.monotonic()`` — Condition.wait sleeps in
        wall-clock time, so the deadline must too (an injected virtual
        clock would otherwise never advance and the loop would spin
        forever).

        ``max_retries`` (or ``entry.max_retries``, re-read every attempt so
        a sharer attaching mid-wait can widen it) bounds the **failed head
        admission attempts that follow a memory event**: ``0`` fails typed
        on the first OOM (fail-fast), ``N`` allows N re-admissions after
        releases/evictions (pure poll-slice wakes don't consume the
        budget — parity with the sim twin's per-kick accounting), ``None``
        retries until the deadline (the flat ``load_timeout_s`` behavior)."""
        if key is None:
            key = (self._entry_key(entry) if entry is not None
                   else self._admission_key())
        failed_attempts = 0
        # budget accounting mirrors the sim twin exactly: the INITIAL
        # attempt counts whether or not this waiter starts at the head
        # (GPUNode.reserve charges its inline attempt before queueing), and
        # afterwards only HEAD attempts that follow a NOTIFIED wake (a
        # release/eviction — an actual memory event) consume it, the twin
        # of one charge per kick(). Pure 50 ms poll slices never burn it.
        counted_wake = True
        initial_attempt = True
        waiter = (key, nbytes)
        with self._mem_free:
            heapq.heappush(self._waiters, waiter)
            try:
                while True:
                    if self.dead:
                        raise NodeLostError(
                            entry.key if entry is not None else "device",
                            self.dead_reason or "node crashed")
                    if entry is not None and entry.cancelled:
                        raise _LoadCancelled()
                    if self._waiters[0] == waiter:  # we are the head waiter
                        try:
                            self._reserve_device(nbytes)
                            if entry is not None:
                                entry.dev_reserved = True
                            return
                        except OutOfDeviceMemory:
                            # an impossible request (bigger than the whole
                            # device) can never be admitted: fail it now
                            # instead of squatting at the head of the queue
                            # until its deadline starves everyone behind it
                            if nbytes > self.capacity:
                                raise
                            if deadline - time.monotonic() <= 0:
                                raise
                            if counted_wake:
                                failed_attempts += 1
                                # re-read the budget every attempt: a later
                                # sharer attaching to the entry may have
                                # widened it (prepare() under this lock),
                                # and a stale snapshot would fail a shared
                                # load its most generous requester allows
                                budget = (entry.max_retries
                                          if entry is not None else max_retries)
                                if budget is not None and failed_attempts > budget:
                                    # per-request retry budget exhausted:
                                    # fail typed now instead of burning the
                                    # rest of the flat deadline
                                    raise
                            # only a failed head ATTEMPT is an OOM retry;
                            # non-head waiters below are just queued behind
                            # the scheduler's ordering, not behind memory
                            self.stats["oom_retries"] += 1
                    else:
                        free = self.capacity - self.device_used
                        if nbytes <= free and all(
                                w_bytes > free
                                for w_key, w_bytes in self._waiters
                                if w_key < key):
                            # backfill (no eviction): nobody ahead can use
                            # these free bytes RIGHT NOW. Tradeoff, same as
                            # the seed's racing admission: under a steady
                            # small-request stream a big head may never see
                            # bytes accumulate — but the head keeps
                            # exclusive eviction rights, and every wait is
                            # deadline-bounded either way.
                            self._reserve_device(nbytes)
                            if entry is not None:
                                entry.dev_reserved = True
                            return
                        if deadline - time.monotonic() <= 0:
                            raise OutOfDeviceMemory(
                                f"need {nbytes}, used {self.device_used}/"
                                f"{self.capacity} (queued behind "
                                f"{len(self._waiters) - 1} waiters)"
                            )
                        if initial_attempt:
                            # the first failed opportunity charges the
                            # budget even when queued behind other waiters
                            # — a budget of 0 must fail-fast here exactly
                            # like the sim's inline reserve() attempt, not
                            # wait to reach the head of the queue
                            failed_attempts += 1
                            budget = (entry.max_retries
                                      if entry is not None else max_retries)
                            if budget is not None and failed_attempts > budget:
                                raise OutOfDeviceMemory(
                                    f"need {nbytes}, used {self.device_used}/"
                                    f"{self.capacity} (retry budget "
                                    f"{budget} exhausted behind "
                                    f"{len(self._waiters) - 1} waiters)"
                                )
                    # short slices so deadlines and cancellation are
                    # observed even if a notify is missed; wait() returns
                    # True only when notified (a memory event) — a plain
                    # timeout slice must not consume the retry budget
                    initial_attempt = False
                    remaining = deadline - time.monotonic()
                    counted_wake = self._mem_free.wait(
                        timeout=min(max(remaining, 0.001), 0.05))
            finally:
                self._waiters.remove(waiter)
                heapq.heapify(self._waiters)
                self._mem_free.notify_all()  # a new head may now proceed

    # public admission API (the engine's slot/context accounting goes
    # through these — no more reaching into _release_device)
    def reserve_slot(self, nbytes: int, *, timeout: Optional[float] = None,
                     priority: int = 0,
                     deadline_at: Optional[float] = None,
                     max_retries: Optional[int] = None) -> None:
        """Blocking slot reservation with eviction + backpressure; raises
        OutOfDeviceMemory once the deadline passes OR the per-request
        ``max_retries`` budget is exhausted (None = deadline only).
        ``priority``/``deadline_at`` order the wait under ``scheduler="edf"``."""
        t = self.load_timeout_s if timeout is None else timeout
        self._reserve_device_blocking(
            nbytes, time.monotonic() + t,
            key=self._admission_key(priority, deadline_at),
            max_retries=max_retries)

    def release_slot(self, nbytes: int) -> None:
        self._release_device(nbytes)

    def reserve_context(self, nbytes: int = GPU_CONTEXT_BYTES, *,
                        priority: int = 0,
                        deadline_at: Optional[float] = None,
                        max_retries: Optional[int] = None) -> None:
        self.reserve_slot(nbytes, priority=priority, deadline_at=deadline_at,
                          max_retries=max_retries)
        with self._lock:
            self.context_bytes_used += nbytes

    def release_context(self, nbytes: int = GPU_CONTEXT_BYTES) -> None:
        self._release_device(nbytes)
        with self._lock:
            self.context_bytes_used -= nbytes

    # ------------------------------------------------------------------
    # host-tier admission (the host ceiling is enforced, not advisory)
    # ------------------------------------------------------------------
    def _admit_host(self, nbytes: int) -> bool:
        """Account ``nbytes`` against ``host_capacity`` (call with the lock
        held). Past the ceiling, evict refcount-0 HOST-tier entries (LRU)
        first; returns False when the bytes still do not fit."""
        if self.host_used + nbytes > self.host_capacity:
            victims = sorted(
                (e for e in self._entries.values()
                 if e.tier is Tier.HOST and e.refcount == 0
                 and e.host_accounted),
                key=lambda e: e.last_used,
            )
            for v in victims:
                if self.host_used + nbytes <= self.host_capacity:
                    break
                v.tier = Tier.DROPPED
                self._unindex_entry(v)
                v.ready.clear()
                self.host_used -= v.size
                v.host_accounted = False
                v.host_obj = None
                self.stats["host_evictions"] += 1
        if self.host_used + nbytes > self.host_capacity:
            return False
        self.host_used += nbytes
        return True

    def set_evictable_provider(self, cb: Callable[[], List[Entry]]) -> None:
        """Lesson-3 cache policy: the runtime tells the daemon which cached
        (stage-1/2, refcount-0) entries may be evicted for new arrivals."""
        self._evictable_cb = cb

    def _evict(self, need: int) -> int:
        freed = 0
        if not self._evictable_cb:
            return 0
        victims = sorted(self._evictable_cb(), key=lambda e: e.last_used)
        for e in victims:
            if freed >= need:
                break
            if e.refcount == 0 and e.tier is Tier.DEVICE:
                e.tier = Tier.DROPPED
                self._unindex_entry(e)
                e.ready.clear()
                e.dev_obj = None
                if e.dev_reserved:
                    self.device_used -= e.size
                    e.dev_reserved = False
                if e.host_accounted:
                    self.host_used -= e.size
                    e.host_accounted = False
                e.host_obj = None
                freed += e.size
                self.stats["evictions"] += 1
        if freed:
            self._mem_free.notify_all()
        return freed

    # ------------------------------------------------------------------
    # prepare / attach (the proactive, parallel half)
    # ------------------------------------------------------------------
    def prepare(self, request: Request, *, system_shares_ro: bool = True) -> Dict[str, Handle]:
        """Start async loads for every declared datum; return handles now.

        Read-only data is deduplicated across invocations of the same
        function iff ``system_shares_ro`` (SAGE yes; baselines no). The
        request's SLO metadata rides on every load job: under
        ``scheduler="edf"`` the loader queue and the OOM-admission wait both
        serve the tightest-slack job first, and attaching to an in-flight
        shared entry tightens that entry's key for its *future* admission
        waits (the already-queued pool job keeps its enqueue-time key)."""
        prio, deadline_at = self.request_slo(request)
        handles: Dict[str, Handle] = {}
        if self.dead:
            # dead node: hand back already-failed typed handles so the
            # caller's wait() fails fast instead of parking on a daemon
            # that will never load (the eviction layer re-dispatches)
            for d in request.loadable():
                e = Entry(function=request.function_name, key=d.key,
                          size=d.size, read_only=False, tier=Tier.FAILED,
                          error=NodeLostError(
                              d.key, self.dead_reason or "node crashed"))
                e.ready.set()
                handles[d.key] = Handle(e, self)
            return handles
        for d in request.loadable():
            shared = d.read_only and system_shares_ro
            ekey = (request.function_name, d.key, None if shared else request.uuid)
            with self._lock:
                e = self._entries.get(ekey)
                if e is not None and e.tier not in (Tier.DROPPED, Tier.FAILED):
                    e.refcount += 1
                    e.last_used = self.clock.now()
                    e.priority = max(e.priority, prio)
                    if deadline_at is not None:
                        e.deadline_at = (deadline_at if e.deadline_at is None
                                         else min(e.deadline_at, deadline_at))
                    if e.max_retries is not None:
                        # most generous requester wins: a budget-less
                        # attacher must not fail a shared load early
                        e.max_retries = (
                            None if request.max_retries is None
                            else max(e.max_retries, request.max_retries))
                    self.stats["shared_hits"] += 1
                    handles[d.key] = Handle(e, self)
                    if e.tier is Tier.HOST:
                        # promote host -> device (PCIe only; no db re-read):
                        # stage-2 warm hit of the exit ladder. The chain
                        # restarts at the "pcie" phase with a FRESH stream —
                        # the previous promotion's stream already ran to
                        # done and must not satisfy this leg for free.
                        # Dropping it also retires its share of the
                        # attributed counters, or the fresh stream's
                        # pauses would hide behind the stale claim level.
                        e.tier = Tier.LOADING_DEV
                        e.load_phase = "pcie"
                        if e.pcie_stream is not None:
                            e.attributed_preemptions = max(
                                e.attributed_preemptions
                                - e.pcie_stream.preemptions, 0)
                            e.attributed_stalled_s = max(
                                e.attributed_stalled_s
                                - e.pcie_stream.stalled_s, 0.0)
                        e.pcie_stream = None
                        self.stats["host_promotions"] += 1
                        self._submit_load(lambda e=e: self._load_full(e),
                                          self._entry_key(e))
                    continue
                e = Entry(
                    function=request.function_name, key=d.key, size=d.size,
                    read_only=shared, refcount=1,
                    priority=prio, deadline_at=deadline_at,
                    max_retries=request.max_retries,
                    poisoned=request.fault_injected,
                    jitter_s=request.jitter_s,
                )
                e.last_used = self.clock.now()
                self._index_entry(ekey, e)
                handles[d.key] = Handle(e, self)
            self._submit_load(lambda e=e: self._load_full(e),
                              self._entry_key(e))
        return handles

    # ------------------------------------------------------------------
    # loader jobs (run on the bounded pool; never raise)
    # ------------------------------------------------------------------
    def _fail(self, e: Entry, reason: str, cause: Optional[BaseException]) -> None:
        with self._lock:
            self._rollback_accounting(e)
            e.tier = Tier.FAILED
            self._unindex_entry(e)
            if e.error is None:
                e.error = (cause if isinstance(cause, DataLoadError)
                           else DataLoadError(e.key, reason, cause))
            self.stats["load_failures"] += 1
            e.ready.set()
            self._mem_free.notify_all()

    def _abort(self, e: Entry) -> None:
        with self._lock:
            self._rollback_accounting(e)
            e.tier = Tier.DROPPED
            self._unindex_entry(e)
            if e.error is None:
                e.error = DataLoadError(e.key, "cancelled: released while loading")
            self.stats["load_cancellations"] += 1
            e.ready.set()
            self._mem_free.notify_all()

    def _rollback_accounting(self, e: Entry) -> None:
        if e.dev_reserved:
            self.device_used -= e.size
            e.dev_reserved = False
        if e.host_accounted:
            self.host_used -= e.size
            e.host_accounted = False
        e.host_obj = e.dev_obj = None
        # freeze the legs' byte accounting: a cancelled/failed stream
        # charges the link only for the chunks it actually moved
        for st in (e.db_stream, e.pcie_stream):
            if st is not None:
                st.cancel()

    def _entry_prefix(self, e: Entry) -> Tuple[int, float]:
        """The entry's urgency prefix under the ACTIVE scheduler — built
        the same way the pool's queued keys are, so the arbiter compares
        like with like. Under "fifo" every prefix is (0, 0.0): nothing is
        ever strictly tighter and preemption never fires."""
        if self.scheduler == "edf":
            return (-int(e.priority),
                    math.inf if e.deadline_at is None else float(e.deadline_at))
        return (0, 0.0)

    def _drive_stream(self, e: Entry, attr: str, broker) -> bool:
        """Advance the leg's stream to completion in arbiter-sized chunks.

        Returns ``True`` when the leg finished; ``False`` when the stream
        **yielded** — a strictly tighter queued load preempted it, the
        stream paused (completed bytes kept), and the continuation was
        re-submitted to the pool under this entry's current key, freeing
        the worker for the tighter job. Raises :class:`_LoadCancelled`
        promptly when the entry is released mid-transfer."""
        st = getattr(e, attr)
        if st is None:
            st = broker.open_stream(e.size, scale=self.time_scale)
            setattr(e, attr, st)
        if st.paused_at is not None:  # continuation of a preempted leg
            st.resume(self.clock.now())
        # chunk only where a yield is possible: an unpooled (baseline)
        # daemon has no loader queue, so its demand signal is always None
        # and chunking would be ~250 pointless fair-share transactions
        # per 8 GB load
        while True:
            if e.cancelled:
                raise _LoadCancelled()
            # re-read per chunk: a degradation window opening (or closing)
            # mid-stream re-paces the remaining chunks so the preemption
            # latency bound holds on the slowed link
            chunk = self.arbiter.chunk_hint(st.broker) if self.pooled else None
            st.advance(chunk)
            if st.done:
                return True
            if e.cancelled:
                raise _LoadCancelled()
            if self.arbiter.should_yield(self._entry_prefix(e)):
                st.pause(self.clock.now())
                # stats (not arbiter.preemptions) is the threaded driver's
                # authoritative counter: it increments under the daemon
                # lock, while the arbiter's is for the single-threaded sim
                with self._lock:
                    self.stats["preemptions"] += 1
                self._submit_load(lambda e=e: self._load_full(e),
                                  self._entry_key(e))
                return False

    def _load_full(self, e: Entry) -> None:
        """Resumable db->host->device chain: dispatches on ``e.load_phase``
        so a preempted leg's continuation (or a host->device promotion,
        which starts at phase "pcie") resumes exactly where it left off."""
        if e.load_phase == "db":
            if e.jitter_s > 0.0:
                # injected loader jitter (docs/resilience.md, "Gray
                # failures"): stall the db leg while HOLDING the loader
                # slot — the pathology is the wedged worker, same as the
                # sim twin's jitter delay. The db_down check runs after
                # the stall elapses, mirroring the sim's event order.
                j, e.jitter_s = e.jitter_s, 0.0
                self.clock.sleep(j * self.time_scale)
                with self._lock:
                    if e.cancelled:
                        self._abort(e)
                        return
            if self.db_down:
                # flapping db (fault injection): fail the leg fast and
                # typed — no bandwidth was moved, so nothing to roll back
                # beyond the standard accounting path
                self._fail(e, "db link down", None)
                return
            # database -> host (db path contention): the transfer is a
            # chunked stream over the db broker; the payload lookup itself
            # is un-brokered (its timing is the stream)
            try:
                if not self._drive_stream(e, "db_stream", self.paths.db):
                    return  # yielded; continuation re-queued
                payload = self.db.fetch(e.key, None)
            except _LoadCancelled:
                self._abort(e)
                return
            except Exception as exc:  # noqa: BLE001 — propagated via the entry
                self._fail(e, "database fetch failed", exc)
                return
            if e.poisoned:
                # injected loader fault: the db leg ran to completion (the
                # corrupt fetch cost the link its full bandwidth share)
                # and THEN fails — parity with the sim twin's poison point
                self._fail(e, "injected loader fault", None)
                return
            with self._lock:
                if e.cancelled:
                    self._abort(e)
                    return
                # host admission: the host ceiling is enforced — evict
                # refcount-0 HOST entries, then fail typed (the seed
                # incremented host_used unconditionally and overcommitted
                # the host tier without bound)
                if not self._admit_host(e.size):
                    self._fail(
                        e,
                        f"host admission failed: need {e.size}, used "
                        f"{self.host_used}/{self.host_capacity}",
                        None,
                    )
                    return
                e.host_obj = payload
                e.host_accounted = True
                # stay in a LOADING tier for the PCIe/admission leg: a tier
                # of HOST here would let release() take the rollback path
                # (instead of cancelling) while this loader still runs — it
                # would then reserve device bytes for a DROPPED entry and
                # leak them — and would let a concurrent shared hit
                # schedule a second PCIe leg
                e.tier = Tier.LOADING_DEV
                e.load_phase = "pcie"
        self._load_dev(e)

    def _load_dev(self, e: Entry) -> None:
        # host -> device (PCIe path contention), then admission with
        # backpressure: an OutOfDeviceMemory here used to kill the thread
        # and hang every waiter; now it retries until load_timeout_s and
        # then fails the entry with a typed error.
        try:
            if not self._drive_stream(e, "pcie_stream", self.paths.pcie):
                return  # yielded; continuation re-queued
            if e.cancelled:
                raise _LoadCancelled()
            self._reserve_device_blocking(
                e.size, time.monotonic() + self.load_timeout_s, entry=e
            )
            dev = self.db.to_device(e.host_obj)
        except _LoadCancelled:
            self._abort(e)
            return
        except Exception as exc:  # noqa: BLE001 — propagated via the entry
            self._fail(e, "device admission/materialization failed", exc)
            return
        with self._lock:
            if e.cancelled:
                self._abort(e)
                return
            e.dev_obj = dev
            e.tier = Tier.DEVICE
            # bytes moved are accounted on COMPLETION: a failed or
            # cancelled load rolls through _fail/_abort and never lands
            # here, so stats["loads"]/["bytes_loaded"] no longer overstate
            # the data actually delivered. The flag keeps a host->device
            # re-promotion from double-counting the entry.
            if not e.stats_counted:
                e.stats_counted = True
                self.stats["loads"] += 1
                self.stats["bytes_loaded"] += e.size
            e.ready.set()

    # ------------------------------------------------------------------
    # explicit allocation (cudaMalloc-style via the shim)
    # ------------------------------------------------------------------
    def alloc(self, request: Request, key: str, nbytes: int) -> Handle:
        """Shim ``cudaMalloc``: blocking admission with the same
        backpressure/deadline as every other reservation (it used to call
        the non-blocking path and raise on any transient pressure); raises
        :class:`OutOfDeviceMemory` only once ``load_timeout_s`` passes."""
        prio, deadline_at = self.request_slo(request)
        self._reserve_device_blocking(
            nbytes, time.monotonic() + self.load_timeout_s,
            key=self._admission_key(prio, deadline_at),
            max_retries=request.max_retries)
        e = Entry(function=request.function_name, key=key, size=nbytes,
                  read_only=False, tier=Tier.DEVICE, refcount=1,
                  priority=prio, deadline_at=deadline_at,
                  max_retries=request.max_retries)
        e.dev_reserved = True
        e.last_used = self.clock.now()
        e.ready.set()
        with self._lock:
            self._index_entry((request.function_name, key, request.uuid), e)
        return Handle(e, self)

    # ------------------------------------------------------------------
    # release / exit-ladder actions
    # ------------------------------------------------------------------
    def release(self, request: Request, handles: Dict[str, Handle]) -> None:
        """Invocation finished: writable data freed; read-only refcount--
        (entries stay cached on device for the exit ladder to manage).

        A writable entry still in a LOADING tier is *cancelled* instead of
        freed here — its loader owns the accounting and rolls it back at the
        next checkpoint, so the release/loader race cannot leak bytes."""
        with self._lock:
            for h in handles.values():
                e = h.entry
                e.refcount -= 1
                e.last_used = self.clock.now()
                if not e.read_only and e.refcount <= 0:
                    if e.tier in (Tier.LOADING_HOST, Tier.LOADING_DEV):
                        e.cancelled = True
                        continue
                    self._rollback_accounting(e)
                    if e.tier is not Tier.FAILED:
                        e.tier = Tier.DROPPED
                    self._unindex_entry(e)
            self._mem_free.notify_all()

    def function_entries(self, function: str) -> List[Entry]:
        """The LIVE entries tracked for ``function`` (terminal
        DROPPED/FAILED entries are unindexed at their transition) — O(that
        function's live entries) via the per-function index, not a scan of
        every entry on the daemon."""
        with self._lock:
            return list(self._fn_index.get(function, {}).values())

    def demote_to_host(self, function: str) -> int:
        """Exit stage 2: cached read-only device copies -> host RAM."""
        n = 0
        with self._lock:
            for e in self.function_entries(function):
                if e.read_only and e.refcount == 0 and e.tier is Tier.DEVICE:
                    e.tier = Tier.HOST
                    e.dev_obj = None
                    e.ready.clear()
                    if e.dev_reserved:
                        self.device_used -= e.size
                        e.dev_reserved = False
                    n += e.size
            if n:
                self._mem_free.notify_all()
        return n

    def drop_host(self, function: str) -> int:
        """Exit stage 4: host copies dropped."""
        n = 0
        with self._lock:
            for e in self.function_entries(function):
                if e.read_only and e.refcount == 0 and e.tier in (Tier.HOST, Tier.DEVICE):
                    self._rollback_accounting(e)
                    e.tier = Tier.DROPPED
                    self._unindex_entry(e)
                    e.ready.clear()
                    n += e.size
            if n:
                self._mem_free.notify_all()
        return n

    def evictable_entries(self, function: str) -> List[Entry]:
        return [
            e for e in self.function_entries(function)
            if e.read_only and e.refcount == 0 and e.tier is Tier.DEVICE
        ]
