"""Preemptible chunked transfer engine (docs/dataplane.md, "Transfer
scheduling").

The db and PCIe paths used to move every load as one atomic run-to-
completion ``BandwidthBroker.transfer()``: once a loose 8 GB load owned the
link, a tight-deadline 50 MB load queued behind it, and the EDF scheduler
could only reorder *queued* work. FaaSTube (arXiv:2411.01830) shows that
reassigning the bandwidth of an **in-flight** transfer is the dominant
lever for GPU-serverless tail latency; HAS-GPU (arXiv:2505.01968) argues
the arbitration should stay SLO-class-aware.

This module is the shared policy core both drivers run:

* :class:`TransferStream` — one transfer, split into chunks, that can be
  paused between chunks and resumed later **without losing completed
  bytes**. The wall-clock driver calls :meth:`TransferStream.advance`
  (blocking); the virtual-time driver calls
  :meth:`TransferStream.sim_advance` (callback). Cancelling a stream
  freezes its byte accounting: only bytes actually moved are charged to
  the link.
* :class:`LinkArbiter` — the preemption decision. It watches the *demand*
  for the link (the tightest :data:`~repro.core.daemon.AdmissionKey`
  waiting on the loader queue) and tells an in-flight stream to yield when
  a **strictly tighter** ``(priority, deadline)`` class is waiting. Under
  ``transfer="run_to_completion"`` (the default) it never yields and
  chunking collapses to a single full-size advance — bit-identical to the
  pre-stream behavior.

Preemption compares only the urgency *prefix* of an AdmissionKey —
``(-priority, absolute deadline)`` — never the arrival sequence number:
equal-urgency work must not preempt itself, or two same-class streams
would thrash the link trading chunks. Under ``scheduler="fifo"`` every key
carries the degenerate prefix ``(0, 0.0)``, so nothing is ever strictly
tighter and ``"preemptive"`` is a no-op: preemptive transfer is an EDF
feature, exactly as in the papers above.
"""
from __future__ import annotations

from typing import Callable, Optional, Tuple

TRANSFER_MODES = ("run_to_completion", "preemptive")

# Preemption latency is bounded by one chunk. 32 MiB is ~6 ms on the
# paper's 5.05 GB/s effective PCIe link and ~20 ms on the 1.63 GB/s db
# path — far below the context-creation floor (285 ms) — while keeping an
# 8 GB transfer at only ~250 scheduling points.
DEFAULT_CHUNK_BYTES = 32 << 20
# floor for degradation-scaled chunks: below ~1 MiB the per-chunk
# bookkeeping dominates the modeled transfer itself
MIN_CHUNK_BYTES = 1 << 20


def key_prefix(key) -> Optional[Tuple]:
    """Urgency prefix of an AdmissionKey: ``(-priority, deadline)``. The
    arrival seq is dropped so equal-urgency work can never preempt itself."""
    if key is None:
        return None
    return tuple(key[:2])


class TransferStream:
    """One chunked, preemptible transfer over a
    :class:`~repro.core.datapath.BandwidthBroker` link.

    Progress (``moved``) survives pause/resume cycles; ``cancel()``
    freezes it, so a cancelled stream charges the link only for the bytes
    it actually moved (byte-exact accounting on the release() path).
    ``stalled_s`` accumulates the wall (or virtual) time spent paused and
    ``preemptions`` counts the pauses — both roll up into per-record
    telemetry.
    """

    __slots__ = ("broker", "total", "moved", "scale", "cancelled",
                 "paused_at", "stalled_s", "preemptions")

    def __init__(self, broker, nbytes: float, *, scale: float = 1.0):
        self.broker = broker
        self.total = max(float(nbytes), 0.0)
        self.scale = scale
        self.moved = 0.0
        self.cancelled = False
        self.paused_at: Optional[float] = None  # clock stamp while paused
        self.stalled_s = 0.0
        self.preemptions = 0

    # ------------------------------------------------------------------
    @property
    def remaining(self) -> float:
        return max(self.total - self.moved, 0.0)

    @property
    def done(self) -> bool:
        return not self.cancelled and self.remaining <= 0.0

    def _next_chunk(self, chunk: Optional[float]) -> float:
        if chunk is None:
            return self.remaining
        return min(float(chunk), self.remaining)

    # ------------------------------------------------------------------
    # wall-clock mode (threaded daemon)
    # ------------------------------------------------------------------
    def advance(self, chunk: Optional[float] = None) -> float:
        """Move the next ``chunk`` bytes (all remaining when ``None``) under
        the link's fair sharing; blocks for the modeled duration and
        returns it. A no-op on a done or cancelled stream."""
        amt = self._next_chunk(chunk)
        if amt <= 0.0 or self.cancelled:
            return 0.0
        dt = self.broker.transfer(amt, scale=self.scale)
        self.moved += amt
        return dt

    # ------------------------------------------------------------------
    # virtual-time mode (simulator)
    # ------------------------------------------------------------------
    def sim_advance(self, chunk: Optional[float],
                    done: Callable[[], None]) -> None:
        """Virtual-time advance; ``done`` fires when the chunk completes.
        With ``chunk=None`` this is exactly one full-size ``sim_transfer``
        — the same event sequence the pre-stream code scheduled."""
        amt = self._next_chunk(chunk)
        if amt <= 0.0 or self.cancelled:
            done()
            return

        def fin():
            self.moved += amt
            done()

        self.broker.sim_transfer(amt, fin)

    # ------------------------------------------------------------------
    # preemption lifecycle
    # ------------------------------------------------------------------
    def pause(self, now: float) -> None:
        """Yield the link between chunks (completed bytes are kept)."""
        if self.paused_at is None:
            self.paused_at = now
            self.preemptions += 1

    def resume(self, now: float) -> None:
        """Re-take the link; the paused span lands in ``stalled_s``."""
        if self.paused_at is not None:
            self.stalled_s += max(now - self.paused_at, 0.0)
            self.paused_at = None

    def cancel(self) -> None:
        """Abort the stream: ``moved`` is frozen and further advances are
        no-ops. The link keeps only the bytes already transferred."""
        self.cancelled = True


class LinkArbiter:
    """Preemption policy for one node's transfer links.

    ``demand`` is a zero-argument callable returning the tightest
    AdmissionKey currently *waiting* for a loader slot (the loader-pool /
    loader-gate queue head), or ``None`` when nothing queues. The arbiter
    itself holds no queue — both drivers already keep one — it only
    answers, between chunks, "must this stream yield now?".
    """

    def __init__(self, mode: str = "run_to_completion",
                 chunk_bytes: int = DEFAULT_CHUNK_BYTES,
                 demand: Optional[Callable[[], Optional[Tuple]]] = None):
        if mode not in TRANSFER_MODES:
            raise ValueError(
                f"unknown transfer mode {mode!r}; use one of {TRANSFER_MODES}")
        self.mode = mode
        self.chunk_bytes = int(chunk_bytes)
        self._demand = demand
        self.preemptions = 0  # link-wide pause count (benchmark headline)

    # ------------------------------------------------------------------
    @property
    def preemptive(self) -> bool:
        return self.mode == "preemptive"

    def set_mode(self, mode: str) -> None:
        if mode not in TRANSFER_MODES:
            raise ValueError(
                f"unknown transfer mode {mode!r}; use one of {TRANSFER_MODES}")
        self.mode = mode

    def bind_demand(self, fn: Callable[[], Optional[Tuple]]) -> None:
        self._demand = fn

    # ------------------------------------------------------------------
    def chunk_hint(self, link=None) -> Optional[int]:
        """Per-advance chunk size: ``None`` (one full-size advance — the
        pre-stream behavior) unless preemption needs chunk boundaries.

        With ``link`` (a :class:`~repro.core.datapath.BandwidthBroker`),
        the chunk is scaled by the link's current degradation factor so
        the per-chunk transfer TIME — the preemption latency bound — stays
        roughly constant when a fault window slows the link. Drivers call
        this per advance, so an in-flight stream adapts its pacing
        mid-stream as degradation windows open and close."""
        if not self.preemptive:
            return None
        deg = 1.0 if link is None else getattr(link, "degradation", 1.0)
        if deg >= 1.0:
            return self.chunk_bytes
        return max(MIN_CHUNK_BYTES, int(self.chunk_bytes * deg))

    def should_yield(self, key) -> bool:
        """True when a strictly tighter ``(priority, deadline)`` class is
        waiting for the link than the in-flight stream's ``key``."""
        if not self.preemptive or self._demand is None:
            return False
        head = key_prefix(self._demand())
        mine = key_prefix(key)
        return head is not None and mine is not None and head < mine

    def note_preemption(self) -> None:
        self.preemptions += 1
