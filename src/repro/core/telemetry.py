"""Per-invocation stage telemetry (Fig 2 / Fig 15 / Table 4 breakdowns)."""
from __future__ import annotations

import threading
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

# canonical stage order (paper Fig 2)
STAGES = (
    "container_create",
    "cpu_ctx",
    "cpu_data",
    "gpu_ctx",
    "gpu_data",
    "compute",
    "return_result",
)
SETUP_STAGES = STAGES[:5]

# canonical failure taxonomy (docs/resilience.md): every failed record
# carries one of these in ``error_class`` so reports and the chaos
# benchmark never re-parse ``error`` message strings.
ERROR_CLASSES = ("data_load", "timeout", "shed", "breaker", "node_lost",
                 "hedged", "other")

# ``error`` strings are "Type: message"; map the type prefix to a class.
# NodeLostError subclasses DataLoadError, so it is matched first.
# "hedged" marks a cancelled hedge loser — such records are always
# ``dropped`` (the winning twin is the request's one outcome), so the
# class never shows up in error_counts()/slo_by_priority().
_ERROR_PREFIXES = (
    ("NodeLostError", "node_lost"),
    ("ShedError", "shed"),
    ("BreakerOpenError", "breaker"),
    ("HedgedError", "hedged"),
    ("DataLoadError", "data_load"),
    ("TimeoutError", "timeout"),
)


def classify_error(error: Optional[str]) -> Optional[str]:
    """Error class for an ``InvocationRecord.error`` string (None for
    records that did not fail). Fallback for records produced before the
    writer stamped ``error_class`` directly."""
    if error is None:
        return None
    for prefix, cls in _ERROR_PREFIXES:
        if error.startswith(prefix):
            return cls
    return "other"


@dataclass
class InvocationRecord:
    request_id: str
    function: str
    system: str
    arrival_t: float = 0.0
    start_t: float = 0.0
    end_t: float = 0.0
    warm_stage: Optional[int] = None  # exit-policy stage reused (None = cold)
    stages: Dict[str, float] = field(default_factory=dict)  # stage -> seconds
    dropped: bool = False
    error: Optional[str] = None  # "Type: message" when the invocation failed
    deadline_s: Optional[float] = None  # per-request SLO (recorded, not enforced)
    priority: int = 0
    max_retries: Optional[int] = None  # OOM-admission retry budget (None = flat deadline)
    node_id: str = ""        # node that served the invocation ("gpu0", ...)
    # residency tier of the function on the chosen node AT DISPATCH time
    # ("device"|"loading"|"host"|"none"); None = not cluster-dispatched
    dispatch_tier: Optional[str] = None
    # transfer-scheduling attribution (docs/dataplane.md): how many times
    # this invocation's transfer streams were paused to yield the link,
    # and the total seconds they sat paused. Attributed to the invocation
    # whose window the pause happened in (the loading record in the sim;
    # the delta over the invocation's in-flight span in the runtime).
    preemptions: int = 0
    stalled_s: float = 0.0
    setup_wall: float = 0.0  # wall time of the (possibly parallel) setup span
    result: Any = None       # handler return value (real runtime only)
    # resilience attribution (docs/resilience.md): failure taxonomy class
    # (one of ERROR_CLASSES when error is set) and how many times the
    # request was re-dispatched after losing its node
    error_class: Optional[str] = None
    redispatches: int = 0
    # compute-plane attribution (docs/compute.md): how many same-function
    # invocations shared this record's stacked kernel launch (1 = solo),
    # and the request_ids it was batched with. Each member still gets its
    # own record; the compute stage holds the amortized shared span.
    batch_size: int = 1
    batched_with: tuple = ()

    @property
    def e2e(self) -> float:
        return self.end_t - self.arrival_t

    @property
    def slo_miss(self) -> bool:
        return self.deadline_s is not None and self.e2e > self.deadline_s

    @property
    def duration(self) -> float:
        return self.end_t - self.start_t

    @property
    def setup_time(self) -> float:
        return sum(self.stages.get(s, 0.0) for s in SETUP_STAGES)

    @property
    def queueing(self) -> float:
        return max(self.start_t - self.arrival_t, 0.0)


class Telemetry:
    def __init__(self):
        self._lock = threading.Lock()
        self.records: List[InvocationRecord] = []
        self._by_id: Dict[str, InvocationRecord] = {}
        # sorted-view cache for the pXX quantile family: (attr, function)
        # -> (version, sorted values). ``add`` bumps the version, so every
        # append invalidates; repeated quantile calls between appends reuse
        # the sorted list instead of re-sorting the whole record set.
        # (Records are final by the time they are added — both drivers set
        # end_t/stages before calling add() — so a cached view never goes
        # stale without the version changing.)
        self._version = 0
        self._sorted_cache: Dict[tuple, tuple] = {}

    def add(self, rec: InvocationRecord) -> None:
        with self._lock:
            self.records.append(rec)
            # one logical outcome per request id: a superseded (dropped)
            # attempt never shadows the request's real record — a hedge
            # loser's cancellation can land AFTER its winner on both
            # drivers, so last-add-wins would point find() at the corpse
            cur = self._by_id.get(rec.request_id)
            if cur is None or not rec.dropped:
                self._by_id[rec.request_id] = rec
            self._version += 1

    def find(self, request_id: str) -> Optional[InvocationRecord]:
        """O(1) lookup by request id (records added via ``add``)."""
        with self._lock:
            return self._by_id.get(request_id)

    def snapshot(self) -> List[InvocationRecord]:
        """Consistent copy of the record list (public: the cluster merge
        and gateway report paths consume it). Every read path goes through
        this: runtime pool threads ``add()`` concurrently with readers, and
        iterating ``self.records`` unlocked races the append (a list can be
        observed mid-resize)."""
        with self._lock:
            return list(self.records)

    # ------------------------------------------------------------------
    def by_function(self) -> Dict[str, List[InvocationRecord]]:
        out = defaultdict(list)
        for r in self.snapshot():
            if not r.dropped:
                out[r.function].append(r)
        return dict(out)

    def mean_stage_breakdown(self, function: Optional[str] = None) -> Dict[str, float]:
        recs = [
            r for r in self.snapshot()
            if not r.dropped and (function is None or r.function == function)
        ]
        if not recs:
            return {s: 0.0 for s in STAGES}
        return {
            s: sum(r.stages.get(s, 0.0) for r in recs) / len(recs) for s in STAGES
        }

    def mean_e2e(self, function: Optional[str] = None) -> float:
        recs = [
            r for r in self.snapshot()
            if not r.dropped and (function is None or r.function == function)
        ]
        return sum(r.e2e for r in recs) / len(recs) if recs else 0.0

    def _quantile(self, q: float, key, function: Optional[str] = None) -> float:
        """Sorted-index quantile of ``key(record)`` over non-dropped
        records (one implementation for every pXX view). Arbitrary ``key``
        callables cannot be cached; the pXX family below routes through
        the attribute-cached :meth:`_quantile_attr` instead."""
        vals = sorted(
            key(r) for r in self.snapshot()
            if not r.dropped and (function is None or r.function == function)
        )
        if not vals:
            return 0.0
        return vals[min(int(q * len(vals)), len(vals) - 1)]

    def _sorted_vals(self, attr: str, function: Optional[str]) -> list:
        """Sorted ``getattr(record, attr)`` view, cached until the next
        ``add``. The version is read BEFORE the snapshot: a concurrent add
        can only make the stored entry look stale (recomputed next call),
        never let stale data be served as fresh."""
        cache_key = (attr, function)
        cached = self._sorted_cache.get(cache_key)
        if cached is not None and cached[0] == self._version:
            return cached[1]
        version = self._version
        vals = sorted(
            getattr(r, attr) for r in self.snapshot()
            if not r.dropped and (function is None or r.function == function)
        )
        self._sorted_cache[cache_key] = (version, vals)
        return vals

    def _quantile_attr(self, q: float, attr: str,
                       function: Optional[str] = None) -> float:
        vals = self._sorted_vals(attr, function)
        if not vals:
            return 0.0
        return vals[min(int(q * len(vals)), len(vals) - 1)]

    def p50_duration(self, function: Optional[str] = None) -> float:
        """Median start->end duration (the dispatch benchmark's headline:
        warm routing removes setup stages from the middle of the
        distribution, not just the tail)."""
        return self._quantile_attr(0.5, "duration", function)

    def p95_duration(self, function: Optional[str] = None) -> float:
        """95th-percentile start->end duration (tail view: preemptive
        transfer is a tail-latency feature, docs/dataplane.md)."""
        return self._quantile_attr(0.95, "duration", function)

    def p99_duration(self, function: Optional[str] = None) -> float:
        """99th-percentile start->end duration — the headline the
        preemption benchmark compares per deadline class."""
        return self._quantile_attr(0.99, "duration", function)

    def transfer_wait(self, function: Optional[str] = None) -> float:
        """Total seconds invocation transfer streams spent paused on a
        yielded link (sum of ``stalled_s`` over records; 0.0 under
        ``transfer="run_to_completion"``)."""
        return sum(
            r.stalled_s for r in self.snapshot()
            if not r.dropped and (function is None or r.function == function)
        )

    def preemption_count(self, function: Optional[str] = None) -> int:
        """Total stream pauses attributed to records (see
        ``InvocationRecord.preemptions``)."""
        return sum(
            r.preemptions for r in self.snapshot()
            if not r.dropped and (function is None or r.function == function)
        )

    def p99_e2e(self, function: Optional[str] = None) -> float:
        return self._quantile_attr(0.99, "e2e", function)

    def throughput(self, t_window: float) -> float:
        done = [r for r in self.snapshot() if not r.dropped]
        return len(done) / t_window if t_window > 0 else 0.0

    def warm_fraction(self) -> float:
        recs = [r for r in self.snapshot() if not r.dropped]
        if not recs:
            return 0.0
        return sum(1 for r in recs if r.warm_stage is not None) / len(recs)

    def errors(self) -> List[InvocationRecord]:
        """Invocations that failed (data-plane or handler faults)."""
        return [r for r in self.snapshot() if r.error is not None]

    def error_count(self) -> int:
        return len(self.errors())

    def error_counts(self) -> Dict[str, int]:
        """Failed records tallied by error class (``ERROR_CLASSES``):
        ``data_load``, ``timeout``, ``shed``, ``breaker``, ``node_lost``,
        ``other``. Reads the stamped ``error_class`` and falls back to
        parsing the ``error`` type prefix — callers never re-parse
        message strings (docs/resilience.md)."""
        out: Dict[str, int] = {}
        for r in self.snapshot():
            if r.dropped or r.error is None:
                continue
            cls = r.error_class or classify_error(r.error) or "other"
            out[cls] = out.get(cls, 0) + 1
        return out

    @staticmethod
    def _is_miss(r: InvocationRecord) -> bool:
        return r.error is not None or r.slo_miss

    def slo_misses(self) -> List[InvocationRecord]:
        """Records that violated their deadline: completed too late, or
        failed outright (a failed request never met its SLO)."""
        return [r for r in self.snapshot()
                if not r.dropped and r.deadline_s is not None
                and self._is_miss(r)]

    def slo_miss_rate(self) -> float:
        """Misses over records that carried a deadline (0.0 if none did —
        deadlines are opt-in request metadata). Computed from ONE snapshot
        so a concurrent ``add()`` cannot skew numerator vs denominator."""
        with_slo = [r for r in self.snapshot()
                    if not r.dropped and r.deadline_s is not None]
        if not with_slo:
            return 0.0
        return sum(1 for r in with_slo if self._is_miss(r)) / len(with_slo)

    # ------------------------------------------------------------------
    # per-node attribution (cluster dispatch, docs/cluster.md)
    # ------------------------------------------------------------------
    def by_node(self) -> Dict[str, List[InvocationRecord]]:
        """Records grouped by the node that served them."""
        out = defaultdict(list)
        for r in self.snapshot():
            if not r.dropped:
                out[r.node_id].append(r)
        return dict(out)

    def node_counts(self) -> Dict[str, int]:
        """Invocations per node — the dispatch-skew view the runtime/sim
        parity test compares."""
        return {n: len(rs) for n, rs in self.by_node().items()}

    def dispatch_hit_rate(self) -> float:
        """Fraction of cluster-dispatched records routed to a node where
        the function was already resident (device/loading/host) at
        dispatch time. Records with ``dispatch_tier is None`` (single-node
        drivers) are excluded; 0.0 when nothing was cluster-dispatched."""
        routed = [r for r in self.snapshot()
                  if not r.dropped and r.dispatch_tier is not None]
        if not routed:
            return 0.0
        return sum(1 for r in routed if r.dispatch_tier != "none") / len(routed)

    def dispatch_by_node(self) -> Dict[str, Dict[str, float]]:
        """Per-node dispatch breakdown: ``{node_id: {requests, hits,
        hit_rate}}`` over cluster-dispatched records."""
        out: Dict[str, Dict[str, float]] = {}
        for r in self.snapshot():
            if r.dropped or r.dispatch_tier is None:
                continue
            c = out.setdefault(r.node_id, {"requests": 0, "hits": 0})
            c["requests"] += 1
            if r.dispatch_tier != "none":
                c["hits"] += 1
        for c in out.values():
            c["hit_rate"] = c["hits"] / c["requests"]
        return out

    def slo_by_priority(self) -> Dict[int, Dict[str, float]]:
        """Per-priority-class SLO attainment over deadline-carrying records:
        ``{priority: {requests, misses, miss_rate, attainment}}``. This is
        the report the EDF-vs-FIFO scheduling benchmark compares class by
        class (docs/api.md)."""
        classes: Dict[int, Dict[str, float]] = {}
        for r in self.snapshot():
            if r.dropped or r.deadline_s is None:
                continue
            c = classes.setdefault(r.priority, {"requests": 0, "misses": 0})
            c["requests"] += 1
            if self._is_miss(r):
                c["misses"] += 1
        for c in classes.values():
            c["miss_rate"] = c["misses"] / c["requests"]
            c["attainment"] = 1.0 - c["miss_rate"]
        return classes
