"""Back-compat shim: dispatch scoring moved to ``repro.core.placement``.

The per-request scoring (``NodeSnapshot``/``choose_node``/
``locality_score``) now lives in :mod:`repro.core.placement.scoring`,
next to the planner/autoscaler control plane that builds on it
(docs/planner.md). Import from ``repro.core.placement``; this module
stays so existing imports keep working.
"""
from repro.core.placement.scoring import (  # noqa: F401
    DISPATCH_POLICIES, TIER_SCORE, TIERS, NodeSnapshot, choose_node,
    locality_score,
)
from repro.core.slowness import (  # noqa: F401
    EwmaDetector, HedgeConfig, QuarantineConfig, SlownessDetector,
)

__all__ = ["DISPATCH_POLICIES", "TIERS", "TIER_SCORE", "NodeSnapshot",
           "choose_node", "locality_score", "EwmaDetector", "HedgeConfig",
           "QuarantineConfig", "SlownessDetector"]
