"""Shared GPU compute plane: fractional SM slicing + same-function batching
(docs/compute.md).

The memory plane already shares read-only/context bytes across invocations;
this module shares the *compute* stage the same way, behind one knob set
(``compute=``) riding the usual spec/gateway adopt-or-refuse plumbing.
Defaults off (``compute="exclusive"``) keep both drivers bit-identical to
the seed — the plane is only ever consulted when a :class:`ComputeConfig`
with ``mode="shared"`` is attached.

Two cooperating mechanisms, HAS-GPU-style (PAPERS.md):

* **Spatial slicing** — a node's SM budget is quantized into
  ``ComputeConfig.slices`` equal slices. A function needs ``k`` slices
  (from its declared ``sm_fraction``, or auto-derived from its profiled
  compute stage); the plane packs co-running invocations deterministically
  and stretches a granted-short invocation's compute span by ``k/granted``.
  Small functions co-run on one GPU instead of serializing behind the
  seed's exclusive compute FIFO.
* **Same-function batching** — concurrent invocations of one function on
  one node coalesce into a single kernel launch over stacked inputs (the
  Pallas kernels in ``src/repro/kernels/`` all grid over the batch axis).
  A batch of ``n`` costs ``compute_s * (1 + batch_marginal * (n - 1))``
  total — the marginal cost of an extra batch row is pinned by
  ``benchmarks/kernel_bench.py``'s batch-axis sweep — so the per-member
  amortized span shrinks toward ``batch_marginal * compute_s``. The
  collection window is deadline-aware: a member is never held past its
  EDF slack (``arrival + deadline - now``, charged the worst-case stacked
  span).

Both drivers consume this module byte-for-byte: the simulator attaches a
:class:`ComputePlane` per :class:`~repro.core.sim.domain.GPUNode` (virtual
time, event-driven :class:`BatchCollector`), the threaded runtime attaches
a :class:`ThreadedComputePlane` per ``SageRuntime`` (condition-variable
twin with the identical slicing/amortization arithmetic).
"""
from __future__ import annotations

import math
import threading
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

__all__ = [
    "COMPUTE_MODES", "ComputeConfig", "resolve_compute", "slices_for",
    "batched_span", "batch_hold_s", "ComputePlane", "BatchCollector",
    "ThreadedComputePlane", "empty_compute_stats",
]

COMPUTE_MODES = ("exclusive", "shared")

#: number of SM slices a node's compute budget quantizes into
DEFAULT_SLICES = 8
#: default collection window before an under-full batch launches anyway
DEFAULT_WINDOW_S = 0.002
#: marginal cost of one extra batch row, as a fraction of a solo launch —
#: conservative vs the kernel_bench sweep (stacked Pallas launches measure
#: well under this on the reference path)
DEFAULT_MARGINAL = 0.3
#: auto sm_fraction: a function whose profiled compute stage is this long
#: (or longer) wants the whole GPU; shorter stages scale down linearly
DEFAULT_AUTO_FULL_MS = 40.0


@dataclass(frozen=True)
class ComputeConfig:
    """Resolved ``compute=`` knob (``resolve_compute`` normalizes the
    user-facing forms; ``None`` everywhere means exclusive/seed)."""

    mode: str = "shared"
    slices: int = DEFAULT_SLICES
    max_batch: int = 1            # 1 = slicing only, batching off
    batch_window_s: float = DEFAULT_WINDOW_S
    batch_marginal: float = DEFAULT_MARGINAL
    auto_full_ms: float = DEFAULT_AUTO_FULL_MS

    def __post_init__(self) -> None:
        if self.mode not in COMPUTE_MODES:
            raise ValueError(
                f"unknown compute mode {self.mode!r}; use one of "
                f"{COMPUTE_MODES}")
        if self.slices < 1:
            raise ValueError(f"compute slices must be >= 1, got {self.slices}")
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.batch_window_s < 0.0:
            raise ValueError("batch_window_s must be >= 0")
        if not 0.0 <= self.batch_marginal <= 1.0:
            raise ValueError("batch_marginal must be in [0, 1]")
        if self.auto_full_ms <= 0.0:
            raise ValueError("auto_full_ms must be > 0")


def resolve_compute(value: Any) -> Optional[ComputeConfig]:
    """Normalize the ``compute=`` knob. ``None``/``"exclusive"`` -> ``None``
    (the seed path — no plane is ever attached); ``"shared"`` -> defaults;
    a dict -> ``ComputeConfig(**dict)``; a config passes through. An
    explicit ``mode="exclusive"`` config also resolves to ``None`` so every
    consumer has exactly one off-state to test."""
    if value is None or value == "exclusive":
        return None
    if value == "shared" or value is True:
        return ComputeConfig()
    if isinstance(value, dict):
        value = ComputeConfig(**value)
    if isinstance(value, ComputeConfig):
        return None if value.mode == "exclusive" else value
    raise ValueError(
        f"compute must be 'exclusive', 'shared', a dict, or a "
        f"ComputeConfig; got {value!r}")


def slices_for(cfg: ComputeConfig, sm_fraction: Optional[float],
               compute_s: float) -> int:
    """SM slices a function needs: its declared ``sm_fraction`` quantized
    up, or (auto mode) the profiled compute stage scaled against
    ``auto_full_ms`` — a 5 ms function on the default 40 ms scale wants
    1/8 of the GPU. Always in ``[1, slices]``; deterministic."""
    frac = sm_fraction
    if frac is None:
        frac = min(1.0, compute_s / (cfg.auto_full_ms / 1e3))
    k = int(math.ceil(frac * cfg.slices - 1e-9))
    return max(1, min(cfg.slices, k))


def batched_span(compute_s: float, n: int, marginal: float) -> float:
    """Total span of one stacked launch over ``n`` inputs."""
    if n <= 1:
        return compute_s
    return compute_s * (1.0 + marginal * (n - 1))


def batch_hold_s(cfg: ComputeConfig, now: float, arrival_t: Optional[float],
                 deadline_s: Optional[float], est_compute_s: float) -> float:
    """How long this member may sit in an open batch: the window, capped by
    the member's EDF slack so batching never creates a deadline miss the
    member didn't already have. The slack is charged the WORST-CASE stacked
    span (a full ``max_batch`` launch), not the solo span — an edge-of-slack
    member would otherwise miss by exactly the batch's marginal overhead."""
    if deadline_s is None or arrival_t is None:
        return cfg.batch_window_s
    worst = batched_span(est_compute_s, cfg.max_batch, cfg.batch_marginal)
    slack = arrival_t + deadline_s - now - worst
    return max(0.0, min(cfg.batch_window_s, slack))


def empty_compute_stats(mode: str, slices: int) -> Dict[str, object]:
    """The exact key set ``compute_stats()`` reports on BOTH drivers
    (runtime<->sim key parity, like ``resilience_stats``)."""
    return {"mode": mode, "slices": slices, "grants": 0,
            "contended_grants": 0, "batches": 0, "batched": 0}


# ----------------------------------------------------------------------
# simulator side
# ----------------------------------------------------------------------
class ComputePlane:
    """Virtual-time fractional SM budget for one simulated node.

    Each slice is a FIFO of its own (``free_at``); a grant takes the
    earliest instant any slice frees, claims ``min(k, idle-then)`` slices,
    and stretches the span by ``k/granted`` when granted short. Packing is
    deterministic: ties break by slice index, so replays are exact."""

    __slots__ = ("cfg", "free_at", "grants", "contended_grants",
                 "batches", "batched")

    def __init__(self, cfg: ComputeConfig):
        self.cfg = cfg
        self.free_at = [0.0] * cfg.slices
        self.grants = 0
        self.contended_grants = 0
        self.batches = 0
        self.batched = 0

    def slices_for(self, sm_fraction: Optional[float],
                   compute_s: float) -> int:
        return slices_for(self.cfg, sm_fraction, compute_s)

    def acquire(self, now: float, k: int, span_s: float
                ) -> Tuple[float, float]:
        """Grant ``k`` slices for ``span_s``; returns ``(start, span)``
        with ``span`` stretched by ``k/granted`` under contention."""
        free_at = self.free_at
        start = max(now, min(free_at))
        idle = [i for i, t in enumerate(free_at) if t <= start + 1e-12]
        g = min(k, len(idle))
        span = span_s * (k / g)
        end = start + span
        for i in idle[:g]:
            free_at[i] = end
        self.grants += 1
        if g < k:
            self.contended_grants += 1
        return start, span

    def free_fraction(self, now: float) -> float:
        """Fraction of the SM budget idle right now (dispatch scoring)."""
        free = sum(1 for t in self.free_at if t <= now)
        return free / len(self.free_at)

    def reset(self) -> None:
        """Node teardown/crash: all in-flight grants died with the epoch."""
        for i in range(len(self.free_at)):
            self.free_at[i] = 0.0

    def stats(self) -> Dict[str, object]:
        out = empty_compute_stats("shared", self.cfg.slices)
        out.update(grants=self.grants, contended_grants=self.contended_grants,
                   batches=self.batches, batched=self.batched)
        return out


class BatchCollector:
    """One OPEN same-function batch on one simulated node.

    Members join as their setup paths finish (``SageInvocation`` hands over
    instead of creating its ``Completion``); the batch flushes when it hits
    ``max_batch`` or when the tightest member's hold expires — every join
    can only move the flush *earlier* (generation-guarded re-arm), so no
    member is ever held past its own EDF slack. ``finish`` is the driver
    callback that turns one member + the shared grant into its per-member
    completion; the node's ``epoch`` guards against flushing across a
    crash."""

    __slots__ = ("clock", "node", "fn", "cfg", "finish", "members",
                 "close_at", "closed", "epoch", "gen")

    def __init__(self, clock, node, fn, cfg: ComputeConfig,
                 finish: Callable):
        self.clock = clock
        self.node = node
        self.fn = fn
        self.cfg = cfg
        self.finish = finish
        self.members: List[Tuple[Any, float]] = []  # (invocation, ready_t)
        self.close_at: Optional[float] = None
        self.closed = False
        self.epoch = node.epoch
        self.gen = 0

    def join(self, inv) -> None:
        now = self.clock.now()
        self.members.append((inv, now))
        inv._batch = self
        rec = inv.rec
        est = self.fn.compute_s * self.node.slow_factor
        limit = now + batch_hold_s(self.cfg, now, rec.arrival_t,
                                   rec.deadline_s, est)
        if len(self.members) >= self.cfg.max_batch:
            self._flush()
            return
        if self.close_at is None or limit < self.close_at:
            self.close_at = limit
            self.gen += 1
            gen = self.gen
            self.clock.schedule_at(limit, lambda: self._fire(gen))

    def leave(self, inv) -> None:
        """A member is cancelled (hedge loser) while parked: it exits the
        batch before the stacked launch, so the flush neither counts it nor
        charges it a span — its own failure path releases its bytes."""
        self.members = [(m, t) for m, t in self.members if m is not inv]
        inv._batch = None
        if not self.members:
            self._retire()

    def _retire(self) -> None:
        self.closed = True
        batches = self.node.compute_batches
        if batches is not None and batches.get(self.fn.name) is self:
            del batches[self.fn.name]

    def _fire(self, gen: int) -> None:
        if self.closed or gen != self.gen or self.node.epoch != self.epoch:
            return
        self._flush()

    def _flush(self) -> None:
        self._retire()
        members = self.members
        size = len(members)
        if not size:
            return
        now = self.clock.now()
        plane = self.node.compute_plane
        compute_s = self.fn.compute_s * self.node.slow_factor
        total = batched_span(compute_s, size, self.cfg.batch_marginal)
        k = plane.slices_for(getattr(self.fn, "sm_fraction", None),
                             self.fn.compute_s)
        start, span = plane.acquire(now, k, total)
        if size > 1:
            plane.batches += 1
            plane.batched += size
        ids = sorted(m.rec.request_id for m, _ in members)
        for inv, ready_t in members:
            inv._batch = None
            self.finish(inv, ready_t, start, span, size,
                        tuple(i for i in ids if i != inv.rec.request_id))


# ----------------------------------------------------------------------
# threaded-runtime side
# ----------------------------------------------------------------------
class _RuntimeBatch:
    __slots__ = ("requests", "closed", "close_at", "size", "remaining",
                 "granted", "k")

    def __init__(self) -> None:
        self.requests: List[Any] = []
        self.closed = False
        self.close_at = float("inf")
        self.size = 0
        self.remaining = 0
        self.granted: Optional[int] = None
        self.k = 0


class ThreadedComputePlane:
    """Condition-variable twin of :class:`ComputePlane` for the threaded
    ``SageRuntime``: the same slice budget, grant-short stretching, and
    batch amortization arithmetic, applied to the *measured* handler wall
    time (the slow_factor sleep-to-model pattern from ``sage_run``). The
    default path never constructs one — ``compute="exclusive"`` keeps the
    seed's whole-node handler lock."""

    def __init__(self, cfg: ComputeConfig, clock):
        self.cfg = cfg
        self.clock = clock
        self._cond = threading.Condition()
        self._free = cfg.slices
        self._open: Dict[str, _RuntimeBatch] = {}
        self.grants = 0
        self.contended_grants = 0
        self.batches = 0
        self.batched = 0

    # -- introspection --------------------------------------------------
    def free_fraction(self) -> float:
        with self._cond:
            return self._free / self.cfg.slices

    def stats(self) -> Dict[str, object]:
        with self._cond:
            out = empty_compute_stats("shared", self.cfg.slices)
            out.update(grants=self.grants,
                       contended_grants=self.contended_grants,
                       batches=self.batches, batched=self.batched)
            return out

    # -- the wrapped handler path --------------------------------------
    def run(self, fn, inner: Callable, shim, request):
        """Execute ``inner`` (the function's real handler) under the shared
        plane: optionally batch with concurrent same-function arrivals,
        acquire the function's slice grant, and stretch the measured wall
        time to the modeled shared-compute span."""
        import time as _time

        from repro.core.slowness import HedgedError

        est = getattr(fn, "compute_s_hint", 0.0) or 0.0
        k = slices_for(self.cfg, getattr(fn, "sm_fraction", None), est)
        batch = None
        if self.cfg.max_batch > 1:
            batch = self._join(fn, request, est)
        ev = getattr(request, "hedge_cancel", None)
        if ev is not None and ev.is_set():
            # cancelled while parked in the collector: exit before the
            # launch so the engine's HedgedError unwind releases the
            # member's bytes exactly (no leaked device_used)
            if batch is not None:
                self._leave(batch, request)
            raise HedgedError(f"{fn.name}: superseded by hedged twin")
        g = self._acquire(batch, k)
        t0 = _time.monotonic()
        try:
            return inner(shim, request)
        finally:
            wall = _time.monotonic() - t0
            size = batch.size if batch is not None else 1
            span = batched_span(wall, size, self.cfg.batch_marginal) * (k / g)
            if span > wall:
                self.clock.sleep(span - wall)
            if batch is not None:
                self._release_batch(batch)
            else:
                self._release_solo(g)

    # -- batching -------------------------------------------------------
    def _join(self, fn, request, est: float) -> _RuntimeBatch:
        """Park in the open batch for ``fn`` until it closes (max_batch
        reached, or the tightest member's hold expires). Symmetric: every
        member watches the close deadline, so a cancelled member never
        strands the rest."""
        cfg, clock = self.cfg, self.clock
        ev = getattr(request, "hedge_cancel", None)
        with self._cond:
            now = clock.now()
            b = self._open.get(fn.name)
            if b is None or b.closed:
                b = _RuntimeBatch()
                self._open[fn.name] = b
            b.requests.append(request)
            hold = batch_hold_s(cfg, now, getattr(request, "arrival_t", now),
                                getattr(request, "deadline_s", None), est)
            b.close_at = min(b.close_at, now + hold)
            if len(b.requests) >= cfg.max_batch:
                self._close(fn.name, b)
            self._cond.notify_all()
            while not b.closed:
                if ev is not None and ev.is_set():
                    break  # caller re-checks and leaves
                now = clock.now()
                if now >= b.close_at:
                    self._close(fn.name, b)
                    self._cond.notify_all()
                    break
                self._cond.wait(min(b.close_at - now, 0.05))
        return b

    def _close(self, name: str, b: _RuntimeBatch) -> None:
        # caller holds self._cond
        b.closed = True
        if self._open.get(name) is b:
            del self._open[name]
        b.size = b.remaining = len(b.requests)
        if b.size > 1:
            self.batches += 1
            self.batched += b.size
            ids = sorted(getattr(r, "uuid", "") for r in b.requests)
        for r in b.requests:
            r.batch_size = b.size
            r.batched_with = (tuple(i for i in ids if i != r.uuid)
                              if b.size > 1 else ())

    def _leave(self, b: _RuntimeBatch, request) -> None:
        with self._cond:
            if not b.closed:
                if request in b.requests:
                    b.requests.remove(request)
                if not b.requests:
                    b.closed = True
                    for name, cand in list(self._open.items()):
                        if cand is b:
                            del self._open[name]
            else:
                b.remaining -= 1
                if b.remaining == 0 and b.granted is not None:
                    self._free += b.granted
                    b.granted = None
            self._cond.notify_all()

    # -- slice accounting ----------------------------------------------
    def _acquire(self, batch: Optional[_RuntimeBatch], k: int) -> int:
        """One grant per solo invocation, one SHARED grant per batch (the
        stacked launch is a single kernel). Waits only when the budget is
        fully busy; otherwise takes what is free, like the sim plane."""
        with self._cond:
            if batch is not None:
                # every member re-checks ``granted`` after each wake: a
                # peer may have granted the batch while this member was
                # parked on the budget (waiting on ``_free`` alone here
                # double-grants the batch and leaks its first grant)
                while batch.granted is None and self._free <= 0:
                    self._cond.wait()
                if batch.granted is None:
                    batch.granted = min(k, self._free)
                    batch.k = k
                    self._free -= batch.granted
                    self.grants += 1
                    if batch.granted < k:
                        self.contended_grants += 1
                    self._cond.notify_all()  # wake peers parked above
                return batch.granted
            while self._free <= 0:
                self._cond.wait()
            g = min(k, self._free)
            self._free -= g
            self.grants += 1
            if g < k:
                self.contended_grants += 1
            return g

    def _release_solo(self, g: int) -> None:
        with self._cond:
            self._free += g
            self._cond.notify_all()

    def _release_batch(self, batch: _RuntimeBatch) -> None:
        """The stacked launch's shared grant frees when its LAST member's
        modeled span elapses."""
        with self._cond:
            batch.remaining -= 1
            if batch.remaining == 0 and batch.granted is not None:
                self._free += batch.granted
                batch.granted = None
            self._cond.notify_all()
