"""System policies: SAGE and the paper's baselines (§7.1).

* FixedGSL    — instance-fixed GPU serverless (Azure Functions / Alibaba FC
                style): 1 GiB-granularity memory slots, serial setup, no
                sharing.
* FixedGSL-F  — FixedGSL with flexible (exact-size) allocation: more
                concurrent invocations, *worse* data-path contention (the
                paper shows it underperforming FixedGSL).
* DGSF        — disaggregated GPUs for serverless (IPDPS'22): 4 pre-created
                GPU contexts per function, FCFS per-function queue, no
                read-only sharing.
* SAGE        — parallel setup + read-only & context sharing + multi-stage
                exit.
* SAGE-NR     — SAGE with read-only sharing disabled (ablation, Fig 16).
* SAGE-PS     — parallel setup only (Fig 15 ablation).
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional


@dataclass(frozen=True)
class SystemPolicy:
    name: str
    parallel_setup: bool = False       # overlap gpu_ctx with data loading
    share_read_only: bool = False      # dedupe RO data across invocations
    share_context: bool = False        # reuse live engine/executable
    pre_created_contexts: int = 0      # DGSF: contexts pinned at registration
    slot_granularity: int = 1 << 30    # FixedGSL: memory rounding (bytes); 0 = exact
    multi_stage_exit: bool = False     # SAGE ladder vs single keep-warm
    keep_warm_s: float = 30.0          # plain keep-warm TTL for baselines
    prewarmed_container: bool = True   # §7.1: all systems get pre-warmed containers
    executable_cache: bool = False     # BEYOND-PAPER (TPU): keep the compiled
    # executable in host RAM past exit stage 3, so a stage-3/4 warm hit pays
    # only program re-load (~10% of a compile), not a full context creation.
    # The paper's GPU contexts cannot be cached this way; XLA executables can.


FIXEDGSL = SystemPolicy("fixedgsl")
FIXEDGSL_F = SystemPolicy("fixedgsl-f", slot_granularity=0)
DGSF = SystemPolicy(
    "dgsf", pre_created_contexts=4, share_context=True, slot_granularity=0
)
SAGE = SystemPolicy(
    "sage", parallel_setup=True, share_read_only=True, share_context=True,
    slot_granularity=0, multi_stage_exit=True,
)
SAGE_NR = replace(SAGE, name="sage-nr", share_read_only=False)
SAGE_PS = SystemPolicy(
    "sage-ps", parallel_setup=True, slot_granularity=0
)
# beyond-paper TPU variant: executable caching across exit stage 3
SAGE_CACHE = replace(SAGE, name="sage-cache", executable_cache=True)

SYSTEMS = {p.name: p for p in (FIXEDGSL, FIXEDGSL_F, DGSF, SAGE, SAGE_NR,
                               SAGE_PS, SAGE_CACHE)}


def get_system(name: str) -> SystemPolicy:
    if name not in SYSTEMS:
        raise KeyError(f"unknown system {name!r}; known: {sorted(SYSTEMS)}")
    return SYSTEMS[name]
