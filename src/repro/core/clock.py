"""Clock abstraction: RealClock (threads) / VirtualClock (discrete-event).

Policy code takes a clock so the threaded runtime and the trace simulator
share one implementation of SAGE's decision logic.

``VirtualClock`` is a thin facade over the discrete-event engine in
:mod:`repro.core.sim.kernel` — the event heap, typed event records, and
the past-time causality counter all live there; this class only pins the
legacy name and call signature (``now`` / ``schedule`` / ``schedule_at`` /
``run_until`` / ``empty``) that pre-kernel callers were built against.
"""
from __future__ import annotations

import time

from repro.core.sim.kernel import EventKernel, EventKind


class RealClock:
    def now(self) -> float:
        return time.monotonic()

    def sleep(self, dt: float) -> None:
        if dt > 0:
            time.sleep(dt)


class VirtualClock(EventKernel):
    """Event-queue virtual time, single-threaded (driven by the simulator).

    Inherits the whole kernel: ``schedule(dt, fn, *args)`` /
    ``schedule_at(t, fn, *args)`` post typed events, ``run_until`` fires
    them in ``(t, seq)`` order, ``events_processed`` / ``kind_counts`` /
    ``past_events`` expose the engine counters (docs/simulator.md).
    """

    __slots__ = ()


__all__ = ["RealClock", "VirtualClock", "EventKind"]
