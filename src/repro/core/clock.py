"""Clock abstraction: RealClock (threads) / VirtualClock (discrete-event).

Policy code takes a clock so the threaded runtime and the trace simulator
share one implementation of SAGE's decision logic.
"""
from __future__ import annotations

import heapq
import itertools
import threading
import time
from typing import Callable, List, Optional, Tuple


class RealClock:
    def now(self) -> float:
        return time.monotonic()

    def sleep(self, dt: float) -> None:
        if dt > 0:
            time.sleep(dt)


class VirtualClock:
    """Event-queue virtual time, single-threaded (driven by the simulator)."""

    def __init__(self):
        self._t = 0.0
        self._q: List[Tuple[float, int, Callable]] = []
        self._seq = itertools.count()

    def now(self) -> float:
        return self._t

    def schedule(self, dt: float, fn: Callable) -> None:
        heapq.heappush(self._q, (self._t + max(dt, 0.0), next(self._seq), fn))

    def schedule_at(self, t: float, fn: Callable) -> None:
        heapq.heappush(self._q, (max(t, self._t), next(self._seq), fn))

    def run_until(self, t_end: float = float("inf")) -> None:
        while self._q and self._q[0][0] <= t_end:
            t, _, fn = heapq.heappop(self._q)
            self._t = t
            fn()
        if t_end != float("inf"):
            self._t = max(self._t, t_end)

    def empty(self) -> bool:
        return not self._q
