"""Taxon shim (paper §5.2): intercepts the function's GPU calls and
re-dispatches them by category — memory calls to the unified memory daemon,
kernel calls to the kernel executor.

TPU adaptation: the interception point is the runtime API the handler is
written against (SageLoadToGPU / SageDumpToDB / alloc / launch) rather than
the CUDA driver ABI; classification and forwarding semantics are the paper's.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

from repro.core.daemon import Handle, MemoryDaemon
from repro.core.request import Request


class TaxonShim:
    def __init__(self, daemon: MemoryDaemon, executor, request: Request,
                 handles: Dict[str, Handle]):
        self.daemon = daemon
        self.executor = executor
        self.request = request
        self._handles = handles  # pre-loaded by the engine's prepare()
        self.memory_calls = 0
        self.kernel_calls = 0

    # ---- memory calls (-> daemon) -------------------------------------
    def sage_load_to_gpu(self, key: str) -> Handle:
        """Async: returns immediately with a handle; the daemon may still be
        loading (§5.2.1: 'SageLoadToGPU is an asynchronous operation')."""
        self.memory_calls += 1
        h = self._handles.get(key)
        if h is None:
            # datum not declared in the request: load on demand (no overlap
            # benefit — this is the slow path the programming model avoids)
            for d in self.request.in_data:
                if d.key == key:
                    h = self.daemon.prepare(
                        type(self.request)(
                            function_name=self.request.function_name, in_data=[d]
                        )
                    )[key]
                    break
            else:
                raise KeyError(f"{key} not in request.in_data")
            self._handles[key] = h
        return h

    def cuda_malloc(self, key: str, nbytes: int) -> Handle:
        self.memory_calls += 1
        h = self.daemon.alloc(self.request, key, nbytes)
        self._handles[key] = h
        return h

    def sage_dump_to_db(self, key: str, value: Any, size: int = 0) -> None:
        self.memory_calls += 1
        self.daemon.db.put(key, value, size=size)

    # ---- kernel calls (-> executor) ------------------------------------
    def launch_kernel(self, fn, *args, **kwargs):
        """Forwarded to the kernel executor, which verifies with the daemon
        that every operand handle is ready before launching (§5.2.2)."""
        self.kernel_calls += 1
        return self.executor.launch(fn, args, kwargs)
