"""Engine layer: a lean discrete-event kernel.

The kernel is framework-like — events, a heap, virtual time — and knows
nothing about GPUs or serving. Domain code registers work by posting
:class:`Event` records; the kernel fires them in ``(t, seq)`` order.

Design constraints (docs/simulator.md):

* **Typed event records, C-speed ordering.** :class:`Event` is a tuple
  subclass ``(t, seq, kind, fn, args)``: the heap compares events with the
  C tuple comparator (``t`` then the unique ``seq`` — comparison never
  reaches the callable), while call sites still get named accessors and a
  ``kind`` taxonomy for profiling.
* **Allocation-light.** One object per event, no closure chains: handlers
  are bound methods on slotted state machines and positional ``args`` ride
  the event record itself.
* **Causality is loud.** ``schedule_at`` with a timestamp in the past
  still clamps to *now* (the pre-kernel ``VirtualClock`` behavior, which
  seeded traces depend on) but now counts the violation in
  ``past_events`` and warns once — a new handler that schedules into the
  past surfaces in tests instead of silently reordering history.
"""
from __future__ import annotations

import warnings
from enum import IntEnum
from heapq import heappop, heappush
from typing import Callable, Tuple

__all__ = ["Event", "EventKind", "EventKernel"]

_INF = float("inf")


class EventKind(IntEnum):
    """Event taxonomy (docs/simulator.md). Purely informational: the kernel
    orders by time, never by kind. ``CALL`` is the generic bucket the
    :class:`~repro.core.clock.VirtualClock` facade posts into."""

    CALL = 0        # generic scheduled callback (legacy facade)
    ARRIVAL = 1     # a workload arrival entering the system
    FEED = 2        # trace-feeder refill (streaming replay)
    TRANSFER = 3    # bandwidth-broker stream completion
    ADMISSION = 4   # memory-admission grant / expiry timer
    COMPUTE = 5     # compute (kernel-execution) completion
    TIMER = 6       # exit-ladder and other domain timers
    FAULT = 7       # injected fault (crash/restart/degrade/flap)


class Event(tuple):
    """One scheduled event: ``(t, seq, kind, fn, args)``.

    A tuple subclass so heap sift comparisons run in C — ``seq`` is unique
    per kernel, so ordering is decided before the non-comparable ``fn``
    field is ever reached.
    """

    __slots__ = ()

    def __new__(cls, t: float, seq: int, kind: int, fn: Callable,
                args: Tuple = ()):
        return tuple.__new__(cls, (t, seq, kind, fn, args))

    @property
    def t(self) -> float:
        return self[0]

    @property
    def seq(self) -> int:
        return self[1]

    @property
    def kind(self) -> int:
        return self[2]

    @property
    def fn(self) -> Callable:
        return self[3]

    @property
    def args(self) -> Tuple:
        return self[4]

    def __repr__(self) -> str:  # debugging aid, not a hot path
        kind = EventKind(self[2]).name if self[2] in EventKind._value2member_map_ \
            else self[2]
        return f"Event(t={self[0]:.6f}, seq={self[1]}, kind={kind}, fn={self[3]!r})"


class EventKernel:
    """Heap-scheduled virtual time. Single-threaded; the domain drives it.

    Counters (all plain ints, safe to read any time):

    * ``events_processed`` — events fired since construction.
    * ``kind_counts[k]`` — events fired per :class:`EventKind` value.
    * ``past_events`` — ``schedule_at`` calls that targeted the past and
      were clamped to *now* (each one is a latent causality bug in a
      handler; the first occurrence warns).
    """

    __slots__ = ("_t", "_q", "_seq", "events_processed", "kind_counts",
                 "past_events")

    def __init__(self):
        self._t = 0.0
        self._q: list = []
        self._seq = 0
        self.events_processed = 0
        self.kind_counts = [0] * (max(EventKind) + 1)
        self.past_events = 0

    # ------------------------------------------------------------------
    def now(self) -> float:
        return self._t

    def empty(self) -> bool:
        return not self._q

    @property
    def queued(self) -> int:
        """Events currently on the heap. (Deliberately a property, not
        ``__len__``: several call sites truth-test clocks — ``clock or
        RealClock()`` — and an empty kernel must stay truthy.)"""
        return len(self._q)

    # ------------------------------------------------------------------
    def schedule(self, dt: float, fn: Callable, *args,
                 kind: int = EventKind.CALL) -> None:
        """Post ``fn(*args)`` at ``now + dt`` (negative ``dt`` clamps to
        now, matching the pre-kernel clock)."""
        self._seq += 1
        # tuple.__new__ directly: skips the Python-level Event.__new__
        # frame on the hottest allocation in the simulator
        heappush(self._q,
                 tuple.__new__(Event, (self._t + (dt if dt > 0.0 else 0.0),
                                       self._seq, kind, fn, args)))

    def schedule_at(self, t: float, fn: Callable, *args,
                    kind: int = EventKind.CALL) -> None:
        """Post ``fn(*args)`` at absolute time ``t``. A ``t`` in the past
        clamps to *now* — counted in ``past_events`` and warned once, so
        causality bugs in new handlers surface in tests instead of being
        silently reordered."""
        if t < self._t:
            self.past_events += 1
            if self.past_events == 1:
                warnings.warn(
                    f"schedule_at(t={t!r}) is in the past (now={self._t!r}); "
                    "clamping to now. Further occurrences are counted in "
                    "EventKernel.past_events without warning.",
                    RuntimeWarning, stacklevel=3)
            t = self._t
        self._seq += 1
        heappush(self._q, tuple.__new__(Event, (t, self._seq, kind, fn, args)))

    # ------------------------------------------------------------------
    def run_until(self, t_end: float = _INF) -> int:
        """Fire events in ``(t, seq)`` order up to and including ``t_end``;
        returns the number fired. With a finite ``t_end`` the clock lands
        exactly on ``t_end`` afterwards (idle time is skipped)."""
        q = self._q
        counts = self.kind_counts
        fired = 0
        while q and q[0][0] <= t_end:
            ev = heappop(q)
            self._t = ev[0]
            counts[ev[2]] += 1
            fn, args = ev[3], ev[4]
            if args:
                fn(*args)
            else:
                fn()
            fired += 1
        self.events_processed += fired
        if t_end != _INF and t_end > self._t:
            self._t = t_end
        return fired
