"""Streaming telemetry aggregates for trace-scale replays.

A million-invocation replay cannot afford one retained
:class:`~repro.core.telemetry.InvocationRecord` per arrival (~1 KB each ->
gigabytes). :class:`AggregateTelemetry` is a drop-in *sink* for the
``telemetry.add(rec)`` call sites that keeps O(1) memory:

* running count / failure / SLO / warm-hit tallies,
* a P² (Jain & Chlamtac 1985) sketch per tracked quantile — online,
  five-marker, no sample retention,
* a fixed-size reservoir (Vitter's algorithm R) of latencies for exact
  post-hoc quantiles over a uniform sample.

The simulator selects it with ``Simulator(record_mode="aggregate")``;
the default ``"full"`` mode keeps the classic record-retaining
:class:`~repro.core.telemetry.Telemetry` unchanged.
"""
from __future__ import annotations

import random
from typing import Dict, List, Optional

from repro.core.telemetry import InvocationRecord, classify_error

__all__ = ["P2Quantile", "Reservoir", "AggregateTelemetry"]


class P2Quantile:
    """P² single-quantile estimator: five markers tracked online, heights
    adjusted by a piecewise-parabolic fit. Exact for the first five
    observations, O(1) per observation after."""

    __slots__ = ("p", "_n", "_q", "_pos", "_count")

    def __init__(self, p: float):
        if not 0.0 < p < 1.0:
            raise ValueError(f"quantile must be in (0, 1), got {p}")
        self.p = p
        self._q: List[float] = []       # marker heights
        self._pos: List[float] = []     # marker positions (1-based)
        self._n: List[int] = []         # actual marker positions
        self._count = 0

    def add(self, x: float) -> None:
        self._count += 1
        q = self._q
        if len(q) < 5:
            q.append(x)
            q.sort()
            if len(q) == 5:
                self._n = [1, 2, 3, 4, 5]
                p = self.p
                self._pos = [1.0, 1.0 + 2.0 * p, 1.0 + 4.0 * p,
                             3.0 + 2.0 * p, 5.0]
            return
        n = self._n
        # locate the cell x falls into, updating extremes
        if x < q[0]:
            q[0] = x
            k = 0
        elif x >= q[4]:
            q[4] = x
            k = 3
        else:
            k = 0
            while k < 3 and x >= q[k + 1]:
                k += 1
        for i in range(k + 1, 5):
            n[i] += 1
        p = self.p
        self._pos[1] += p / 2.0
        self._pos[2] += p
        self._pos[3] += (1.0 + p) / 2.0
        self._pos[4] += 1.0
        # adjust the three middle markers toward their desired positions
        for i in (1, 2, 3):
            d = self._pos[i] - n[i]
            if (d >= 1.0 and n[i + 1] - n[i] > 1) or \
                    (d <= -1.0 and n[i - 1] - n[i] < -1):
                d = 1 if d > 0 else -1
                # piecewise-parabolic (P²) height update
                qn = q[i] + d / (n[i + 1] - n[i - 1]) * (
                    (n[i] - n[i - 1] + d) * (q[i + 1] - q[i])
                    / (n[i + 1] - n[i])
                    + (n[i + 1] - n[i] - d) * (q[i] - q[i - 1])
                    / (n[i] - n[i - 1]))
                if q[i - 1] < qn < q[i + 1]:
                    q[i] = qn
                else:  # parabola left the bracket: fall back to linear
                    q[i] = q[i] + d * (q[i + d] - q[i]) / (n[i + d] - n[i])
                n[i] += d

    def value(self) -> float:
        """Current estimate (exact while fewer than 5 observations)."""
        q = self._q
        if not q:
            return 0.0
        if len(q) < 5:
            s = sorted(q)
            return s[min(int(self.p * len(s)), len(s) - 1)]
        return q[2]

    @property
    def count(self) -> int:
        return self._count


class Reservoir:
    """Fixed-size uniform sample of a stream (Vitter's algorithm R)."""

    __slots__ = ("k", "n", "sample", "_rng")

    def __init__(self, k: int = 4096, rng: Optional[random.Random] = None):
        self.k = k
        self.n = 0
        self.sample: List[float] = []
        self._rng = rng or random.Random(0)

    def add(self, x: float) -> None:
        self.n += 1
        if len(self.sample) < self.k:
            self.sample.append(x)
        else:
            j = self._rng.randrange(self.n)
            if j < self.k:
                self.sample[j] = x

    def quantile(self, q: float) -> float:
        """Sorted-index quantile over the retained sample (same index rule
        as ``Telemetry._quantile``)."""
        if not self.sample:
            return 0.0
        vals = sorted(self.sample)
        return vals[min(int(q * len(vals)), len(vals) - 1)]


class AggregateTelemetry:
    """Streaming sink for ``telemetry.add(rec)``: aggregates, then drops
    the record. Tracks the end-to-end latency distribution (P² p50/p99 +
    reservoir), duration, goodput (completions that met their deadline),
    warm-hit and preemption tallies — the fields BENCH_*.json reports."""

    __slots__ = ("count", "completed", "failures", "warm_hits",
                 "preemptions", "stalled_s", "deadline_total",
                 "deadline_met", "first_arrival_t", "last_end_t",
                 "e2e_p50", "e2e_p99", "duration_p50", "duration_p99",
                 "e2e_sample", "e2e_sum", "error_classes")

    def __init__(self, *, reservoir_k: int = 4096, seed: int = 0):
        self.count = 0
        self.completed = 0
        self.failures = 0
        # failure tally by error class (docs/resilience.md taxonomy) —
        # the streaming twin of Telemetry.error_counts()
        self.error_classes: Dict[str, int] = {}
        self.warm_hits = 0
        self.preemptions = 0
        self.stalled_s = 0.0
        self.deadline_total = 0
        self.deadline_met = 0
        self.first_arrival_t: Optional[float] = None
        self.last_end_t = 0.0
        self.e2e_p50 = P2Quantile(0.5)
        self.e2e_p99 = P2Quantile(0.99)
        self.duration_p50 = P2Quantile(0.5)
        self.duration_p99 = P2Quantile(0.99)
        self.e2e_sample = Reservoir(reservoir_k,
                                    random.Random(f"{seed}:telemetry"))
        self.e2e_sum = 0.0

    # -- Telemetry-compatible sink ------------------------------------
    def add(self, rec: InvocationRecord) -> None:
        if rec.dropped:
            return  # superseded re-dispatch attempt, not an outcome
        self.count += 1
        if self.first_arrival_t is None or rec.arrival_t < self.first_arrival_t:
            self.first_arrival_t = rec.arrival_t
        if rec.end_t > self.last_end_t:
            self.last_end_t = rec.end_t
        self.preemptions += rec.preemptions
        self.stalled_s += rec.stalled_s
        if rec.error is not None:
            self.failures += 1
            cls = rec.error_class or classify_error(rec.error) or "other"
            self.error_classes[cls] = self.error_classes.get(cls, 0) + 1
            if rec.deadline_s is not None:
                self.deadline_total += 1  # a failed request missed its SLO
            return
        self.completed += 1
        if rec.warm_stage is not None:
            self.warm_hits += 1
        e2e = rec.e2e
        self.e2e_sum += e2e
        self.e2e_p50.add(e2e)
        self.e2e_p99.add(e2e)
        self.e2e_sample.add(e2e)
        dur = rec.duration
        self.duration_p50.add(dur)
        self.duration_p99.add(dur)
        if rec.deadline_s is not None:
            self.deadline_total += 1
            if e2e <= rec.deadline_s:
                self.deadline_met += 1

    # -- views ---------------------------------------------------------
    def mean_e2e(self) -> float:
        return self.e2e_sum / self.completed if self.completed else 0.0

    def warm_fraction(self) -> float:
        return self.warm_hits / self.completed if self.completed else 0.0

    def error_counts(self) -> Dict[str, int]:
        """Failure tally by error class (Telemetry.error_counts twin)."""
        return dict(self.error_classes)

    def goodput(self) -> float:
        """Fraction of deadline-carrying requests that completed in time
        (1.0 when no request carried a deadline — goodput degenerates to
        completion then)."""
        if not self.deadline_total:
            return 1.0 if not self.failures else (
                self.completed / (self.completed + self.failures))
        return self.deadline_met / self.deadline_total

    def snapshot(self) -> Dict[str, float]:
        return {
            "count": self.count,
            "completed": self.completed,
            "failures": self.failures,
            "mean_e2e_s": self.mean_e2e(),
            "p50_e2e_s": self.e2e_p50.value(),
            "p99_e2e_s": self.e2e_p99.value(),
            "p50_duration_s": self.duration_p50.value(),
            "p99_duration_s": self.duration_p99.value(),
            "reservoir_p50_e2e_s": self.e2e_sample.quantile(0.5),
            "reservoir_p99_e2e_s": self.e2e_sample.quantile(0.99),
            "warm_fraction": self.warm_fraction(),
            "goodput": self.goodput(),
            "preemptions": self.preemptions,
            "stalled_s": self.stalled_s,
            "error_counts": dict(self.error_classes),
        }
