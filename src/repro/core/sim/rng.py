"""Named seeded RNG streams (engine layer).

All randomness in the simulator is injected: each consumer draws from its
own named stream, so adding a draw in one subsystem can never shift the
sequence another subsystem sees (the classic way seeded experiments rot).

The ``root`` stream is ``random.Random(seed)`` — bit-compatible with the
pre-kernel ``Simulator._rng``, whose ``choice`` stream the seeded paper
§7.8 random-dispatch replays depend on (tests/test_dispatch.py). Named
streams hash ``"{seed}:{name}"`` through ``random.Random``'s stable
str-seeding (SHA-512), the same scheme ``repro.api.workload`` uses for
per-function arrival streams.
"""
from __future__ import annotations

import random
from typing import Dict

__all__ = ["RngStreams"]


class RngStreams:
    """Registry of independent, deterministically-seeded RNG streams."""

    __slots__ = ("seed", "root", "_named")

    def __init__(self, seed: int = 0):
        self.seed = seed
        self.root = random.Random(seed)
        self._named: Dict[str, random.Random] = {}

    def get(self, name: str) -> random.Random:
        """The stream for ``name`` (created on first use; stable across
        processes and unaffected by draws on any other stream)."""
        rng = self._named.get(name)
        if rng is None:
            rng = self._named[name] = random.Random(f"{self.seed}:{name}")
        return rng
