"""Policy layer: scheduler / dispatch knobs as plugin strategy objects.

The *scoring and key code itself* is shared byte-for-byte with the threaded
daemon — admission keys use the same ``(-priority, deadline, seq)`` formula
as ``daemon._admission_key`` and cluster dispatch calls the same
:func:`repro.core.dispatch.choose_node` the cluster runtime uses. These
objects only bind that shared code to the simulator's call sites, so a new
policy is one registry entry, not a simulator edit.

(The transfer knob is already a plugin: :class:`repro.core.transfer
.LinkArbiter` carries the ``run_to_completion``/``preemptive`` modes.)
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

from repro.core.daemon import SCHEDULERS, AdmissionKey
from repro.core.placement import DISPATCH_POLICIES, choose_node
from repro.core.telemetry import InvocationRecord

__all__ = [
    "AdmissionPolicy", "FifoAdmission", "EdfAdmission", "admission_policy",
    "DispatchStrategy", "RandomDispatch", "SnapshotDispatch",
    "PlannedDispatch", "dispatch_strategy",
]


# ---------------------------------------------------------------------------
# admission (loader/memory ordering) — twin of daemon._admission_key
# ---------------------------------------------------------------------------
class AdmissionPolicy:
    """Orders a node's loader gate and memory-admission heap."""

    name = "?"

    def key(self, node, rec: Optional[InvocationRecord] = None) -> AdmissionKey:
        raise NotImplementedError


class FifoAdmission(AdmissionPolicy):
    """Pure arrival order (the node's monotonic key sequence)."""

    name = "fifo"

    def key(self, node, rec: Optional[InvocationRecord] = None) -> AdmissionKey:
        return (0, 0.0, next(node._key_seq))


class EdfAdmission(AdmissionPolicy):
    """Priority class first, then earliest absolute deadline (requests
    without a deadline sort last within their class)."""

    name = "edf"

    def key(self, node, rec: Optional[InvocationRecord] = None) -> AdmissionKey:
        seq = next(node._key_seq)
        if rec is not None:
            dl = (math.inf if rec.deadline_s is None
                  else rec.arrival_t + rec.deadline_s)
            return (-rec.priority, dl, seq)
        return (0, 0.0, seq)


_ADMISSION = {p.name: p for p in (FifoAdmission(), EdfAdmission())}
assert set(_ADMISSION) == set(SCHEDULERS)


def admission_policy(name: str) -> AdmissionPolicy:
    try:
        return _ADMISSION[name]
    except KeyError:
        raise ValueError(
            f"unknown scheduler {name!r}; use one of {SCHEDULERS}") from None


# ---------------------------------------------------------------------------
# cluster dispatch — twin of ClusterRuntime's node choice
# ---------------------------------------------------------------------------
class DispatchStrategy:
    """Picks the node an arrival runs on. ``pick`` returns ``(node, tier)``
    where ``tier`` is the function's residency tier on the chosen node AT
    DISPATCH time (recorded as ``InvocationRecord.dispatch_tier``)."""

    name = "?"

    def pick(self, sim, fn_name: str) -> Tuple[object, Optional[str]]:
        raise NotImplementedError


class RandomDispatch(DispatchStrategy):
    """Uniform choice from the simulator's root RNG — the same seeded
    ``rng.choice`` stream as the pre-dispatch simulator, so seeded §7.8
    replays are unchanged."""

    name = "random"

    def pick(self, sim, fn_name: str):
        # dispatchable_nodes() IS sim.nodes unless eviction is draining a
        # dead node, so the seeded choice stream is normally untouched
        node = sim._rng.choice(sim.dispatchable_nodes())
        return node, node.residency(fn_name)[0]


class SnapshotDispatch(DispatchStrategy):
    """Snapshot-scoring dispatch (``locality`` / ``least_loaded``): builds
    one :class:`~repro.core.dispatch.NodeSnapshot` per node and defers to
    the SAME :func:`~repro.core.dispatch.choose_node` the cluster runtime
    calls — byte-for-byte shared scoring."""

    def __init__(self, name: str):
        self.name = name

    def pick(self, sim, fn_name: str):
        nodes = sim.dispatchable_nodes()
        snaps = [sim.node_snapshot(n, fn_name) for n in nodes]
        idx = choose_node(self.name, snaps)
        return nodes[idx], snaps[idx].ro_tier


class PlannedDispatch(DispatchStrategy):
    """Planner-backed dispatch (docs/planner.md): routes to the
    function's planned home via the simulator's
    :class:`~repro.core.placement.control.PlacementControl` — the SAME
    ``PlacementPlanner.pick`` the cluster runtime calls. This strategy
    object serves the re-dispatch path (crash recovery); fresh arrivals
    go through ``Simulator._planned_arrive``, which adds the
    work-stealing board on top of the same pick."""

    name = "planned"

    def pick(self, sim, fn_name: str):
        nodes = sim.dispatchable_nodes()
        snaps = [sim.node_snapshot(n, fn_name) for n in nodes]
        idx, _hit = sim._control.planner.pick(fn_name, snaps)
        return nodes[idx], snaps[idx].ro_tier


_DISPATCH = {"random": RandomDispatch(), "planned": PlannedDispatch()}
_DISPATCH.update({name: SnapshotDispatch(name) for name in DISPATCH_POLICIES
                  if name not in _DISPATCH})


def dispatch_strategy(name: str) -> DispatchStrategy:
    try:
        return _DISPATCH[name]
    except KeyError:
        raise ValueError(
            f"unknown dispatch {name!r}; use one of {DISPATCH_POLICIES}"
        ) from None
