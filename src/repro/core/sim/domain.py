"""Domain layer: simulated GPU nodes, instances, and transfer-leg state
machines (docs/simulator.md).

Everything here is an explicit event handler over plain slotted classes —
the engine fires events, these objects mutate node state and post the next
event. No per-event closures: a multi-leg load is a :class:`_LoadChain`,
a chunked stream drive is a :class:`_StreamDrive`, a queued reservation is
a :class:`PendingReservation` whose expiry rides the event's ``args``.

The modeling contract is unchanged from the pre-kernel simulator (module
docstring of :mod:`repro.core.simulator`): same loader gate, admission
heap, host tier, exit ladders, and fair-share links as the threaded
daemon, golden-trace-guarded in tests/test_sim_golden.py.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.baselines import SystemPolicy
from repro.core.clock import VirtualClock
from repro.core.daemon import SCHEDULERS, AdmissionKey
from repro.core.datapath import DB_BANDWIDTH, PCIE_BANDWIDTH, BandwidthBroker
from repro.core.dispatch import NodeSnapshot
from repro.core.exit_policy import ExitLadder
from repro.core.profiles import MB, FunctionProfile
from repro.core.sim.kernel import EventKind
from repro.core.sim.policies import admission_policy
from repro.core.telemetry import InvocationRecord
from repro.core.transfer import DEFAULT_CHUNK_BYTES, TRANSFER_MODES, LinkArbiter

# invocation-model constants (paper Table 4; shared by every policy path)
GPU_CTX_S = 0.2851
CPU_CTX_S = 0.001
RETURN_S = 0.0001
CONTAINER_S = 2.0


@dataclass
class SimFunction:
    profile: FunctionProfile
    name: str = ""
    # declared SM fraction in (0, 1] for the shared compute plane
    # (docs/compute.md); None = auto, derived from the profiled compute
    # stage. Ignored entirely under compute="exclusive".
    sm_fraction: Optional[float] = None

    def __post_init__(self):
        self.name = self.name or self.profile.name

    @property
    def ro_bytes(self) -> int:
        return int(self.profile.read_only_mb * MB)

    @property
    def w_bytes(self) -> int:
        return int(self.profile.writable_mb * MB)

    @property
    def ctx_bytes(self) -> int:
        return int(self.profile.context_mb * MB)

    @property
    def compute_s(self) -> float:
        return self.profile.compute_ms / 1e3

    def slot_bytes(self, granularity: int) -> int:
        need = self.ctx_bytes + self.ro_bytes + self.w_bytes
        if granularity:
            need = ((need + granularity - 1) // granularity) * granularity
        return need


@dataclass
class SimInstance:
    fn: SimFunction
    ladder: ExitLadder = field(default_factory=ExitLadder)
    busy: bool = False
    dead: bool = False
    has_ctx: bool = False
    ctx_building: bool = False
    # (on_ready, on_fail) pairs: failure of the building invocation's ctx
    # reservation propagates to everyone latched onto it
    ctx_waiters: List[Tuple[Callable, Callable]] = field(default_factory=list)
    has_ro_device: bool = False
    has_ro_host: bool = False
    slot: int = 0


class PendingReservation:
    """One queued device-memory reservation (may carry a failure deadline).
    ``key`` is the :data:`~repro.core.daemon.AdmissionKey` that orders the
    pending heap — the twin of the threaded daemon's waiter heap."""

    __slots__ = ("nbytes", "cont", "on_fail", "expired", "granted", "key",
                 "attempts", "max_retries")

    def __init__(self, nbytes: int, cont: Callable, on_fail: Optional[Callable],
                 key: AdmissionKey, max_retries: Optional[int] = None):
        self.nbytes = nbytes
        self.cont = cont
        self.on_fail = on_fail
        self.expired = False
        self.granted = False
        self.key = key
        # per-request OOM retry budget (twin of the daemon's): the failed
        # reserve() attempt that queued us counts as attempt #1; each failed
        # head admission in kick() is one retry
        self.attempts = 1
        self.max_retries = max_retries


class _StreamDrive:
    """Drives one :class:`~repro.core.transfer.TransferStream` chunk by
    chunk (one full-size advance under ``run_to_completion``). Between
    chunks, if a strictly tighter ``(priority, deadline)`` class waits on
    the loader gate, the stream pauses (completed bytes kept), its resume
    re-queues under its own key, and the freed slot goes to the queue head
    — identical yield semantics to the threaded daemon's ``_drive_stream``.
    """

    __slots__ = ("node", "st", "key", "phase_done")

    def __init__(self, node: "GPUNode", st, key: AdmissionKey,
                 phase_done: Callable):
        self.node = node
        self.st = st
        self.key = key
        self.phase_done = phase_done

    def step(self) -> None:
        node, st = self.node, self.st
        if st.done or st.cancelled:
            self.phase_done()
            return
        if node.daemon_pooled and node.arbiter.should_yield(self.key):
            st.pause(node.clock.now())
            node.arbiter.note_preemption()
            # fresh seq: behind the tighter head, ahead of looser work
            resume_key = (self.key[0], self.key[1], next(node._key_seq))
            heapq.heappush(node._loader_queue, (resume_key, self.resume))
            node.release_loader()
            return
        # ungated (baseline) loads can never yield — the demand signal
        # is the loader gate they do not use — so chunking them would
        # only add events; advance full-size instead
        # per-advance hint: degradation-scaled chunks keep the preemption
        # latency bound when a fault window slows this stream's link
        st.sim_advance(node.arbiter.chunk_hint(st.broker)
                       if node.daemon_pooled else None, self.step)

    def resume(self) -> None:
        self.st.resume(self.node.clock.now())
        self.step()


class _LoadChain:
    """One db->host->device load: the two transfer legs as an explicit
    state machine (``start`` → ``host_loaded`` → ``dev_loaded``).

    Fault hooks (docs/resilience.md): a flapping db (``node.db_down``)
    fails the chain before the db leg moves any bytes; a poisoned load
    fails AFTER the db leg completes — the corrupt fetch consumed its
    full bandwidth share, the same point the threaded daemon poisons.
    Either way ``on_fail(reason)`` runs instead of ``done`` and the
    loader gate is released. A per-arrival ``jitter_s`` (LoaderJitter
    gray failure) delays the db leg while HOLDING the loader slot — a
    jittery loader wedges loader workers, which is exactly the tail
    pathology the slowness detector has to see."""

    __slots__ = ("node", "nbytes", "done", "via_db", "key", "rec",
                 "db_st", "pcie_st", "t_pcie", "gated", "on_fail", "poison",
                 "jitter_s")

    def __init__(self, node: "GPUNode", nbytes: int, done: Callable,
                 via_db: bool, key: AdmissionKey,
                 rec: Optional[InvocationRecord],
                 on_fail: Optional[Callable] = None, poison: bool = False,
                 jitter_s: float = 0.0):
        self.node = node
        self.nbytes = nbytes
        self.done = done
        self.via_db = via_db
        self.key = key
        self.rec = rec
        self.gated = node.daemon_pooled
        self.db_st = node.db.open_stream(nbytes) if via_db else None
        self.pcie_st = node.pcie.open_stream(nbytes)
        self.t_pcie = 0.0
        self.on_fail = on_fail
        self.poison = poison
        self.jitter_s = jitter_s

    def start(self) -> None:
        if self.jitter_s > 0.0 and self.via_db:
            j, self.jitter_s = self.jitter_s, 0.0
            self.node.clock.schedule(j, self.start, kind=EventKind.TRANSFER)
            return
        if self.via_db:
            if self.node.db_down:
                self._fail_leg("db link down")
                return
            self.node._drive(self.db_st, self.key, self.host_loaded)
        else:  # host promotion: PCIe only
            self.host_loaded()

    def host_loaded(self) -> None:
        if self.poison and self.via_db:
            self._fail_leg("injected loader fault")
            return
        self.t_pcie = self.node.clock.now()
        self.node._drive(self.pcie_st, self.key, self.dev_loaded)

    def _fail_leg(self, reason: str) -> None:
        node = self.node
        if self.gated:
            node.release_loader()
        node.load_failures += 1
        if self.on_fail is not None:
            self.on_fail(reason)

    def dev_loaded(self) -> None:
        node, rec = self.node, self.rec
        if rec is not None:
            # actual span, accumulated per record (parallel private
            # legs overlap in time, same additive convention as before)
            rec.stages["gpu_data"] = (rec.stages.get("gpu_data", 0.0)
                                      + node.clock.now() - self.t_pcie)
            for st in (self.db_st, self.pcie_st):
                if st is not None:
                    rec.preemptions += st.preemptions
                    rec.stalled_s += st.stalled_s
        if self.gated:
            node.release_loader()
        if self.via_db:  # completion-counted, like the daemon's stats
            node.loads += 1
            node.bytes_loaded += self.nbytes
        self.done()


class GPUNode:
    """One simulated GPU node (device memory + compute FIFO + data paths).

    Mirrors the threaded daemon's data-plane contract (docs/dataplane.md):
    loads run through a **bounded loader gate** (``loader_threads`` concurrent
    db->PCIe streams, high-water mark in ``max_inflight_loads``), and memory
    reservations given a deadline *fail* past ``load_timeout_s`` instead of
    queueing forever — the failed invocation's record carries ``error``."""

    def __init__(self, policy: SystemPolicy, clock: VirtualClock, *,
                 capacity: int = 40 << 30, host_capacity: int = 125 << 30,
                 exit_ttl: float = 30.0, name: str = "gpu0",
                 loader_threads: int = 4, load_timeout_s: float = 600.0,
                 scheduler: str = "fifo",
                 transfer: str = "run_to_completion",
                 chunk_bytes: int = DEFAULT_CHUNK_BYTES):
        if scheduler not in SCHEDULERS:
            raise ValueError(f"unknown scheduler {scheduler!r}; use one of {SCHEDULERS}")
        if transfer not in TRANSFER_MODES:
            raise ValueError(
                f"unknown transfer mode {transfer!r}; use one of {TRANSFER_MODES}")
        self.policy = policy
        self.clock = clock
        self.capacity = capacity
        self.host_capacity = host_capacity
        self.exit_ttl = exit_ttl
        self.name = name
        self.scheduler = scheduler
        self.used = 0
        # host-tier accounting (twin of the daemon's host admission): bytes
        # resident on host, plus which function's shared-RO host copy is
        # evictable (the refcount-0 HOST entries of the threaded daemon)
        self.host_used = 0
        self.host_resident: Dict[str, int] = {}
        self.host_touch: Dict[str, float] = {}  # last use, for LRU eviction
        self.host_evictions = 0
        self.db = BandwidthBroker(DB_BANDWIDTH, clock, "db", concurrency_penalty=0.06)
        self.pcie = BandwidthBroker(PCIE_BANDWIDTH, clock, "pcie")
        self.compute_free_at = 0.0
        # shared compute plane (docs/compute.md): None = the seed's
        # exclusive compute FIFO above; attached by Simulator.set_compute.
        # ``compute_batches`` holds the per-function OPEN BatchCollector.
        self.compute_plane = None
        self.compute_batches: Dict[str, object] = {}
        self.instances: Dict[str, List[SimInstance]] = {}
        # SAGE shared read-only state per function: tier + waiters
        self.ro_state: Dict[str, str] = {}  # function -> none|loading|device|host
        self.ro_ready_cbs: Dict[str, List[Tuple[Callable, Callable]]] = {}
        self.dgsf_free: Dict[str, int] = {}
        self.dgsf_queue: Dict[str, List[Callable]] = {}
        # memory occupancy: streaming time-weighted accumulator (the
        # pre-kernel list of (t, used) samples held one tuple per memory
        # event — a million-invocation replay would pin millions)
        self._mem_first_t: Optional[float] = None
        self._mem_last_t = 0.0
        self._mem_last_v = 0
        self._mem_acc = 0.0
        # pending device reservations, heap-ordered by AdmissionKey (the
        # twin of the daemon's ordered waiter heap)
        self.pending_mem: List[Tuple[AdmissionKey, PendingReservation]] = []
        self._key_seq = itertools.count()
        # bounded loader gate (twin of daemon.LoaderPool). Only SAGE has the
        # unified memory daemon; baseline platforms (FixedGSL/DGSF) load in
        # per-invocation containers with no shared pool — gating them would
        # cap the very db-path contention Fig 4 measures (paper: 34.9x).
        self.daemon_pooled = policy.name.startswith("sage")
        self.loader_threads = max(1, int(loader_threads))
        self.load_timeout_s = load_timeout_s
        self.inflight_loads = 0
        self.max_inflight_loads = 0
        self._loader_queue: List[Tuple[AdmissionKey, Callable]] = []
        self._kicking = False
        # link arbiter (twin of the daemon's): demand = the tightest job
        # waiting on the loader gate; only the gated (SAGE) path ever
        # yields, exactly like the threaded pool (docs/dataplane.md)
        self.arbiter = LinkArbiter(
            transfer, chunk_bytes,
            demand=lambda: self._loader_queue[0][0] if self._loader_queue
            else None)
        self.load_failures = 0
        # data actually delivered over the db path (twin of the daemon's
        # stats["loads"]/["bytes_loaded"]: counted on completion, host
        # promotions not re-counted — they never touch the db leg)
        self.loads = 0
        self.bytes_loaded = 0
        # fault-injection state (docs/resilience.md) — all defaults keep
        # the no-fault replay bit-identical. ``epoch`` retires deferred
        # completions scheduled before a crash; ``active`` tracks live
        # invocations ONLY when ``fault_tracking`` is set (the set is
        # per-arrival overhead the million-invocation replay must not pay).
        self.healthy = True
        self.epoch = 0
        self.fault_tracking = False
        self.active: set = set()
        self.db_down = False
        self.crashes = 0
        # gray-failure state (docs/resilience.md, "Gray failures"): a
        # SlowNode window multiplies kernel time by ``slow_factor`` (1.0 =
        # exact seed arithmetic — x * 1.0 is bit-identical); a MemoryLeak
        # window creeps ``used`` by ``leaked`` bytes, reclaimed exactly
        # when the window closes or the node tears down.
        self.slow_factor = 1.0
        self.leaked = 0
        # dynamic node pool (docs/planner.md): a draining node takes no
        # new placements; once idle it is retired via the same teardown
        # path a crash uses (exact context/slot/byte release).
        self.draining = False
        self.retired = False

    # ------------------------------------------------------------------
    # fault injection: node crash / restore (docs/resilience.md)
    # ------------------------------------------------------------------
    def _teardown(self) -> list:
        """Release every accounting tier to empty (the PR-7 eviction
        teardown): epoch bump retires every deferred completion/grant
        scheduled before this point (Completion guards on it; the
        brokers' reset retires their stream events), and the returned
        victims are the live invocations the caller must resolve —
        WITHOUT touching this node's (already-zeroed) accounting."""
        self.epoch += 1
        victims = list(self.active)
        self.active.clear()
        self.used = 0
        self._sample_mem()
        self.host_used = 0
        self.host_resident.clear()
        self.host_touch.clear()
        self.instances = {f: [] for f in self.instances}
        self.ro_state = {f: "none" for f in self.ro_state}
        self.ro_ready_cbs = {f: [] for f in self.ro_ready_cbs}
        for _, p in self.pending_mem:
            p.expired = True  # a pending expiry event finds it dead
        self.pending_mem.clear()
        self._loader_queue.clear()
        self.inflight_loads = 0
        self.compute_free_at = 0.0
        if self.compute_plane is not None:
            # every in-flight grant died with the epoch; parked batches
            # are orphaned (their flush events no-op on the epoch guard)
            self.compute_plane.reset()
        self.compute_batches.clear()
        self.dgsf_free = {f: 0 for f in self.dgsf_free}
        self.dgsf_queue = {f: [] for f in self.dgsf_queue}
        self.leaked = 0  # the zeroed accounting reclaims the leak
        self.db.reset()
        self.pcie.reset()
        return victims

    def crash(self) -> None:
        """Kill the node: full teardown, and each live invocation's
        ``on_node_lost`` runs so the control layer can re-dispatch or
        fail it typed."""
        if not self.healthy:
            return
        self.healthy = False
        self.crashes += 1
        for inv in self._teardown():
            inv.on_node_lost()

    def restore(self) -> None:
        """Node rejoins, cold (the crash emptied every tier). DGSF's
        pre-created context pools are re-initialized by the simulator,
        which knows the registered functions."""
        self.healthy = True

    # ------------------------------------------------------------------
    # gray failures: memory leak accounting (docs/resilience.md)
    # ------------------------------------------------------------------
    def leak(self, nbytes: int) -> None:
        """One MemoryLeak tick: ``used`` creeps up with no owner. No
        kick — pressure only ever rises from a leak."""
        self.leaked += nbytes
        self.used += nbytes
        self._sample_mem()

    def reclaim_leak(self) -> None:
        """Window closed (or injector torn down): give the bytes back
        exactly and re-admit whatever the creep was blocking."""
        if not self.leaked:
            return
        freed, self.leaked = self.leaked, 0
        self.release(freed)

    # ------------------------------------------------------------------
    # dynamic node pool: graceful drain (docs/planner.md)
    # ------------------------------------------------------------------
    def is_idle(self) -> bool:
        """No live invocations, parked reservations, or loader work —
        safe to retire. (``active`` is maintained when ``fault_tracking``
        is on; the planner/autoscaler turns it on for every node.)"""
        return (not self.active and not self.pending_mem
                and not self._loader_queue and self.inflight_loads == 0)

    def finalize_drain(self) -> None:
        """Retire a drained node once idle: the SAME teardown a crash
        runs — exact context/slot/byte release, broker reset, epoch bump
        — but graceful: there are no victims to fail."""
        if self.retired:
            return
        assert self.is_idle(), f"finalize_drain on busy node {self.name}"
        victims = self._teardown()
        assert not victims
        self.retired = True

    # ------------------------------------------------------------------
    # SLO-aware admission keys (same formula as daemon._admission_key),
    # via the policy-layer plugin registry (sim/policies.py)
    # ------------------------------------------------------------------
    def admission_key(self, rec: Optional[InvocationRecord] = None) -> AdmissionKey:
        return admission_policy(self.scheduler).key(self, rec)

    # ------------------------------------------------------------------
    # dispatch snapshot (twin of MemoryDaemon.residency/pressure)
    # ------------------------------------------------------------------
    def residency(self, function: str) -> Tuple[str, int]:
        """(best tier, resident bytes) of ``function``'s shared read-only
        data — "device" > "loading" (an in-flight load new arrivals latch
        onto) > "host" > "none", same ranking as the threaded daemon's."""
        st = self.ro_state.get(function, "none")
        if st not in ("device", "loading", "host"):
            return "none", 0
        nbytes = next(
            (i.fn.ro_bytes for i in self.instances.get(function, [])
             if not i.dead),
            self.host_resident.get(function, 0),
        )
        return st, nbytes

    def pending_admission_count(self) -> int:
        """Parked (not yet granted/expired) device-memory waiters — the
        ``pending_admissions`` field of the dispatch snapshot."""
        return sum(1 for _, p in self.pending_mem
                   if not p.expired and not p.granted)

    def loader_queue_depth(self) -> int:
        """Queued + in-flight loads on the loader gate (0 for ungated
        baseline platforms) — the ``loader_queue`` snapshot field."""
        return (len(self._loader_queue) + self.inflight_loads
                if self.daemon_pooled else 0)

    def pressure(self) -> Dict[str, int]:
        return {
            "device_free": max(self.capacity - self.used, 0),
            "device_capacity": self.capacity,
            "pending_admissions": self.pending_admission_count(),
            "loader_queue": self.loader_queue_depth(),
            "loader_threads": self.loader_threads,
        }

    def dispatch_snapshot(self, function: str,
                          health_score: float = 1.0) -> NodeSnapshot:
        tier, ro_bytes = self.residency(function)
        return NodeSnapshot(node_id=self.name, ro_tier=tier,
                            ro_bytes=ro_bytes, healthy=self.healthy,
                            health_score=health_score,
                            compute_free_frac=(
                                self.compute_plane.free_fraction(
                                    self.clock.now())
                                if self.compute_plane is not None else 1.0),
                            **self.pressure())

    # ------------------------------------------------------------------
    # loader gate
    # ------------------------------------------------------------------
    def acquire_loader(self, start: Callable,
                       key: Optional[AdmissionKey] = None) -> None:
        """Run ``start`` when a loader slot frees up (AdmissionKey order
        past the bound — arrival order under "fifo", tightest slack first
        under "edf")."""
        if self.inflight_loads < self.loader_threads:
            self.inflight_loads += 1
            self.max_inflight_loads = max(self.max_inflight_loads, self.inflight_loads)
            start()
        else:
            heapq.heappush(self._loader_queue, (key or self.admission_key(), start))

    def release_loader(self) -> None:
        self.inflight_loads -= 1
        if self._loader_queue:
            _, nxt = heapq.heappop(self._loader_queue)
            self.inflight_loads += 1
            self.max_inflight_loads = max(self.max_inflight_loads, self.inflight_loads)
            nxt()

    def _drive(self, st, key: AdmissionKey, phase_done: Callable) -> None:
        _StreamDrive(self, st, key, phase_done).step()

    def load(self, nbytes: int, done: Callable, *, via_db: bool = True,
             key: Optional[AdmissionKey] = None,
             rec: Optional[InvocationRecord] = None,
             on_fail: Optional[Callable] = None,
             poison: bool = False, jitter_s: float = 0.0) -> None:
        """One db->host->device stream. Under a SAGE daemon it runs on the
        bounded gate and the slot is held across the whole chain, exactly
        like a real loader-pool worker; baseline platforms stream ungated.

        Each leg is a chunked :class:`~repro.core.transfer.TransferStream`;
        with ``rec`` the PCIe leg's **actual** contended (+ preempted) span
        lands in ``rec.stages["gpu_data"]`` and the streams' preemption /
        stall counters roll into ``rec.preemptions`` / ``rec.stalled_s``.

        ``on_fail(reason)`` runs instead of ``done`` when the chain hits
        an injected fault (db flap / ``poison``, docs/resilience.md);
        with ``on_fail=None`` faults cannot reach this load."""
        key = key if key is not None else self.admission_key()
        chain = _LoadChain(self, nbytes, done, via_db, key, rec,
                           on_fail=on_fail, poison=poison, jitter_s=jitter_s)
        if chain.gated:
            self.acquire_loader(chain.start, key)
        else:
            chain.start()

    # ------------------------------------------------------------------
    # host-tier admission (twin of MemoryDaemon._admit_host)
    # ------------------------------------------------------------------
    def reserve_host(self, nbytes: int) -> bool:
        """Admit ``nbytes`` to the host tier; past the ceiling, evict
        idle host-state shared-RO copies (the refcount-0 HOST entries of
        the threaded daemon) LRU-first — same victim order as the
        daemon's ``_admit_host`` — before giving up."""
        if self.host_used + nbytes > self.host_capacity:
            victims = sorted(self.host_resident,
                             key=lambda f: self.host_touch.get(f, 0.0))
            for fname in victims:
                if self.host_used + nbytes <= self.host_capacity:
                    break
                if self.ro_state.get(fname) != "host":
                    continue  # in use on device / mid-promotion: not evictable
                self.host_used -= self.host_resident.pop(fname)
                self.host_touch.pop(fname, None)
                self.ro_state[fname] = "none"
                for inst in self.instances.get(fname, []):
                    inst.has_ro_host = False
                self.host_evictions += 1
        if self.host_used + nbytes > self.host_capacity:
            return False
        self.host_used += nbytes
        return True

    def release_host(self, nbytes: int) -> None:
        self.host_used -= nbytes

    def touch_host(self, fname: str) -> None:
        if fname in self.host_resident:
            self.host_touch[fname] = self.clock.now()

    def drop_host_resident(self, fname: str) -> None:
        """Release the shared-RO host copy accounting for ``fname``."""
        self.release_host(self.host_resident.pop(fname, 0))
        self.host_touch.pop(fname, None)

    # ------------------------------------------------------------------
    def _sample_mem(self):
        """Fold the occupancy level held since the last memory event into
        the streaming time-weighted accumulator (same arithmetic, in the
        same order, as the pre-kernel batch pass over ``mem_samples``)."""
        now = self.clock.now()
        if self._mem_first_t is None:
            self._mem_first_t = now
        else:
            self._mem_acc += self._mem_last_v * (now - self._mem_last_t)
        self._mem_last_t = now
        self._mem_last_v = self.used

    def mean_memory_bytes(self, t_end: float) -> Optional[float]:
        """Time-weighted mean device occupancy over [first sample, t_end];
        ``None`` when no memory event ever fired on this node."""
        if self._mem_first_t is None:
            return None
        acc = self._mem_acc + self._mem_last_v * (t_end - self._mem_last_t)
        return acc / max(t_end - self._mem_first_t, 1e-9)

    def reserve(self, nbytes: int, cont: Callable, *,
                on_fail: Optional[Callable] = None,
                timeout: Optional[float] = None,
                key: Optional[AdmissionKey] = None,
                max_retries: Optional[int] = None) -> None:
        """Reserve device memory; queue (with lazy eviction) if full.

        Queued reservations are served in ``key`` order (:data:`AdmissionKey`
        — arrival order under "fifo", tightest remaining slack first under
        "edf"), mirroring the threaded daemon's ordered waiter heap. With
        ``on_fail``, the queued reservation expires after ``timeout``
        (default ``load_timeout_s``) — the twin of the daemon's OOM-retry
        deadline — and ``on_fail`` runs instead of ``cont``.

        ``max_retries`` is the per-request OOM retry budget (twin of the
        daemon's): ``0`` fails here on the first OOM instead of queueing,
        ``N`` allows N failed head re-admissions in :meth:`kick`, ``None``
        waits out the flat deadline."""
        self._advance_ladders()
        if self.used + nbytes <= self.capacity or self._evict(nbytes - (self.capacity - self.used)):
            self.used += nbytes
            self._sample_mem()
            cont()
            return
        if nbytes > self.capacity and on_fail is not None:
            # impossible request (bigger than the whole device): fail now
            # rather than head-of-line-block the queue until the deadline
            # (twin of the daemon's fast-fail in _reserve_device_blocking)
            self.load_failures += 1
            on_fail()
            return
        if max_retries is not None and max_retries <= 0 and on_fail is not None:
            # retry budget 0: the failed attempt above was the only one
            # allowed — fail-fast typed, exactly like the daemon's head
            # attempt raising with an exhausted budget
            self.load_failures += 1
            on_fail()
            return
        p = PendingReservation(nbytes, cont, on_fail, key or self.admission_key(),
                               max_retries=max_retries)
        heapq.heappush(self.pending_mem, (p.key, p))
        if on_fail is not None:
            t = self.load_timeout_s if timeout is None else timeout
            self.clock.schedule(t, self._expire_pending, p,
                                kind=EventKind.ADMISSION)

    def _expire_pending(self, p: PendingReservation) -> None:
        """Deadline event for a queued reservation (popped lazily by
        :meth:`kick` once expired)."""
        if p.granted or p.expired:
            return
        p.expired = True
        self.load_failures += 1
        p.on_fail()
        self.kick()  # the queue head may have been behind this one

    def release(self, nbytes: int) -> None:
        self.used -= nbytes
        self._sample_mem()
        self.kick()

    def _grant(self, p: PendingReservation) -> None:
        p.granted = True
        self.used += p.nbytes
        self._sample_mem()
        p.cont()

    def kick(self) -> None:
        """Admit pending reservations in AdmissionKey order, evicting idle
        warm instances (Lesson-3) when plain headroom is not enough. A
        blocked head parks; later waiters may only BACKFILL free bytes no
        earlier waiter could use — same semantics as the daemon's ordered
        admission wait."""
        if self._kicking:
            return
        self._kicking = True
        charged = set()  # reservations already charged a retry this kick
        try:
            while self.pending_mem:
                _, p = self.pending_mem[0]
                if p.expired:
                    heapq.heappop(self.pending_mem)
                    continue
                self._advance_ladders()
                if self.used + p.nbytes > self.capacity:
                    self._evict(p.nbytes - (self.capacity - self.used))
                if self.used + p.nbytes <= self.capacity:
                    heapq.heappop(self.pending_mem)
                    self._grant(p)
                    continue
                # failed head admission: ONE retry against the request's
                # budget per kick (= per memory event), however many
                # backfill iterations re-examine the same blocked head —
                # parity with the daemon's counted-wake accounting
                if id(p) not in charged:
                    charged.add(id(p))
                    p.attempts += 1
                    if (p.max_retries is not None and p.on_fail is not None
                            and p.attempts > p.max_retries):
                        heapq.heappop(self.pending_mem)
                        p.expired = True
                        self.load_failures += 1
                        p.on_fail()
                        continue
                # head blocked: backfill the best-keyed waiter that fits
                # WITHOUT eviction (walking in key order, every waiter
                # skipped could not use the free bytes anyway)
                backfilled = None
                for entry in sorted(self.pending_mem)[1:]:
                    q = entry[1]
                    if q.expired:
                        continue
                    if self.used + q.nbytes <= self.capacity:
                        backfilled = entry
                        break
                if backfilled is None:
                    break
                self.pending_mem.remove(backfilled)
                heapq.heapify(self.pending_mem)
                self._grant(backfilled[1])
        finally:
            self._kicking = False

    def _evict(self, need: int) -> bool:
        """Lesson-3: drop idle warm instances (oldest first) to fit."""
        if need <= 0:
            return True
        freed = 0
        for fname, insts in self.instances.items():
            for inst in sorted(insts, key=lambda i: i.ladder.completion_t or 0):
                if inst.busy or inst.dead:
                    continue
                freed += self._destroy(inst)
                if freed >= need:
                    return True
        return freed >= need

    def _destroy(self, inst: SimInstance) -> int:
        freed = 0
        if inst.dead:
            return 0
        inst.dead = True
        if inst.has_ctx:
            freed += inst.fn.ctx_bytes
            inst.has_ctx = False
        if inst.has_ro_device:
            freed += inst.fn.ro_bytes
            inst.has_ro_device = False
            self.ro_state[inst.fn.name] = "none"
        if inst.slot:
            freed += inst.slot
            inst.slot = 0
        # the shared-RO host copy dies with its function's instance
        # (device-resident entries keep a host copy too, like the daemon)
        if inst.has_ro_host and self.ro_state.get(inst.fn.name) == "host":
            self.ro_state[inst.fn.name] = "none"
        if self.ro_state.get(inst.fn.name) == "none":
            self.drop_host_resident(inst.fn.name)
        inst.has_ro_host = False
        self.instances[inst.fn.name].remove(inst)
        if freed:
            self.release(freed)
        return freed

    def _advance_ladders(self) -> None:
        now = self.clock.now()
        for insts in self.instances.values():
            for inst in list(insts):
                if inst.busy or inst.dead:
                    continue
                s = inst.ladder.advance(now)
                if s >= 5:
                    self._destroy(inst)
