"""Domain layer: per-policy invocation state machines.

Each platform's invocation lifecycle (SAGE's parallel ctx/data setup,
FixedGSL's serial chain, DGSF's pre-created-context pool) is one slotted
class whose bound methods are the event handlers — the direct state-machine
transcription of the pre-kernel closure chains, golden-trace-guarded in
tests/test_sim_golden.py.

A SAGE invocation finishes when all four paths (``mem``, ``ctx``, ``ro``,
``win``) have completed; the paths are tracked as a bitmask instead of a
per-invocation dict.
"""
from __future__ import annotations

from typing import Callable, Optional

from repro.core.compute import BatchCollector
from repro.core.sim.domain import (
    CPU_CTX_S, GPU_CTX_S, RETURN_S, GPUNode, SimFunction, SimInstance,
)
from repro.core.sim.kernel import EventKind
from repro.core.telemetry import InvocationRecord

__all__ = ["SageInvocation", "FixedInvocation", "DgsfInvocation",
           "Completion", "CallbackCompletion", "sage_instance"]

# SAGE setup paths still outstanding (bitmask)
_MEM, _CTX, _RO, _WIN = 1, 2, 4, 8
_ALL = _MEM | _CTX | _RO | _WIN


def _schedule_compute(sim, node, fn, rec, done, timing=None):
    """Schedule the compute stage and stamp ``rec.stages["compute"]``.

    Three paths, in priority order: an explicit ``timing=(ready_t, start,
    span)`` from a flushed batch (docs/compute.md); the node's shared
    :class:`~repro.core.compute.ComputePlane` (fractional-slice grant,
    span stretched under contention); or — always, at defaults — the
    seed's exclusive compute FIFO, arithmetic untouched."""
    now = sim.clock.now()
    if timing is not None:
        ready_t, start, span = timing
        rec.stages["compute"] = (start - ready_t) + span
    elif node.compute_plane is not None:
        plane = node.compute_plane
        compute_s = fn.compute_s * node.slow_factor
        k = plane.slices_for(getattr(fn, "sm_fraction", None), fn.compute_s)
        start, span = plane.acquire(now, k, compute_s)
        rec.stages["compute"] = (start - now) + span
    else:
        compute_s = fn.compute_s * node.slow_factor
        start = max(now, node.compute_free_at)
        node.compute_free_at = start + compute_s
        span = compute_s
        rec.stages["compute"] = (start - now) + compute_s
    sim.clock.schedule_at(start + span, done, kind=EventKind.COMPUTE)


def _batch_finish(inv, ready_t, start, span, size, peers):
    """Per-member epilogue of a flushed batch: stamp the batch telemetry
    and hand the member its :class:`Completion` with the shared grant's
    timing — each member keeps its OWN record and byte bookkeeping, so
    cancellation/crash accounting is unchanged."""
    rec = inv.rec
    rec.batch_size = size
    rec.batched_with = peers
    inv._completion = Completion(
        inv.sim, inv.node, inv.fn, rec, inv.inst, inv.release_bytes,
        extra_done=(inv._drop_host if inv.release_bytes else None),
        owner=inv if inv.node.fault_tracking else None,
        timing=(ready_t, start, span))


class Completion:
    """FIFO compute, then return + cleanup (the tail every non-DGSF
    invocation shares): compute queues behind ``node.compute_free_at``,
    ``done`` releases the invocation's private bytes, parks the instance
    back on its exit ladder, and kicks admission.

    The node's ``epoch`` is captured at creation: a completion scheduled
    before a crash no-ops when it fires afterwards (the bytes/instance it
    would touch died with the old epoch — releasing them would corrupt
    the restarted node's accounting). ``owner`` is the invocation to
    deregister from ``node.active`` (fault tracking only).

    ``cancel()`` flags a hedge loser mid-kernel (docs/resilience.md,
    "Gray failures"): the compute span it already claimed elapses, but
    ``_done`` then runs the *cancellation* bookkeeping — the identical
    byte-exact release/instance/kick sequence, with the record marked
    ``dropped``/``hedged`` instead of counted as a completion."""

    __slots__ = ("sim", "node", "fn", "rec", "inst", "release_bytes",
                 "extra_done", "epoch", "owner", "cancelled")

    def __init__(self, sim, node: GPUNode, fn: SimFunction,
                 rec: InvocationRecord, inst: Optional[SimInstance],
                 release_bytes: int, extra_done: Optional[Callable] = None,
                 owner=None, timing=None):
        self.sim = sim
        self.node = node
        self.fn = fn
        self.rec = rec
        self.inst = inst
        self.release_bytes = release_bytes
        self.extra_done = extra_done
        self.epoch = node.epoch
        self.owner = owner
        self.cancelled = False
        _schedule_compute(sim, node, fn, rec, self._done, timing=timing)

    def cancel(self) -> None:
        self.cancelled = True

    def _done(self) -> None:
        sim, node, rec, inst = self.sim, self.node, self.rec, self.inst
        if node.epoch != self.epoch:
            return  # node crashed mid-compute; on_node_lost owned the record
        if self.cancelled:
            # hedge loser, cancelled mid-kernel: exact same resource
            # bookkeeping as a completion, but the record is a dropped
            # "hedged" outcome — never a completion, never a breaker feed
            sim._fail_record(self.fn, rec, "superseded by hedged twin",
                             cls="hedged")
        else:
            rec.stages["return_result"] = RETURN_S
            rec.end_t = sim.clock.now() + RETURN_S
            sim.telemetry.add(rec)
            sim.completed += 1
            sim.inflight -= 1
            if sim.breakers:
                sim._note_result(self.fn.name, True)
        if self.owner is not None:
            node.active.discard(self.owner)
        if self.release_bytes:
            node.release(self.release_bytes)
        if inst is not None:
            inst.busy = False
            inst.ladder.on_complete(sim.clock.now())
        if self.extra_done is not None:
            self.extra_done()
        node.kick()  # an idle warm instance is now evictable
        if not self.cancelled and sim._slowness is not None:
            sim._tail_complete(node, self.fn, rec)
        if sim._has_drains:  # a completion is a drain's quiesce boundary
            sim._try_finalize_drains()


class CallbackCompletion:
    """DGSF variant of :class:`Completion`: the callback releases the data
    bytes and recycles the context slot itself, and there is no exit-ladder
    instance or admission kick. Epoch-guarded like :class:`Completion`."""

    __slots__ = ("sim", "node", "fn", "rec", "cb", "epoch", "owner")

    def __init__(self, sim, node: GPUNode, fn: SimFunction,
                 rec: InvocationRecord, cb: Callable, owner=None):
        self.sim = sim
        self.node = node
        self.fn = fn
        self.rec = rec
        self.cb = cb
        self.epoch = node.epoch
        self.owner = owner
        _schedule_compute(sim, node, fn, rec, self._done)

    def _done(self) -> None:
        sim, rec = self.sim, self.rec
        if self.node.epoch != self.epoch:
            return
        rec.stages["return_result"] = RETURN_S
        rec.end_t = sim.clock.now() + RETURN_S
        sim.telemetry.add(rec)
        sim.completed += 1
        sim.inflight -= 1
        if self.owner is not None:
            self.node.active.discard(self.owner)
        if sim.breakers:
            sim._note_result(self.fn.name, True)
        self.cb()
        if sim._slowness is not None:
            sim._tail_complete(self.node, self.fn, rec)
        if sim._has_drains:  # a completion is a drain's quiesce boundary
            sim._try_finalize_drains()


def sage_instance(sim, node: GPUNode, fn: SimFunction) -> SimInstance:
    """The function's live instance on ``node`` (there is at most one under
    SAGE — shared context/RO), created with its exit-ladder stage hooks on
    first use."""
    insts = node.instances[fn.name]
    for i in insts:
        if not i.dead:
            return i
    inst = SimInstance(fn)
    inst.ladder.ttls = (
        (node.exit_ttl,) * 4 if sim.policy.multi_stage_exit
        else (sim.policy.keep_warm_s, 0.0, 0.0, 0.0)
    )
    inst.ladder.on_enter = {
        2: lambda: sim._sage_demote(node, inst),
        3: lambda: sim._sage_drop_ctx(node, inst),
        4: lambda: sim._sage_drop_host(node, inst),
    }
    insts.append(inst)
    return inst


class SageInvocation:
    """SAGE lifecycle: context and data paths run in PARALLEL (the paper's
    Lesson 1) and the invocation computes once all four complete:

    * ``ctx`` — the instance's shared GPU context (one builder, concurrent
      arrivals latch on);
    * ``mem`` — the invocation's private bytes (writable + private RO under
      no-sharing), ONE atomic device reservation + host admission;
    * ``ro``  — the shared read-only data (device hit / latch onto an
      in-flight load / host promotion / cold db load);
    * ``win`` — the writable input transfer (starts once ``mem`` grants).
    """

    __slots__ = ("sim", "node", "fn", "rec", "inst", "warm", "share",
                 "release_bytes", "_pending", "_failed", "_mem_granted",
                 "_poison", "_jitter", "_completion", "_batch")

    def __init__(self, sim, node: GPUNode, fn: SimFunction,
                 rec: InvocationRecord, injected: bool = False,
                 jitter_s: float = 0.0):
        self.sim = sim
        self.node = node
        self.fn = fn
        self.rec = rec
        self._poison = injected
        self._jitter = jitter_s
        self._completion = None
        self._batch = None
        if node.fault_tracking:
            node.active.add(self)
        node._advance_ladders()
        inst = self.inst = sage_instance(sim, node, fn)
        warm = (inst.ladder.on_reuse(sim.clock.now())
                if inst.ladder.completion_t else None)
        self.warm = warm
        rec.warm_stage = warm
        inst.busy = True
        share = self.share = sim.policy.share_read_only
        self._pending = _ALL
        self._failed = False
        self._mem_granted = False
        # bytes that die with this invocation: writable + private RO (NR
        # mode), reserved ATOMICALLY up front — piecemeal ro-then-writable
        # reservation deadlocks under load (every invocation holds half its
        # memory while waiting for the other half).
        self.release_bytes = fn.w_bytes + (0 if share else fn.ro_bytes)
        self._start_ctx()
        self._start_mem()
        self._start_ro()

    # ------------------------------------------------------------------
    def _fail(self, reason: str, cls: str = "data_load") -> None:
        if self._failed:
            return
        self._failed = True
        if self.node.fault_tracking:
            self.node.active.discard(self)
        self.sim._fail_record(self.fn, self.rec, reason, cls=cls)
        inst = self.inst
        inst.busy = False
        inst.ladder.on_complete(self.sim.clock.now())
        if self._mem_granted and self.release_bytes:
            self.node.release(self.release_bytes)
            self.node.release_host(self.release_bytes)

    def on_node_lost(self) -> None:
        """The node died under this invocation (crash fault). The node's
        accounting is already reset — release NOTHING here; just mark the
        invocation failed and hand the record to the control layer, which
        re-dispatches it (eviction on, budget left) or fails it typed."""
        if self._failed:
            return
        self._failed = True
        self.sim._node_lost(self)

    def _take_poison(self) -> bool:
        """Consume the arrival's injected loader fault: exactly ONE db-leg
        load of this invocation fails (a fully-warm invocation that never
        loads simply outruns the fault)."""
        p = self._poison
        self._poison = False
        return p

    def _take_jitter(self) -> float:
        """Consume the arrival's LoaderJitter draw: exactly ONE private
        load of this invocation pays the extra delay."""
        j, self._jitter = self._jitter, 0.0
        return j

    def hedge_cancel(self) -> None:
        """Cancel this hedge loser (docs/resilience.md, "Gray failures").
        Still in setup/load: the standard failure path rolls back the
        granted device+host bytes exactly and in-flight chains release
        their loader slots as they land. Mid-kernel: the completion is
        flagged and runs the cancellation bookkeeping when it fires.
        Either way the record becomes a dropped "hedged" outcome."""
        if self._failed:
            return
        if self._pending:
            self._fail("superseded by hedged twin", cls="hedged")
        elif self._batch is not None:
            # parked in an open batch (docs/compute.md): leave before the
            # stacked launch — the standard failure path then rolls back
            # the granted device+host bytes exactly, so a cancelled member
            # never leaks device_used
            self._batch.leave(self)
            self._fail("superseded by hedged twin", cls="hedged")
        elif self._completion is not None:
            self._completion.cancel()

    def _path_done(self, bit: int) -> None:
        self._pending &= ~bit
        if self._failed:
            return
        if not self._pending:
            cfg = self.sim._compute
            if (cfg is not None and cfg.max_batch > 1
                    and self.node.compute_plane is not None):
                # same-function batching (docs/compute.md): hand over to
                # the node's open collector instead of computing solo
                coll = self.node.compute_batches.get(self.fn.name)
                if coll is None or coll.closed:
                    coll = BatchCollector(self.sim.clock, self.node,
                                          self.fn, cfg, _batch_finish)
                    self.node.compute_batches[self.fn.name] = coll
                coll.join(self)
                return
            self._completion = Completion(
                self.sim, self.node, self.fn, self.rec, self.inst,
                self.release_bytes,
                # private bytes leave the host tier with the invocation
                # (the daemon drops writable entries at release())
                extra_done=(self._drop_host if self.release_bytes else None),
                owner=self if self.node.fault_tracking else None)

    def _drop_host(self) -> None:
        self.node.release_host(self.release_bytes)

    # ------------------------------------------------------------------
    # context path (parallel with data path). The context is shared per
    # instance: exactly ONE builder reserves+creates; concurrent
    # invocations latch onto it (double-reserving 414 MB per concurrent
    # arrival leaks the device dry under load).
    # ------------------------------------------------------------------
    def _start_ctx(self) -> None:
        inst, rec, node = self.inst, self.rec, self.node
        if inst.has_ctx:
            rec.stages["gpu_ctx"] = 0.0
            self._path_done(_CTX)
        elif inst.ctx_building:
            inst.ctx_waiters.append((self._ctx_ok, self._ctx_late_fail))
        else:
            inst.ctx_building = True
            rec.stages["cpu_ctx"] = CPU_CTX_S
            node.reserve(self.fn.ctx_bytes, self._ctx_start,
                         on_fail=self._ctx_fail,
                         key=node.admission_key(rec),
                         max_retries=rec.max_retries)

    def _ctx_ok(self) -> None:
        self._path_done(_CTX)

    def _ctx_late_fail(self) -> None:
        self._fail("context memory not granted within deadline")

    def _ctx_start(self) -> None:
        # paper-faithful: a dropped GPU context costs a full re-creation
        # (Table 4 stage 3 = 309.5 ms). The beyond-paper
        # ``executable_cache`` policy (TPU: XLA executables are
        # host-cacheable objects, CUDA contexts are not) re-loads the
        # program at ~10% of a compile.
        cost = GPU_CTX_S
        if getattr(self.sim.policy, "executable_cache", False) \
                and self.warm is not None:
            cost = GPU_CTX_S * 0.1
        self.rec.stages["gpu_ctx"] = cost
        self.sim.clock.schedule(CPU_CTX_S + cost, self._ctx_done,
                                kind=EventKind.TIMER)

    def _ctx_done(self) -> None:
        inst = self.inst
        inst.has_ctx = True
        inst.ctx_building = False
        self._path_done(_CTX)
        for ok, _ in inst.ctx_waiters:
            ok()
        inst.ctx_waiters = []

    def _ctx_fail(self) -> None:
        inst = self.inst
        inst.ctx_building = False
        waiters, inst.ctx_waiters = inst.ctx_waiters, []
        self._fail("context memory not granted within deadline")
        for _, fl in waiters:
            fl()

    # ------------------------------------------------------------------
    # the invocation's private bytes, one atomic reservation; data loads
    # start only once the memory is granted. The private bytes transit
    # (and occupy) the host tier for the invocation's lifetime, so host
    # admission happens here too — the twin of the daemon's _admit_host
    # on the db->host leg.
    # ------------------------------------------------------------------
    def _start_mem(self) -> None:
        if self.release_bytes:
            self.node.reserve(
                self.release_bytes, self._mem_granted_cb,
                on_fail=self._mem_fail,
                key=self.node.admission_key(self.rec),
                max_retries=self.rec.max_retries)
        else:
            self._mem_granted_cb()

    def _mem_fail(self) -> None:
        self._fail("working-set memory not granted within deadline")

    def _mem_granted_cb(self) -> None:
        node, fn, rec = self.node, self.fn, self.rec
        if self._failed:
            # another path (ctx/ro) already failed this invocation:
            # hand the late grant straight back
            if self.release_bytes:
                node.release(self.release_bytes)
            return
        if self.release_bytes and not node.reserve_host(self.release_bytes):
            node.release(self.release_bytes)
            node.load_failures += 1
            self._fail("host memory not granted within deadline")
            return
        self._mem_granted = True  # device AND host bytes held
        self._path_done(_MEM)
        if not self.share and fn.ro_bytes:
            self._load_private(fn.ro_bytes, self._ro_ok,
                               key=node.admission_key(rec))
        if fn.w_bytes:
            self._load_private(fn.w_bytes, self._win_ok,
                               key=node.admission_key(rec))
        else:
            self._path_done(_WIN)

    def _ro_ok(self) -> None:
        self._path_done(_RO)

    def _win_ok(self) -> None:
        self._path_done(_WIN)

    def _priv_load_fail(self, reason: str) -> None:
        # private-leg fault: _fail rolls back the granted device+host
        # bytes exactly (the _mem_granted path)
        self._fail(reason)

    def _load_private(self, nbytes: int, done: Callable, *, key) -> None:
        # memory was already granted atomically; the transfer itself runs
        # on the node's bounded loader gate. cpu_data keeps the solo db
        # estimate; gpu_data is recorded by load() as the ACTUAL
        # contended+preempted PCIe span (docs/dataplane.md)
        rec, node = self.rec, self.node
        rec.stages["cpu_data"] = (rec.stages.get("cpu_data", 0.0)
                                  + nbytes / node.db.bw)
        node.load(nbytes, done, key=key, rec=rec,
                  on_fail=self._priv_load_fail, poison=self._take_poison(),
                  jitter_s=self._take_jitter())

    # ------------------------------------------------------------------
    # shared read-only data path
    # ------------------------------------------------------------------
    def _start_ro(self) -> None:
        node, fn, rec, share = self.node, self.fn, self.rec, self.share
        st = node.ro_state[fn.name] if share else "none"
        if not share or fn.ro_bytes == 0:
            if share or not fn.ro_bytes:  # nothing shared to wait for
                self._path_done(_RO)
            # (private RO load is driven from _mem_granted_cb above)
        elif st == "device":
            rec.stages["gpu_data"] = 0.0
            self._path_done(_RO)
        elif st == "loading":
            node.ro_ready_cbs[fn.name].append(
                (self._ro_ok, self._ro_inflight_fail))
        elif st == "host":
            # stage-2 hit: PCIe only (the host copy is already resident
            # and admitted — no new host reservation)
            node.ro_state[fn.name] = "loading"
            node.touch_host(fn.name)
            node.reserve(fn.ro_bytes, self._ro_promote,
                         on_fail=self._ro_host_fail,
                         key=node.admission_key(rec),
                         max_retries=rec.max_retries)
        else:
            node.ro_state[fn.name] = "loading"
            node.reserve(fn.ro_bytes, self._ro_dev_granted,
                         on_fail=self._ro_dev_fail,
                         key=node.admission_key(rec),
                         max_retries=rec.max_retries)
            rec.stages["cpu_data"] = fn.ro_bytes / node.db.bw

    def _ro_inflight_fail(self) -> None:
        self._fail("shared read-only load failed")

    def _ro_promote(self) -> None:
        node, fn, rec = self.node, self.fn, self.rec
        node.load(fn.ro_bytes, self._ro_promoted, via_db=False,
                  key=node.admission_key(rec), rec=rec)

    def _ro_promoted(self) -> None:
        node, fn, inst = self.node, self.fn, self.inst
        node.ro_state[fn.name] = "device"
        inst.has_ro_device = True
        inst.has_ro_host = False
        for ok, _ in node.ro_ready_cbs[fn.name]:
            ok()
        node.ro_ready_cbs[fn.name] = []
        self._path_done(_RO)

    def _ro_host_fail(self) -> None:
        node, fn = self.node, self.fn
        node.ro_state[fn.name] = "host"  # entry keeps its host copy
        cbs, node.ro_ready_cbs[fn.name] = node.ro_ready_cbs[fn.name], []
        self._fail("shared read-only memory not granted within deadline")
        for _, fl in cbs:
            fl()

    def _ro_dev_granted(self) -> None:
        node, fn, rec = self.node, self.fn, self.rec
        # db->host leg needs host admission (daemon._admit_host twin); the
        # host copy then stays resident alongside the device copy until
        # stage 4 drops it
        if not node.reserve_host(fn.ro_bytes):
            node.release(fn.ro_bytes)
            node.load_failures += 1
            self._ro_dev_fail()
            return
        node.host_resident[fn.name] = fn.ro_bytes
        node.touch_host(fn.name)
        node.load(fn.ro_bytes, self._ro_dev_loaded,
                  key=node.admission_key(rec), rec=rec,
                  on_fail=self._ro_load_fail, poison=self._take_poison())

    def _ro_dev_loaded(self) -> None:
        node, fn, inst = self.node, self.fn, self.inst
        node.ro_state[fn.name] = "device"
        inst.has_ro_device = True
        for ok, _ in node.ro_ready_cbs[fn.name]:
            ok()
        node.ro_ready_cbs[fn.name] = []
        self._path_done(_RO)

    def _ro_dev_fail(self) -> None:
        node, fn = self.node, self.fn
        node.ro_state[fn.name] = "none"
        node.drop_host_resident(fn.name)
        cbs, node.ro_ready_cbs[fn.name] = node.ro_ready_cbs[fn.name], []
        self._fail("shared read-only memory not granted within deadline")
        for _, fl in cbs:
            fl()

    def _ro_load_fail(self, reason: str) -> None:
        # cold-load fault AFTER the device grant (unlike _ro_dev_fail,
        # where the grant never happened): hand the ro bytes back first,
        # then tear down exactly like the no-grant path
        node, fn = self.node, self.fn
        node.release(fn.ro_bytes)
        node.ro_state[fn.name] = "none"
        node.drop_host_resident(fn.name)
        cbs, node.ro_ready_cbs[fn.name] = node.ro_ready_cbs[fn.name], []
        self._fail(f"shared read-only load failed: {reason}")
        for _, fl in cbs:
            fl()


class FixedInvocation:
    """FixedGSL lifecycle (paper §3.2.1/§7.1): only the *container* is
    pre-warmed — the coarse-grained platform re-runs every GPU setup stage
    per invocation, serially (cpu_ctx -> gpu_ctx -> db -> pcie -> compute).
    The fixed slot is held while the container instance is warm, capping
    concurrency."""

    __slots__ = ("sim", "node", "fn", "rec", "inst", "total", "_failed",
                 "_poison", "_jitter")

    def __init__(self, sim, node: GPUNode, fn: SimFunction,
                 rec: InvocationRecord, injected: bool = False,
                 jitter_s: float = 0.0):
        self.sim = sim
        self.node = node
        self.fn = fn
        self.rec = rec
        self._failed = False
        self._poison = injected
        self._jitter = jitter_s
        if node.fault_tracking:
            node.active.add(self)
        node._advance_ladders()
        insts = node.instances[fn.name]
        now = sim.clock.now()
        for cand in insts:
            if not cand.busy and not cand.dead \
                    and cand.ladder.stage_at(now) == 1:
                cand.ladder.on_reuse(now)
                cand.busy = True
                rec.warm_stage = 1  # warm *container*: skips slot wait only
                self.inst = cand
                self._setup()
                return
        inst = self.inst = SimInstance(fn)
        inst.busy = True
        inst.ladder.ttls = (sim.policy.keep_warm_s, 0.0, 0.0, 0.0)
        inst.ladder.on_enter = {2: (lambda i=inst: node._destroy(i))}
        insts.append(inst)
        # ctx + data memory live inside the fixed slot (no extra reserve)
        inst.slot = fn.slot_bytes(sim.policy.slot_granularity)
        node.reserve(inst.slot, self._setup, on_fail=self._slot_fail,
                     key=node.admission_key(rec),
                     max_retries=rec.max_retries)

    def on_node_lost(self) -> None:
        if self._failed:
            return
        self._failed = True
        self.sim._node_lost(self)

    def _setup(self) -> None:
        if self._failed:
            return
        rec, fn = self.rec, self.fn
        rec.stages["cpu_ctx"] = CPU_CTX_S
        rec.stages["gpu_ctx"] = GPU_CTX_S
        self.total = fn.ro_bytes + fn.w_bytes
        self.sim.clock.schedule(CPU_CTX_S + GPU_CTX_S, self._load,
                                kind=EventKind.TIMER)

    def _load(self) -> None:
        if self._failed:
            return
        node, rec = self.node, self.rec
        rec.stages["cpu_data"] = self.total / node.db.bw
        poison, self._poison = self._poison, False
        jitter, self._jitter = self._jitter, 0.0
        node.load(self.total, self._loaded, key=node.admission_key(rec),
                  rec=rec, on_fail=self._load_fail, poison=poison,
                  jitter_s=jitter)

    def _loaded(self) -> None:
        if self._failed:
            return
        Completion(self.sim, self.node, self.fn, self.rec, self.inst, 0,
                   owner=self if self.node.fault_tracking else None)

    def _load_fail(self, reason: str) -> None:
        # the container's GPU state is suspect after a failed load: the
        # whole slot dies with the invocation (release via _destroy)
        if self._failed:
            return
        self._failed = True
        if self.node.fault_tracking:
            self.node.active.discard(self)
        self.sim._fail_record(self.fn, self.rec, reason)
        self.inst.busy = False
        self.node._destroy(self.inst)

    def _slot_fail(self) -> None:
        # never got the slot: the instance dies without holding memory
        if self._failed:
            return
        self._failed = True
        if self.node.fault_tracking:
            self.node.active.discard(self)
        inst, insts = self.inst, self.node.instances[self.fn.name]
        slot = inst.slot
        inst.slot = 0
        inst.dead = True
        if inst in insts:
            insts.remove(inst)
        self.sim._fail_record(self.fn, self.rec,
                              f"no {slot}-byte slot within deadline")


class DgsfInvocation:
    """DGSF lifecycle: contexts are pre-created and pooled per function;
    an arrival waits (FCFS) for a free context slot, then loads its data
    and computes. Data bytes and the slot recycle after compute."""

    __slots__ = ("sim", "node", "fn", "rec", "total", "_failed", "_poison",
                 "_jitter")

    def __init__(self, sim, node: GPUNode, fn: SimFunction,
                 rec: InvocationRecord, injected: bool = False,
                 jitter_s: float = 0.0):
        self.sim = sim
        self.node = node
        self.fn = fn
        self.rec = rec
        self._failed = False
        self._poison = injected
        self._jitter = jitter_s
        if node.fault_tracking:
            node.active.add(self)
        if node.dgsf_free[fn.name] > 0:
            node.dgsf_free[fn.name] -= 1
            self._with_ctx()
        else:
            node.dgsf_queue[fn.name].append(self._dequeue)

    def on_node_lost(self) -> None:
        if self._failed:
            return
        self._failed = True
        self.sim._node_lost(self)

    def _dequeue(self) -> None:
        if self._failed:
            return
        self.node.dgsf_free[self.fn.name] -= 1
        self._with_ctx()

    def _with_ctx(self) -> None:
        node, fn, rec = self.node, self.fn, self.rec
        rec.stages["cpu_ctx"] = CPU_CTX_S
        rec.stages["gpu_ctx"] = 0.0  # pre-created
        self.total = fn.ro_bytes + fn.w_bytes
        rec.warm_stage = 1
        rec.stages["cpu_data"] = self.total / node.db.bw
        node.reserve(self.total, self._granted, on_fail=self._data_fail,
                     key=node.admission_key(rec),
                     max_retries=rec.max_retries)

    def _granted(self) -> None:
        if self._failed:
            return
        node, rec = self.node, self.rec
        poison, self._poison = self._poison, False
        jitter, self._jitter = self._jitter, 0.0
        node.load(self.total, self._computed, key=node.admission_key(rec),
                  rec=rec, on_fail=self._load_fail, poison=poison,
                  jitter_s=jitter)

    def _computed(self) -> None:
        if self._failed:
            return
        # release data + ctx slot after compute
        CallbackCompletion(self.sim, self.node, self.fn, self.rec,
                           self._release,
                           owner=self if self.node.fault_tracking else None)

    def _release(self) -> None:
        self.node.release(self.total)
        self._free_ctx_slot()

    def _free_ctx_slot(self) -> None:
        node, fn = self.node, self.fn
        node.dgsf_free[fn.name] += 1
        if node.dgsf_queue[fn.name]:
            node.dgsf_queue[fn.name].pop(0)()

    def _load_fail(self, reason: str) -> None:
        if self._failed:
            return
        self._failed = True
        if self.node.fault_tracking:
            self.node.active.discard(self)
        self.sim._fail_record(self.fn, self.rec, reason)
        self.node.release(self.total)
        self._free_ctx_slot()

    def _data_fail(self) -> None:
        if self._failed:
            return
        self._failed = True
        if self.node.fault_tracking:
            self.node.active.discard(self)
        self.sim._fail_record(self.fn, self.rec,
                              "data memory not granted within deadline")
        self._free_ctx_slot()
