"""Discrete-event simulator core (docs/simulator.md).

Three layers, following the engine/domain/policy split:

* **engine** — :mod:`repro.core.sim.kernel`: a lean, allocation-light
  event kernel (typed :class:`Event` records on a binary heap) plus
  :mod:`repro.core.sim.rng` (named seeded RNG streams). The engine knows
  nothing about GPUs, functions, or serving.
* **domain** — :mod:`repro.core.sim.domain` /
  :mod:`repro.core.sim.invocations`: GPU nodes, instances, transfer-leg
  and invocation state machines as explicit event handlers over plain
  slotted classes (no per-event closure chains).
* **policy** — :mod:`repro.core.sim.policies`: scheduler / dispatch /
  transfer knobs as plugin strategy objects, sharing the scoring and key
  code with the threaded daemon byte-for-byte.

:mod:`repro.core.sim.metrics` holds the streaming telemetry aggregates
(reservoir sample + P² percentile sketches) that let a million-invocation
replay keep O(1) memory.

`repro.core.simulator.Simulator` is the façade the rest of the repo
drives; `repro.core.clock.VirtualClock` is a thin façade over
:class:`EventKernel` so pre-existing callers keep working.
"""
from repro.core.sim.kernel import Event, EventKernel, EventKind
from repro.core.sim.rng import RngStreams

__all__ = ["Event", "EventKernel", "EventKind", "RngStreams"]
