"""Benchmark function profiles (paper Tables 1, 2, 4).

Memory columns are verbatim from Table 2 (MB). Compute times: resnet50 is
exactly Table 4 (24.3 ms); the others are chosen so that compute averages
~7.1% of the FixedGSL end-to-end duration (§3.2.1) with the calibrated
data-path bandwidths — they are modeling constants, recorded here once and
used by both the simulator and the real-runtime function builders.
"""
from __future__ import annotations

from dataclasses import dataclass

MB = 1024 * 1024


@dataclass(frozen=True)
class FunctionProfile:
    name: str
    task_type: str
    context_mb: float      # Table 2: context memory (414 for all)
    read_only_mb: float    # Table 2
    writable_mb: float     # Table 2
    compute_ms: float      # calibrated (resnet50 = Table 4)
    gpu_ctx_ms: float = 285.1  # Table 4 GPU context creation
    cpu_ctx_ms: float = 1.0

    @property
    def explicit_mb(self) -> float:
        return self.read_only_mb + self.writable_mb

    @property
    def read_only_ratio(self) -> float:
        return self.read_only_mb / self.explicit_mb if self.explicit_mb else 0.0


PROFILES = {
    p.name: p
    for p in [
        FunctionProfile("bert", "nlp", 414, 1282.5, 60.1, 28.0),
        FunctionProfile("deepspeech", "speech", 414, 24.8, 6.9, 12.0),
        FunctionProfile("inception3", "vision", 414, 91.1, 11.7, 18.0),
        FunctionProfile("nasnet", "vision", 414, 20.3, 11.8, 22.0),
        FunctionProfile("resnet50", "vision", 414, 97.7, 11.9, 24.3),
        FunctionProfile("seq2seq", "speech", 414, 6.1, 0.1, 6.0),
        FunctionProfile("vgg11", "vision", 414, 506.8, 38.0, 15.0),
        FunctionProfile("lbm", "sci", 414, 0.0, 330.0, 45.0),
        FunctionProfile("mrif", "sci", 414, 0.0, 22.0, 18.0),
        FunctionProfile("tpacf", "sci", 414, 0.1, 28.3, 30.0),
    ]
}

# Table 4 (resnet50) reference latencies, ms — used to validate the
# multistage benchmark against the paper.
TABLE4_RESNET50 = {
    "baseline": {"end_to_end": 399.4, "return": 0.1, "compute": 24.3,
                 "gpu_data": 21.7, "gpu_ctx": 285.1, "cpu_data": 67.2, "cpu_ctx": 1.0},
    "stage1": {"end_to_end": 28.9},
    "stage2": {"end_to_end": 49.7},
    "stage3": {"end_to_end": 309.5},
    "stage4": {"end_to_end": 309.5},
    "cold": {"end_to_end": 310.5},
}
