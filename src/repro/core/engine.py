"""Per-function engine (paper §4.1) and instance model.

The instance model is what separates the systems (§7):

* SAGE        — ONE shared engine per (function, device): concurrent
  invocations share the GPU context (compiled executable) and read-only
  data; lifecycle ends via the multi-stage exit ladder.
* FixedGSL/-F — one *instance* (slot + context + private data) per
  concurrent invocation; idle instances stay warm for ``keep_warm_s``;
  colds pay the full serial setup chain.
* DGSF        — ``pre_created_contexts`` context slots per function (FCFS);
  contexts are never created on the critical path, but every invocation
  loads its own data (no read-only sharing).
"""
from __future__ import annotations

import itertools
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

from repro.core.baselines import SystemPolicy
from repro.core.daemon import (
    GPU_CONTEXT_BYTES, DataLoadError, Handle, MemoryDaemon, NodeLostError,
    OutOfDeviceMemory,
)
from repro.core.exit_policy import ExitLadder
from repro.core.request import Request
from repro.core.shim import TaxonShim
from repro.core.slowness import HedgedError
from repro.core.telemetry import InvocationRecord


@dataclass
class GPUFunction:
    """A registered serverless GPU function."""

    name: str
    handler: Callable[[TaxonShim, Request], Any]
    context_builder: Callable[[], Any]  # expensive: jit compile (gpu_ctx)
    read_only: Dict[str, int] = field(default_factory=dict)  # key -> bytes
    writable_hint: int = 0
    context_bytes: int = GPU_CONTEXT_BYTES
    cpu_ctx_s: float = 0.001      # paper Table 4: ~1 ms
    container_s: float = 2.0      # only paid when containers are not prewarmed
    compute_s_hint: float = 0.0   # simulator profile (real mode measures)
    # declared SM fraction in (0, 1] for the shared compute plane
    # (docs/compute.md); None = auto, derived from compute_s_hint
    sm_fraction: Optional[float] = None

    def total_bytes(self) -> int:
        return self.context_bytes + sum(self.read_only.values()) + self.writable_hint


class Instance:
    """One container+context+private-data unit."""

    # shared across all instances; itertools.count never exhausts and its
    # __next__ is atomic under CPython
    _ids = itertools.count()

    def __init__(self, fn: GPUFunction):
        self.id = next(self._ids)
        self.fn = fn
        self.gpu_ctx: Any = None
        self.cpu_ctx_alive = False
        self.container_alive = False
        self.busy = False
        self.reaping = False  # claimed by a ladder-advance pass
        self.ladder = ExitLadder()
        self.slot_bytes = 0           # FixedGSL slot reservation
        self.private_handles: Dict[str, Handle] = {}  # baseline warm data
        self.dead = False


class FunctionEngine:
    """Engine for one (function, device) pair under a given system policy."""

    def __init__(
        self,
        fn: GPUFunction,
        policy: SystemPolicy,
        daemon: MemoryDaemon,
        executor,
        clock,
        *,
        time_scale: float = 1.0,
        exit_ttl: float = 30.0,
    ):
        self.fn = fn
        self.policy = policy
        self.daemon = daemon
        self.executor = executor
        self.clock = clock
        self.time_scale = time_scale
        self.exit_ttl = exit_ttl
        self._lock = threading.Condition()
        self.instances: List[Instance] = []
        self._dgsf_sem = (
            threading.Semaphore(policy.pre_created_contexts)
            if policy.pre_created_contexts else None
        )
        self._shared_ctx: Any = None  # SAGE / DGSF compiled executable
        self._ctx_build_lock = threading.Lock()
        if policy.pre_created_contexts:
            # DGSF: pre-create contexts at registration (off critical path);
            # memory cost is permanent (the paper's 4 x 414 MB overhead)
            for _ in range(policy.pre_created_contexts):
                self.daemon.reserve_context(fn.context_bytes)
            self._shared_ctx = fn.context_builder()

    # ------------------------------------------------------------------
    # helpers
    # ------------------------------------------------------------------
    def _sleep(self, dt: float) -> None:
        if dt > 0:
            self.clock.sleep(dt * self.time_scale)

    def _advance_ladders(self) -> None:
        # ladder actions call into the daemon (demote/drop/destroy), which
        # takes the daemon lock — and the daemon's eviction path calls back
        # into this engine under *its* lock. Running the actions outside
        # self._lock keeps the two locks strictly ordered (daemon -> engine)
        # and kills the ABBA deadlock the seed runtime could hit under load.
        # Each idle instance is CLAIMED (reaping) under the lock first, so a
        # concurrent invocation cannot grab it mid-action and a second
        # advance pass cannot double-run the stage callbacks.
        now = self.clock.now()
        with self._lock:
            claimed = []
            for inst in self.instances:
                if not inst.busy and not inst.dead and not inst.reaping:
                    inst.reaping = True
                    claimed.append(inst)
        for inst in claimed:
            try:
                s = inst.ladder.advance(now)
                if s >= 5:
                    self._destroy(inst)
            finally:
                with self._lock:
                    inst.reaping = False
                    self._lock.notify_all()

    def _destroy(self, inst: Instance) -> None:
        # claim the instance under the lock (ladder actions run on several
        # threads); the actual releases happen outside it to preserve the
        # daemon -> engine lock ordering
        with self._lock:
            if inst.dead:
                return
            inst.dead = True
            # claim the resources under the same lock (a crash sweep and
            # an in-flight _ensure_ctx may both try to release — exactly
            # one claimant wins, so the accounting rolls back exactly once)
            ctx, inst.gpu_ctx = inst.gpu_ctx, None
            slot, inst.slot_bytes = inst.slot_bytes, 0
            handles, inst.private_handles = inst.private_handles, {}
        if ctx is not None:
            self.daemon.release_context(self.fn.context_bytes)
        if slot:
            self.daemon.release_slot(slot)
        if handles:
            req = Request(function_name=self.fn.name)
            self.daemon.release(req, handles)
        with self._lock:
            if inst in self.instances:
                self.instances.remove(inst)

    def evictable_entries(self):
        self._advance_ladders()
        return self.daemon.evictable_entries(self.fn.name)

    # ------------------------------------------------------------------
    # transfer-scheduling attribution (docs/dataplane.md)
    # ------------------------------------------------------------------
    def _attribute_transfer(self, record: InvocationRecord,
                            handles: Dict[str, Handle]) -> None:
        """Claim the handles' not-yet-attributed preemption/stall totals
        for this record. Claim-once semantics live in the daemon: a pause
        on a shared entry lands on exactly ONE sharer's record, so
        Telemetry totals stay comparable across backends."""
        p, s = self.daemon.claim_transfer_attribution(handles)
        record.preemptions += p
        record.stalled_s += s

    def idle_memory_bytes(self) -> int:
        """Memory pinned by warm-but-idle state (Fig 12 accounting)."""
        total = 0
        with self._lock:
            for inst in self.instances:
                if not inst.busy and not inst.dead:
                    if inst.gpu_ctx is not None:
                        total += self.fn.context_bytes
                    total += inst.slot_bytes
        return total

    # ------------------------------------------------------------------
    # invocation entry point
    # ------------------------------------------------------------------
    def invoke(self, request: Request, record: InvocationRecord) -> Any:
        self._advance_ladders()
        if self.policy.name.startswith("sage"):
            return self._invoke_sage(request, record)
        if self.policy.pre_created_contexts:
            return self._invoke_dgsf(request, record)
        return self._invoke_fixed(request, record)

    # ------------------------------------------------------------------
    # SAGE: parallel setup + sharing + multi-stage exit
    # ------------------------------------------------------------------
    def _sage_instance(self) -> Instance:
        """Claim the shared instance (marking it busy atomically with the
        lookup — a ladder-advance pass mid-claim could otherwise demote or
        destroy it under the invocation's feet)."""
        with self._lock:
            while True:
                inst = next((i for i in self.instances if not i.dead), None)
                if inst is None:
                    break
                if not inst.reaping:
                    inst.busy = True
                    return inst
                self._lock.wait(timeout=0.05)  # advance pass is quick
            inst = Instance(self.fn)
            inst.ladder.ttls = (self.exit_ttl,) * 4  # paper: 30 s per stage
            inst.ladder.on_enter = {
                2: lambda: self.daemon.demote_to_host(self.fn.name),
                3: lambda: self._drop_ctx(inst),
                4: lambda: (self.daemon.drop_host(self.fn.name),
                            setattr(inst, "cpu_ctx_alive", False)),
            }
            inst.busy = True
            self.instances.append(inst)
            return inst

    def _drop_ctx(self, inst: Instance) -> None:
        if inst.gpu_ctx is not None:
            self.daemon.release_context(self.fn.context_bytes)
            inst.gpu_ctx = None

    def _ensure_ctx(self, inst: Instance,
                    request: Optional[Request] = None) -> float:
        """Create the GPU context (compile) if missing; returns seconds.
        The requesting invocation's SLO orders the context-memory admission
        wait under ``scheduler="edf"``."""
        prio, deadline_at = (self.daemon.request_slo(request)
                            if request is not None else (0, None))
        budget = request.max_retries if request is not None else None
        t0 = time.monotonic()
        with self._ctx_build_lock:
            if inst.gpu_ctx is None:
                self.daemon.reserve_context(self.fn.context_bytes,
                                            priority=prio,
                                            deadline_at=deadline_at,
                                            max_retries=budget)
                try:
                    if self._shared_ctx is not None and self.policy.share_context:
                        inst.gpu_ctx = self._shared_ctx  # executable cache hit:
                        # context *memory* must still be re-established, but the
                        # compile is amortized (stage-3 recreate is cheap on TPU
                        # when the executable is cached; we keep the conservative
                        # paper model and rebuild unless shared)
                    else:
                        inst.gpu_ctx = self.fn.context_builder()
                except BaseException:
                    self.daemon.release_context(self.fn.context_bytes)
                    raise
                if self.policy.share_context:
                    self._shared_ctx = inst.gpu_ctx
        if inst.dead or self.daemon.dead:
            # the node crashed while the context was building: the crash
            # sweep saw gpu_ctx=None and could not release it, so this
            # thread still owns the reservation — claim-and-release here
            # (same lock as _destroy, so exactly one side wins)
            with self._lock:
                ctx, inst.gpu_ctx = inst.gpu_ctx, None
            if ctx is not None:
                self.daemon.release_context(self.fn.context_bytes)
            raise NodeLostError(self.fn.name,
                                self.daemon.dead_reason or "node crashed")
        return time.monotonic() - t0

    def _hedge_check(self, request: Request) -> None:
        """Cooperative hedge-cancel checkpoint (docs/resilience.md): a
        loser aborts here and unwinds through the same finally chain as a
        failure, so handles/slots/contexts release byte-exactly."""
        ev = request.hedge_cancel
        if ev is not None and ev.is_set():
            raise HedgedError(f"{self.fn.name}: superseded by hedged twin")

    def _invoke_sage(self, request: Request, record: InvocationRecord) -> Any:
        # a loser already cancelled before it started must start nothing:
        # checked before the instance claim so no slot, load, or context
        # is ever touched and the books stay exactly zero
        self._hedge_check(request)
        inst = self._sage_instance()  # returned already claimed (busy=True)
        now = self.clock.now()
        with self._lock:
            warm = inst.ladder.on_reuse(now) if inst.ladder.completion_t else None
        record.warm_stage = warm
        record.stages["container_create"] = (
            0.0 if (self.policy.prewarmed_container or inst.container_alive)
            else self.fn.container_s
        )
        self._sleep(record.stages["container_create"])
        inst.container_alive = True
        if not inst.cpu_ctx_alive:
            record.stages["cpu_ctx"] = self.fn.cpu_ctx_s
            self._sleep(self.fn.cpu_ctx_s)
            inst.cpu_ctx_alive = True
        else:
            record.stages["cpu_ctx"] = 0.0

        # --- the parallelized setup: daemon loads while we build the ctx.
        # On any failure (DataLoadError from a handle, OOM on the context)
        # the finally block still releases the handles — which cancels any
        # still-loading writable entries — and frees the instance, so a
        # failed invocation neither leaks accounting nor wedges the engine.
        t_par0 = time.monotonic()
        handles = self.daemon.prepare(
            request, system_shares_ro=self.policy.share_read_only
        )
        try:
            self._hedge_check(request)  # before the expensive compile...
            ctx_s = self._ensure_ctx(inst, request)
            record.stages["gpu_ctx"] = ctx_s
            self._hedge_check(request)  # ...and before the kernel launches
            # compute launches resolve handles; wait = data not hidden by ctx
            result, data_wait = self._run_handler(inst, request, handles, record)
            record.stages["gpu_data"] = data_wait
            record.stages["cpu_data"] = 0.0  # folded into daemon pipeline (async)
            record.setup_wall = time.monotonic() - t_par0 - record.stages.get("compute", 0.0)
            return result
        finally:
            self._attribute_transfer(record, handles)
            self.daemon.release(request, handles)
            with self._lock:
                inst.busy = False
                inst.ladder.on_complete(self.clock.now())

    # ------------------------------------------------------------------
    # FixedGSL / FixedGSL-F: serial setup, per-invocation instances
    # ------------------------------------------------------------------
    def _acquire_instance(self, record: InvocationRecord) -> Instance:
        with self._lock:
            for inst in self.instances:
                if not inst.busy and not inst.dead and not inst.reaping \
                        and inst.ladder.stage_at(self.clock.now()) == 1:
                    inst.busy = True
                    inst.ladder.on_reuse(self.clock.now())
                    record.warm_stage = 1
                    return inst
            inst = Instance(self.fn)
            inst.busy = True
            self.instances.append(inst)
            return inst

    def _slot_bytes(self) -> int:
        need = self.fn.total_bytes()
        g = self.policy.slot_granularity
        if g:
            need = ((need + g - 1) // g) * g
        return need

    def _invoke_fixed(self, request: Request, record: InvocationRecord) -> Any:
        inst = self._acquire_instance(record)
        warm = record.warm_stage == 1
        try:
            if not warm:
                # admission: reserve the (rounded) slot; the daemon blocks
                # with backpressure and raises past its deadline instead of
                # spinning forever on OOM
                need = self._slot_bytes()
                prio, deadline_at = self.daemon.request_slo(request)
                try:
                    self.daemon.reserve_slot(need, priority=prio,
                                             deadline_at=deadline_at,
                                             max_retries=request.max_retries)
                except OutOfDeviceMemory as oom:
                    raise DataLoadError(
                        f"{self.fn.name}/slot",
                        f"no {need}-byte slot within deadline", oom,
                    ) from oom
                inst.slot_bytes = need
                record.stages["container_create"] = (
                    0.0 if self.policy.prewarmed_container else self.fn.container_s
                )
                self._sleep(record.stages["container_create"])
                inst.container_alive = True
                record.stages["cpu_ctx"] = self.fn.cpu_ctx_s
                self._sleep(self.fn.cpu_ctx_s)
                inst.cpu_ctx_alive = True
                # serial: ctx FIRST (implicit creation), then data
                t0 = time.monotonic()
                self.daemon.reserve_context(self.fn.context_bytes,
                                            priority=prio,
                                            deadline_at=deadline_at,
                                            max_retries=request.max_retries)
                try:
                    inst.gpu_ctx = self.fn.context_builder()
                except BaseException:
                    self.daemon.release_context(self.fn.context_bytes)
                    raise
                record.stages["gpu_ctx"] = time.monotonic() - t0
                t0 = time.monotonic()
                handles = self.daemon.prepare(request, system_shares_ro=False)
                inst.private_handles = handles
                for h in handles.values():  # serial wait: db->host->device
                    h.wait()
                record.stages["cpu_data"] = 0.0
                record.stages["gpu_data"] = time.monotonic() - t0
                self._attribute_transfer(record, handles)
            else:
                handles = inst.private_handles
                for s in ("container_create", "cpu_ctx", "gpu_ctx", "cpu_data", "gpu_data"):
                    record.stages[s] = 0.0
            result, _ = self._run_handler(inst, request, dict(handles), record)
            return result
        except Exception:
            # failed setup or compute: tear the instance down (releases the
            # slot, context, and private handles — cancelling in-flight
            # loads) rather than leaving a half-built warm instance around
            self._destroy(inst)
            raise
        finally:
            with self._lock:
                inst.busy = False
                inst.ladder.ttls = (self.policy.keep_warm_s, 0.0, 0.0, 0.0)
                inst.ladder.on_enter = {k: (lambda i=inst: self._destroy(i)) for k in (2,)}
                inst.ladder.on_complete(self.clock.now())

    # ------------------------------------------------------------------
    # DGSF: pre-created contexts, FCFS, no read-only sharing
    # ------------------------------------------------------------------
    def _invoke_dgsf(self, request: Request, record: InvocationRecord) -> Any:
        self._dgsf_sem.acquire()  # FCFS over the 4 contexts
        try:
            record.stages["container_create"] = 0.0
            record.stages["cpu_ctx"] = self.fn.cpu_ctx_s
            self._sleep(self.fn.cpu_ctx_s)
            record.stages["gpu_ctx"] = 0.0  # pre-created
            t0 = time.monotonic()
            handles = self.daemon.prepare(request, system_shares_ro=False)
            try:
                for h in handles.values():
                    h.wait()
                record.stages["cpu_data"] = 0.0
                record.stages["gpu_data"] = time.monotonic() - t0
                self._attribute_transfer(record, handles)
                record.warm_stage = 1
                inst = Instance(self.fn)
                inst.gpu_ctx = self._shared_ctx
                result, _ = self._run_handler(inst, request, handles, record)
                return result
            finally:
                # release on every path: a DataLoadError mid-wait must still
                # drop/cancel this invocation's private entries
                self.daemon.release(request, handles)
        finally:
            self._dgsf_sem.release()

    # ------------------------------------------------------------------
    def _run_handler(self, inst: Instance, request: Request, handles, record=None):
        """Run the user handler through the taxon shim; returns
        (result, data_wait_seconds). ``record`` gets compute/return stages."""
        shim = TaxonShim(self.daemon, self.executor, request, handles)
        shim.gpu_ctx = inst.gpu_ctx
        w0 = self.executor.wait_time
        t0 = time.monotonic()
        result = self.fn.handler(shim, request)
        wall = time.monotonic() - t0
        data_wait = self.executor.wait_time - w0
        if record is not None:
            record.stages["compute"] = max(wall - data_wait, 0.0)
            record.stages["return_result"] = 0.0001
            # batch attribution stamped on the request by the compute
            # plane's collector (docs/compute.md); defaults when off
            record.batch_size = getattr(request, "batch_size", 1)
            record.batched_with = getattr(request, "batched_with", ())
        return result, data_wait
