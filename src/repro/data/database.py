"""In-memory 'database' (the paper uses MongoDB) with brokered fetch timing.

Values are real Python/JAX objects (reduced-model weight pytrees, inputs);
fetch latency is modeled through the shared db bandwidth broker using the
*declared* A100-scale size, so contention behaves like the paper's Fig 4
while payloads stay CPU-sized.
"""
from __future__ import annotations

import threading
from typing import Any, Dict, Optional

import jax


class Database:
    def __init__(self):
        self._lock = threading.Lock()
        self._kv: Dict[str, Any] = {}
        self._sizes: Dict[str, int] = {}

    def put(self, key: str, value: Any, size: int = 0) -> None:
        with self._lock:
            self._kv[key] = value
            self._sizes[key] = size

    def size_of(self, key: str) -> int:
        return self._sizes.get(key, 0)

    def fetch(self, key: str, broker=None, *, scale: float = 1.0) -> Any:
        if broker is not None:
            broker.transfer(self._sizes.get(key, 0), scale=scale)
        with self._lock:
            return self._kv.get(key)

    def to_device(self, obj: Any) -> Any:
        """Host -> device materialization (jax.device_put for pytrees)."""
        if obj is None:
            return None
        try:
            return jax.device_put(obj)
        except TypeError:
            return obj
