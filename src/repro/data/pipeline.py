"""Deterministic synthetic token pipeline with exact-resume semantics.

Every batch is a pure function of (seed, step), so resuming from a
checkpoint at step N reproduces the identical data stream on any number of
hosts — no iterator state to snapshot, no skew after elastic rescale. Each
host materializes only its shard of the global batch.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    global_batch: int
    seq_len: int
    seed: int = 0
    # synthetic structure: orderly enough that loss visibly decreases
    ngram_order: int = 2


class TokenPipeline:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        # a fixed random bigram table gives learnable structure
        rng = np.random.default_rng(cfg.seed)
        self._trans = rng.integers(
            0, cfg.vocab_size, size=(cfg.vocab_size,), dtype=np.int64
        )

    def batch_at(self, step: int, *, host_id: int = 0, num_hosts: int = 1) -> Dict[str, np.ndarray]:
        """The (host-sharded) batch for ``step`` — pure function of inputs."""
        cfg = self.cfg
        per_host = cfg.global_batch // num_hosts
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, host_id])
        )
        first = rng.integers(0, cfg.vocab_size, size=(per_host, 1), dtype=np.int64)
        toks = np.empty((per_host, cfg.seq_len), dtype=np.int64)
        toks[:, 0] = first[:, 0]
        noise = rng.random((per_host, cfg.seq_len)) < 0.15
        rand = rng.integers(0, cfg.vocab_size, size=(per_host, cfg.seq_len))
        for t in range(1, cfg.seq_len):
            nxt = self._trans[toks[:, t - 1]]
            toks[:, t] = np.where(noise[:, t], rand[:, t], nxt)
        return {
            "tokens": toks.astype(np.int32),
            "loss_mask": np.ones((per_host, cfg.seq_len), np.float32),
        }

    def iterate(self, start_step: int = 0, *, host_id: int = 0,
                num_hosts: int = 1) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch_at(step, host_id=host_id, num_hosts=num_hosts)
            step += 1
