"""Next-token cross-entropy with z-loss and MoE aux-loss folding."""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import forward


def lm_loss(
    cfg: ModelConfig,
    params,
    batch: Dict[str, jax.Array],
    *,
    z_loss: float = 1e-4,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Mean next-token CE. ``batch['tokens']`` (B,S); optional
    ``batch['loss_mask']`` (B,S) — position i masks prediction OF token i."""
    logits, aux = forward(cfg, params, batch)  # (B,S,V) fp32
    tokens = batch["tokens"]
    targets = tokens[:, 1:]
    logits = logits[:, :-1]
    mask = batch.get("loss_mask")
    mask = jnp.ones_like(targets, jnp.float32) if mask is None else mask[:, 1:].astype(jnp.float32)

    lse = jax.nn.logsumexp(logits, axis=-1)  # (B,S-1)
    tgt_logit = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    ce = lse - tgt_logit
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = (ce * mask).sum() / denom
    zl = z_loss * ((lse**2) * mask).sum() / denom
    total = loss + zl + cfg.router_aux_loss * aux
    metrics = {
        "loss": loss,
        "z_loss": zl,
        "aux_loss": aux,
        "total_loss": total,
        "tokens": denom,
    }
    return total, metrics
