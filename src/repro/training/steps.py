"""Train-step factories.

``make_train_step`` builds the GSPMD step (FSDP+TP via rules.py; optional
microbatch gradient accumulation via scan, fp32 accumulators).

``make_dp_compressed_step`` builds a shard_map data-parallel step with int8
error-feedback gradient all-reduce (the cross-pod/DCN path optimization) for
replicated-parameter runs — used by the 100M training example and validated
against the uncompressed step in tests.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.compat import shard_map as compat_shard_map
from repro.training import compression
from repro.training.loss import lm_loss
from repro.training.optimizer import OptimizerConfig, adamw_init, adamw_update

TrainState = Dict[str, Any]


def init_train_state(cfg: ModelConfig, opt_cfg: OptimizerConfig, key) -> TrainState:
    from repro.models import init_params

    params = init_params(cfg, key)
    return {"params": params, "opt": adamw_init(opt_cfg, params)}


def _tree_cast(tree, dt):
    return jax.tree_util.tree_map(lambda x: x.astype(dt), tree)


def _tree_add(a, b):
    return jax.tree_util.tree_map(jnp.add, a, b)


def make_train_step(
    cfg: ModelConfig,
    opt_cfg: OptimizerConfig,
    *,
    microbatches: int = 1,
) -> Callable[[TrainState, Dict[str, jax.Array]], Tuple[TrainState, Dict]]:
    """GSPMD train step: loss -> grads (fp32 accum) -> AdamW."""

    def loss_fn(params, batch):
        return lm_loss(cfg, params, batch)

    def train_step(state: TrainState, batch: Dict[str, jax.Array]):
        params = state["params"]
        if microbatches == 1:
            (_, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
            grads = _tree_cast(grads, jnp.float32)
        else:
            B = batch["tokens"].shape[0]

            def split(x):
                """Split the batch-sized axis (axis 0 for tokens/masks;
                axis 1 for (3, B, S) M-RoPE position ids) into
                (microbatches, B/m)."""
                ax = 0 if x.shape[0] == B else next(
                    i for i, d in enumerate(x.shape) if d == B
                )
                shape = (x.shape[:ax] + (microbatches, B // microbatches)
                         + x.shape[ax + 1:])
                return jnp.moveaxis(x.reshape(shape), ax, 0)

            mb = jax.tree_util.tree_map(split, batch)

            def mb_step(carry, mbatch):
                gsum, msum = carry
                (_, met), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mbatch)
                gsum = _tree_add(gsum, _tree_cast(g, jnp.float32))
                msum = _tree_add(msum, {k: v for k, v in met.items()})
                return (gsum, msum), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            m0 = {
                "loss": jnp.zeros(()), "z_loss": jnp.zeros(()),
                "aux_loss": jnp.zeros(()), "total_loss": jnp.zeros(()),
                "tokens": jnp.zeros(()),
            }
            (gsum, msum), _ = jax.lax.scan(mb_step, (g0, m0), mb)
            grads = jax.tree_util.tree_map(lambda g: g / microbatches, gsum)
            metrics = {k: v / microbatches for k, v in msum.items()}
            metrics["tokens"] = msum["tokens"]

        new_params, new_opt, opt_metrics = adamw_update(
            opt_cfg, grads, state["opt"], params
        )
        metrics.update(opt_metrics)
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


# ---------------------------------------------------------------------------
# DP + int8-compressed gradient all-reduce (shard_map, replicated params)
# ---------------------------------------------------------------------------


def init_dp_state(cfg: ModelConfig, opt_cfg: OptimizerConfig, key) -> TrainState:
    state = init_train_state(cfg, opt_cfg, key)
    state["residuals"] = compression.init_residuals(state["params"])
    return state


def make_dp_compressed_step(
    cfg: ModelConfig,
    opt_cfg: OptimizerConfig,
    mesh,
    *,
    compress: bool = True,
):
    """Data-parallel step over every mesh axis: params replicated, batch
    sharded on axis 0, gradients all-reduced in int8 with error feedback."""
    from jax.sharding import PartitionSpec as P

    axes = tuple(mesh.axis_names)

    def step(state, batch):
        def inner(state, batch):
            params = state["params"]
            (_, metrics), grads = jax.value_and_grad(
                lambda p, b: lm_loss(cfg, p, b), has_aux=True
            )(params, batch)
            grads = _tree_cast(grads, jnp.float32)
            if compress:
                grads, new_res = compression.compress_allreduce(
                    grads, state["residuals"], axes
                )
            else:
                grads = jax.tree_util.tree_map(
                    lambda g: jax.lax.pmean(g, axes), grads
                )
                new_res = state["residuals"]
            metrics = jax.tree_util.tree_map(lambda m: jax.lax.pmean(m, axes), metrics)
            new_params, new_opt, om = adamw_update(opt_cfg, grads, state["opt"], params)
            metrics.update(om)
            return {"params": new_params, "opt": new_opt, "residuals": new_res}, metrics

        return compat_shard_map(
            inner,
            mesh=mesh,
            in_specs=(P(), P(axes)),  # params replicated; batch row-sharded
            out_specs=(P(), P()),
        )(state, batch)

    return step
