"""AdamW with configurable state dtype (bf16 state for the 400B-class archs
so optimizer state fits v5e HBM — see EXPERIMENTS.md §Dry-run), global-norm
clipping, and cosine LR schedule. No optax dependency — pure pytree math.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    betas: Tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: str = "float32"  # 'bfloat16' for 400B-class archs
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_at(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0
    )
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(math.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def adamw_init(cfg: OptimizerConfig, params) -> Dict[str, Any]:
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def adamw_update(
    cfg: OptimizerConfig, grads, opt_state, params
) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    b1, b2 = cfg.betas
    dt = jnp.dtype(cfg.state_dtype)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9)) if cfg.clip_norm else 1.0
    lr = lr_at(cfg, step)
    # bias correction in fp32
    c1 = 1 - b1 ** step.astype(jnp.float32)
    c2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        mhat = m32 / c1
        vhat = v32 / c2
        step_ = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # no decay on norms/biases/scalars
            step_ = step_ + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * step_
        return newp.astype(p.dtype), m32.astype(dt), v32.astype(dt)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
