"""Int8 error-feedback gradient compression for the data-parallel all-reduce.

At 1000+ node scale the DP gradient reduction crosses DCN (between pods) where
bandwidth is ~10x scarcer than ICI. Quantizing gradients to int8 with an
error-feedback residual (Seide et al. 1-bit SGD lineage; here 8-bit with
per-tensor scale) cuts cross-pod reduction bytes 2x vs bf16 / 4x vs fp32 with
negligible convergence impact, because the quantization error is re-injected
into the next step's gradient instead of being dropped.

Used by ``train_step`` in ``dp_compress`` mode (see ``steps.py``): gradients
are quantized per-shard, all-reduced in int32 (sum of int8 fits easily for
<=2^23 replicas), dequantized, and the residual is carried in the optimizer
state. Pure functions; unit + property tested in tests/test_compression.py.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array, residual: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Quantize (x + residual) to int8 with a per-tensor scale.

    Returns (q int8, scale fp32 scalar, new_residual fp32).
    """
    xf = x.astype(jnp.float32) + residual
    amax = jnp.max(jnp.abs(xf))
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    new_residual = xf - q.astype(jnp.float32) * scale
    return q, scale, new_residual


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_residuals(params) -> Any:
    return jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_allreduce(grads, residuals, axis_names) -> Tuple[Any, Any]:
    """Error-feedback int8 all-reduce over ``axis_names`` (inside shard_map).

    Each replica quantizes (grad + residual) locally, the int8 payloads are
    summed with ``lax.psum`` (int32 accumulation), and scales are meaned.
    Returns (reduced fp32 grads, new residuals).
    """
    n = jax.lax.psum(1, axis_names)

    def one(g, r):
        xf = g.astype(jnp.float32) + r
        # one shared scale across replicas (a cheap scalar pmax) so the int8
        # payloads are summable exactly
        amax = jax.lax.pmax(jnp.max(jnp.abs(xf)), axis_names)
        scale = jnp.maximum(amax / 127.0, 1e-12)
        q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
        new_r = xf - q.astype(jnp.float32) * scale  # error feedback
        qsum = jax.lax.psum(q.astype(jnp.int32), axis_names)
        return dequantize(qsum, scale) / n, new_r

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_r = tdef.flatten_up_to(residuals)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return tdef.unflatten([o[0] for o in out]), tdef.unflatten([o[1] for o in out])
