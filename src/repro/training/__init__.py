from repro.training.loss import lm_loss  # noqa: F401
from repro.training.optimizer import OptimizerConfig, adamw_init, adamw_update, lr_at  # noqa: F401
from repro.training.steps import (  # noqa: F401
    init_dp_state,
    init_train_state,
    make_dp_compressed_step,
    make_train_step,
)
