"""olmoe-1b-7b — MoE (16L, d=2048, 16H MHA, 64 experts top-8, d_ff=1024/expert).

Every layer is MoE (moe_every=1); 1B active / 7B total. [arXiv:2409.02060; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,  # MHA
    head_dim=128,
    d_ff=1024,
    vocab_size=50304,
    num_experts=64,
    experts_per_token=8,
    moe_every=1,
    expert_d_ff=1024,
    qk_norm=True,  # OLMoE uses QK-norm
    rope_theta=10_000.0,
    subquadratic=False,
    source="arXiv:2409.02060; hf:allenai/OLMoE-1B-7B-0924",
)
