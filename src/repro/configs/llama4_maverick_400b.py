"""llama4-maverick-400b-a17b — MoE (48L, d=5120, 40H GQA kv=8, 128e top-1).

Maverick alternates dense and MoE FFN layers (interleave step 2) and adds a
shared expert alongside the single routed expert — that is what makes 400B
total / 17B active parameters with top-1 routing. Early-fusion multimodality
is out of scope for the LM backbone (text path only). [hf; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    num_experts=128,
    experts_per_token=1,
    moe_every=2,  # MoE on every 2nd layer (interleave_moe_layer_step=2)
    moe_shared_expert=True,
    expert_d_ff=8192,
    rope_theta=500_000.0,
    param_dtype="bfloat16",
    subquadratic=False,
    source="hf:meta-llama/Llama-4-Maverick-17B-128E; unverified",
)
