"""qwen2-vl-72b — VLM backbone (80L, d=8192, 64H GQA kv=8, d_ff=29568).

M-RoPE (3-section rotary over temporal/height/width position ids), dynamic
resolution handled by the (stubbed) vision frontend: ``input_specs`` feeds
token ids plus precomputed M-RoPE position ids ``(3, B, S)``. The backbone is
a standard pre-norm GQA transformer. [arXiv:2409.12191; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152064,
    qkv_bias=True,  # qwen2 family uses QKV bias
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),  # halves of head_dim: t/h/w
    tie_embeddings=False,
    subquadratic=False,  # full attention -> long_500k skipped
    source="arXiv:2409.12191; hf:Qwen/Qwen2-VL-72B-Instruct",
)
