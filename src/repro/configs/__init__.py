"""Architecture registry: ``--arch <id>`` resolves through here."""
from __future__ import annotations

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig, shape_applicable
from repro.configs.jamba_1_5_large import CONFIG as JAMBA_1_5_LARGE
from repro.configs.llama4_maverick_400b import CONFIG as LLAMA4_MAVERICK
from repro.configs.mamba2_780m import CONFIG as MAMBA2_780M
from repro.configs.olmoe_1b_7b import CONFIG as OLMOE_1B_7B
from repro.configs.phi4_mini_3_8b import CONFIG as PHI4_MINI
from repro.configs.qwen2_5_3b import CONFIG as QWEN2_5_3B
from repro.configs.qwen2_vl_72b import CONFIG as QWEN2_VL_72B
from repro.configs.qwen3_32b import CONFIG as QWEN3_32B
from repro.configs.qwen3_8b import CONFIG as QWEN3_8B
from repro.configs.whisper_small import CONFIG as WHISPER_SMALL

ARCHS = {
    c.name: c
    for c in [
        QWEN2_VL_72B,
        MAMBA2_780M,
        OLMOE_1B_7B,
        LLAMA4_MAVERICK,
        JAMBA_1_5_LARGE,
        QWEN3_32B,
        QWEN2_5_3B,
        QWEN3_8B,
        PHI4_MINI,
        WHISPER_SMALL,
    ]
}


def get_arch(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}")
    return SHAPES[name]


__all__ = [
    "ARCHS",
    "SHAPES",
    "ModelConfig",
    "ShapeConfig",
    "get_arch",
    "get_shape",
    "shape_applicable",
]
