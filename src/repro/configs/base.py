"""Model configuration system.

Every assigned architecture is expressed as a ``ModelConfig``. Layer stacks
are described as *periods*: the smallest repeating pattern of sublayers
(mixer + ffn choices). Homogeneous models have period length 1; jamba has
period length 8 (1 attention + 7 mamba, MoE on every 2nd layer); llama4 has
period length 2 (dense / MoE alternation). The model code scans over periods
so the HLO stays compact regardless of depth.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Sublayer descriptors
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SublayerSpec:
    """One sublayer inside a period."""

    mixer: str  # 'attn' | 'mamba'
    ffn: str  # 'dense' | 'moe' | 'none'


@dataclass(frozen=True)
class ModelConfig:
    # identity
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    # core dims
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    # attention options
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 1_000_000.0
    mrope_sections: Tuple[int, ...] = ()  # vlm M-RoPE (t, h, w) half-dim split
    causal: bool = True
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_every: int = 1  # MoE ffn on layers where (i % moe_every == moe_every-1)
    moe_shared_expert: bool = False
    expert_d_ff: int = 0  # 0 -> d_ff
    moe_capacity_factor: float = 1.25
    router_aux_loss: float = 0.01
    # SSM (mamba2)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 128
    attn_every: int = 0  # hybrid: attn mixer on layers where (i % attn_every == 0)
    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_causal: bool = False
    # norm / embeddings
    rmsnorm_eps: float = 1e-6
    tie_embeddings: bool = False
    # numerics
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    # training-time layout knobs (hillclimbed per arch; see EXPERIMENTS.md §Perf)
    remat_policy: str = "nothing_saveable"  # 'none'|'nothing_saveable'|'dots_saveable'
    # sub-quadratic? (controls long_500k applicability)
    subquadratic: bool = False
    # provenance
    source: str = ""

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        assert self.num_heads % max(self.num_kv_heads, 1) == 0, (
            f"{self.name}: num_heads {self.num_heads} not divisible by "
            f"kv heads {self.num_kv_heads}"
        )

    # ------------------------------------------------------------------
    # Period structure
    # ------------------------------------------------------------------
    @property
    def period_len(self) -> int:
        p = 1
        if self.attn_every > 1:
            p = math.lcm(p, self.attn_every)
        if self.num_experts and self.moe_every > 1:
            p = math.lcm(p, self.moe_every)
        return p

    @property
    def num_periods(self) -> int:
        assert self.num_layers % self.period_len == 0, (
            f"{self.name}: num_layers {self.num_layers} not divisible by "
            f"period {self.period_len}"
        )
        return self.num_layers // self.period_len

    def period_spec(self) -> Tuple[SublayerSpec, ...]:
        """The repeating sublayer pattern."""
        out = []
        for i in range(self.period_len):
            if self.family == "ssm":
                mixer = "mamba"
            elif self.attn_every > 1:
                mixer = "attn" if i % self.attn_every == 0 else "mamba"
            else:
                mixer = "attn"
            if self.family == "ssm":
                ffn = "none"  # mamba2-780m is a pure SSM stack (d_ff = 0)
            elif self.num_experts:
                ffn = "moe" if i % self.moe_every == self.moe_every - 1 else "dense"
            else:
                ffn = "dense"
            out.append(SublayerSpec(mixer=mixer, ffn=ffn))
        return tuple(out)

    @property
    def num_attn_layers(self) -> int:
        return sum(1 for s in self.period_spec() if s.mixer == "attn") * self.num_periods

    @property
    def num_mamba_layers(self) -> int:
        return sum(1 for s in self.period_spec() if s.mixer == "mamba") * self.num_periods

    # ------------------------------------------------------------------
    # Derived SSM dims
    # ------------------------------------------------------------------
    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.ssm_d_inner // self.ssm_headdim

    @property
    def ssm_conv_dim(self) -> int:
        # conv runs over [x, B, C] (ngroups = 1)
        return self.ssm_d_inner + 2 * self.ssm_state

    @property
    def moe_d_ff(self) -> int:
        return self.expert_d_ff or self.d_ff

    # ------------------------------------------------------------------
    # Parameter counting (analytic; used for roofline MODEL_FLOPS and the
    # serverless memory daemon's read-only size accounting)
    # ------------------------------------------------------------------
    def param_counts(self) -> dict:
        d, dh = self.d_model, self.head_dim
        nq, nkv = self.num_heads, self.num_kv_heads
        counts = {"embed": self.vocab_size * d}
        if not self.tie_embeddings:
            counts["lm_head"] = d * self.vocab_size
        attn = d * nq * dh + 2 * d * nkv * dh + nq * dh * d
        if self.qkv_bias:
            attn += (nq + 2 * nkv) * dh
        dense_ffn = 3 * d * self.d_ff  # SwiGLU: gate, up, down
        moe_ffn = 0
        if self.num_experts:
            moe_ffn = self.num_experts * 3 * d * self.moe_d_ff + d * self.num_experts
            if self.moe_shared_expert:
                moe_ffn += 3 * d * self.moe_d_ff
        di, ns, nh = self.ssm_d_inner, self.ssm_state, self.ssm_nheads
        mamba = (
            d * (2 * di + 2 * ns + nh)  # in_proj -> z,x,B,C,dt
            + self.ssm_conv * self.ssm_conv_dim  # conv1d
            + nh * 2  # A_log, D
            + nh  # dt_bias
            + di  # gated norm
            + di * d  # out_proj
        )
        n_attn, n_mamba = 0, 0
        n_dense_ffn, n_moe_ffn = 0, 0
        for s in self.period_spec():
            if s.mixer == "attn":
                n_attn += 1
            else:
                n_mamba += 1
            if s.ffn == "dense":
                n_dense_ffn += 1
            elif s.ffn == "moe":
                n_moe_ffn += 1
        P = self.num_periods
        counts["attn"] = P * n_attn * attn
        counts["mamba"] = P * n_mamba * mamba
        counts["dense_ffn"] = P * n_dense_ffn * dense_ffn
        counts["moe_ffn"] = P * n_moe_ffn * moe_ffn
        counts["norms"] = self.num_layers * 2 * d + d
        if self.is_encoder_decoder:
            # encoder stack (self-attn MHA + dense ffn) + decoder cross-attn
            enc = self.encoder_layers * (attn + dense_ffn + 2 * d)
            cross = self.num_layers * (attn + d)  # cross-attn per decoder layer
            counts["encoder"] = enc
            counts["cross_attn"] = cross
        return counts

    def param_count(self) -> int:
        return sum(self.param_counts().values())

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top-k experts count)."""
        if not self.num_experts:
            return self.param_count()
        c = self.param_counts()
        total = sum(v for k, v in c.items() if k != "moe_ffn")
        n_moe_ffn = sum(1 for s in self.period_spec() if s.ffn == "moe") * self.num_periods
        active_moe = n_moe_ffn * (
            self.experts_per_token * 3 * self.d_model * self.moe_d_ff
            + self.d_model * self.num_experts
            + (3 * self.d_model * self.moe_d_ff if self.moe_shared_expert else 0)
        )
        return total + active_moe

    # ------------------------------------------------------------------
    def reduced(self, **overrides) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        small = dict(
            num_layers=self.period_len * 2,
            d_model=64,
            num_heads=4,
            num_kv_heads=max(1, min(self.num_kv_heads, 2)),
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            param_dtype="float32",
            compute_dtype="float32",
            name=self.name + "-smoke",
        )
        if self.num_experts:
            small.update(num_experts=4, experts_per_token=min(self.experts_per_token, 2), expert_d_ff=64)
        if self.ssm_state:
            small.update(ssm_state=16, ssm_headdim=16, ssm_chunk=16)
        if self.mrope_sections:
            small.update(mrope_sections=(2, 3, 3))
        if self.is_encoder_decoder:
            small.update(encoder_layers=2)
        small.update(overrides)
        return dataclasses.replace(self, **small)


# ---------------------------------------------------------------------------
# Shape suite (assigned input shapes; identical across LM archs)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str  # 'train' | 'prefill' | 'decode'
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether a (arch, shape) cell runs, per the assignment rules."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "long_500k needs sub-quadratic attention; pure full-attention arch"
    return True, ""
