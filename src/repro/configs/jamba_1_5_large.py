"""jamba-1.5-large-398b — hybrid Mamba+attention (72L, d=8192, 64H kv=8).

Jamba period: 8 layers = 1 attention + 7 mamba (attn_every=8), MoE (16
experts, top-2) on every 2nd layer (moe_every=2). KV cache exists only on the
9 attention layers, so long-context decode is sub-quadratic in memory and
compute -> long_500k RUNS. [arXiv:2403.19887; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    num_experts=16,
    experts_per_token=2,
    moe_every=2,
    expert_d_ff=24576,
    attn_every=8,  # 1 attn : 7 mamba
    ssm_state=128,
    ssm_headdim=128,  # d_inner = 16384 -> 128 SSD heads
    ssm_expand=2,
    ssm_conv=4,
    ssm_chunk=128,
    rope_theta=1_000_000.0,
    param_dtype="bfloat16",
    subquadratic=True,  # hybrid -> long_500k runs
    source="arXiv:2403.19887; hf:ai21labs/AI21-Jamba-1.5-Large",
)
