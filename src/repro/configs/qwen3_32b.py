"""qwen3-32b — dense (64L, d=5120, 64H GQA kv=8, d_ff=25600, qk_norm).

Note head_dim=128 is explicit: 64 heads x 128 = 8192 != d_model (matches the
HF config's decoupled head_dim). [hf:Qwen/Qwen3-32B]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=25600,
    vocab_size=151936,
    qk_norm=True,
    qkv_bias=False,  # qwen3 dropped QKV bias in favour of qk_norm
    rope_theta=1_000_000.0,
    subquadratic=False,
    source="hf:Qwen/Qwen3-32B",
)
