"""mamba2-780m — pure SSM (48L, d=1536, attn-free, SSD state=128).

State-space duality (SSD): chunked quadratic-intra / recurrent-inter scan for
train+prefill, O(1) recurrent state update for decode. No MLP (d_ff=0), no
attention — the long_500k shape RUNS for this arch. [arXiv:2405.21060]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    num_layers=48,
    d_model=1536,
    num_heads=1,  # unused (attn-free); kept for dataclass invariants
    num_kv_heads=1,
    head_dim=64,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_headdim=64,   # d_inner = 2*1536 = 3072 -> 48 SSD heads
    ssm_expand=2,
    ssm_conv=4,
    ssm_chunk=128,
    tie_embeddings=True,  # mamba2 ties embeddings
    subquadratic=True,  # SSD -> long_500k runs
    source="arXiv:2405.21060; hf:state-spaces/mamba2-780m",
)
