"""whisper-small — encoder-decoder audio (12L enc + 12L dec, d=768, 12H MHA).

The conv mel frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings ``(B, S_enc, d)`` (post-conv, stride-2, so
S_enc = seq_len // 2). Decoder: causal self-attn + cross-attn with KV cache
-> decode shapes RUN; full attention -> long_500k SKIPPED.
[arXiv:2212.04356]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    num_layers=12,  # decoder layers
    d_model=768,
    num_heads=12,
    num_kv_heads=12,  # MHA
    head_dim=64,
    d_ff=3072,
    vocab_size=51865,
    is_encoder_decoder=True,
    encoder_layers=12,
    rope_theta=10_000.0,  # we use RoPE in place of learned abs pos (noted in DESIGN.md)
    causal=True,
    subquadratic=False,
    source="arXiv:2212.04356; hf:openai/whisper-small",
)
