"""Fault-tolerant checkpointing (msgpack + zstd, no orbax dependency).

Design for 1000+ node operation:
* **atomic commit** — shards are written to ``step_N.tmp/`` and renamed into
  place only after every shard and the manifest fsync; a crashed writer can
  never produce a readable-but-corrupt checkpoint;
* **sharded layout** — each host writes only the param shards it owns
  (``host_shards(params, host_id)``); the manifest records the full pytree
  structure + shapes + dtypes, so restore works on a *different* mesh
  (elastic reshard: arrays are re-device_put under the new sharding);
* **content hashes** — every shard carries an xxh-like checksum (zstd CRC +
  length) verified on load; a bad shard fails fast with its path;
* **retention** — keep the newest K checkpoints (plus any 'milestone' every
  M steps), delete the rest;
* **auto-resume** — ``latest_step()`` scans the directory; the train loop
  restores and continues, making preemption/node-failure recovery a restart
  rather than an operator action.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import struct
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import msgpack
import numpy as np

try:  # optional: fall back to stdlib zlib when the wheel is absent
    import zstandard as zstd
except ImportError:  # pragma: no cover - exercised on zstd-less installs
    zstd = None

import zlib


class _ZlibCompressor:
    """Stdlib stand-in for ``zstd.ZstdCompressor`` (same duck type)."""

    def __init__(self, level: int = 6):
        self.level = min(max(level, 1), 9)

    def compress(self, buf: bytes) -> bytes:
        return zlib.compress(buf, self.level)


class _ZlibDecompressor:
    def decompress(self, blob: bytes, max_output_size: int = 0) -> bytes:
        return zlib.decompress(blob)


def _codec_name() -> str:
    return "zstd" if zstd is not None else "zlib"


def _compressor(level: int):
    if zstd is not None:
        return zstd.ZstdCompressor(level=level)
    return _ZlibCompressor(level)


def _decompressor(codec: str):
    if codec == "zstd":
        if zstd is None:
            raise IOError(
                "checkpoint was written with zstd but the 'zstandard' "
                "package is not installed; pip install zstandard to restore"
            )
        return zstd.ZstdDecompressor()
    if codec == "zlib":
        return _ZlibDecompressor()
    raise IOError(f"unknown checkpoint codec {codec!r}")


def _path_str(path) -> str:
    parts = []
    for p in path:
        parts.append(str(getattr(p, "key", getattr(p, "idx", p))))
    return "/".join(parts)


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat[_path_str(path)] = np.asarray(leaf)
    return flat


def _unflatten_like(tree, flat: Dict[str, np.ndarray]):
    def pick(path, leaf):
        key = _path_str(path)
        arr = flat[key]
        return arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr

    return jax.tree_util.tree_map_with_path(pick, tree)


class CheckpointManager:
    def __init__(
        self,
        directory: str | Path,
        *,
        keep: int = 3,
        milestone_every: int = 0,
        zstd_level: int = 3,
    ):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.milestone_every = milestone_every
        self.zstd = zstd_level

    # ------------------------------------------------------------------
    def _step_dir(self, step: int) -> Path:
        return self.dir / f"step_{step:010d}"

    def save(self, step: int, state: Any, *, host_id: int = 0, num_hosts: int = 1,
             extra: Optional[dict] = None) -> Path:
        """Atomic sharded save. Each host writes its shard file; host 0
        writes the manifest last and commits via rename."""
        flat = _flatten(state)
        keys = sorted(flat)
        my_keys = [k for i, k in enumerate(keys) if i % num_hosts == host_id]
        tmp = self.dir / f"step_{step:010d}.tmp"
        tmp.mkdir(parents=True, exist_ok=True)

        cctx = _compressor(self.zstd)
        shard_meta = {}
        payload = {}
        for k in my_keys:
            a = flat[k]
            buf = a.tobytes()
            payload[k] = cctx.compress(buf)
            shard_meta[k] = {
                "shape": list(a.shape),
                "dtype": str(a.dtype),
                "sha256": hashlib.sha256(buf).hexdigest()[:16],
                "bytes": len(buf),
            }
        shard_path = tmp / f"shard_{host_id:05d}.msgpack.zst"
        with open(shard_path, "wb") as f:
            f.write(msgpack.packb({"codec": _codec_name(), "meta": shard_meta,
                                   "data": payload}, use_bin_type=True))
            f.flush()
            os.fsync(f.fileno())

        if host_id == 0:
            manifest = {
                "step": step,
                "num_hosts": num_hosts,
                "keys": keys,
                "extra": extra or {},
            }
            mpath = tmp / "manifest.json"
            mpath.write_text(json.dumps(manifest, indent=1))
            with open(mpath) as f:
                os.fsync(f.fileno())
            final = self._step_dir(step)
            if final.exists():
                shutil.rmtree(final)
            os.rename(tmp, final)  # the atomic commit point
            self._gc()
            return final
        return tmp

    # ------------------------------------------------------------------
    def _gc(self) -> None:
        steps = sorted(self.steps())
        victims = []
        for s in steps[:-self.keep] if self.keep else []:
            if self.milestone_every and s % self.milestone_every == 0:
                continue
            victims.append(s)
        for s in victims:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    def steps(self) -> List[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if p.suffix == ".tmp" or not (p / "manifest.json").exists():
                continue
            try:
                out.append(int(p.name.split("_")[1]))
            except (IndexError, ValueError):
                continue
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    # ------------------------------------------------------------------
    def restore(self, step: int, like: Any, *, shardings=None) -> Any:
        """Restore into the structure of ``like``; if ``shardings`` is given
        (a pytree of NamedSharding for a possibly *different* mesh), arrays
        are placed under it — elastic rescale on restore."""
        d = self._step_dir(step)
        manifest = json.loads((d / "manifest.json").read_text())
        flat: Dict[str, np.ndarray] = {}
        for shard in sorted(d.glob("shard_*.msgpack.zst")):
            blob = msgpack.unpackb(shard.read_bytes(), raw=False)
            dctx = _decompressor(blob.get("codec", "zstd"))
            for k, meta in blob["meta"].items():
                buf = dctx.decompress(blob["data"][k],
                                      max_output_size=meta["bytes"] or 1)
                if hashlib.sha256(buf).hexdigest()[:16] != meta["sha256"]:
                    raise IOError(f"checksum mismatch in {shard}:{k}")
                flat[k] = np.frombuffer(buf, dtype=np.dtype(meta["dtype"])).reshape(
                    meta["shape"]
                )
        missing = set(manifest["keys"]) - set(flat)
        if missing:
            raise IOError(f"checkpoint step {step} missing shards for: {sorted(missing)[:5]}")
        state = _unflatten_like(like, flat)
        if shardings is not None:
            state = jax.tree_util.tree_map(
                lambda x, s: jax.device_put(x, s), state, shardings
            )
        return state

    def restore_latest(self, like: Any, *, shardings=None) -> Tuple[Optional[int], Any]:
        s = self.latest_step()
        if s is None:
            return None, like
        return s, self.restore(s, like, shardings=shardings)
