"""FunctionSpec: one declarative function description, two lowerings.

The spec is the single way benchmarks, examples, and tests describe a
serverless GPU function: a name, a model-zoo arch (for the real backend), a
paper Table-2 profile and/or explicit byte sizes, a compute hint, and
optional per-request SLO defaults. The gateway lowers it to

* a real ``GPUFunction`` (``core.functions.make_model_function``: actual
  ``jax.jit`` compile, real weights in the database) for the threaded
  ``SageRuntime``, or
* a ``SimFunction`` (modeled bytes/durations) for the virtual-time
  ``Simulator`` twin,

so the same object can drive both drivers and their telemetry compares 1:1
(docs/api.md has the field-by-field lowering table).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Union

from repro.core.profiles import MB, PROFILES, FunctionProfile

# defaults when the spec neither names a paper profile nor declares bytes —
# a small function that stays fast in both backends. The real lowering
# without a profile instead declares the arch's true parameter bytes, so
# parity runs should always pin a profile or explicit sizes.
_DEFAULT_RO_MB = 16.0
_DEFAULT_W_MB = 4.0
_DEFAULT_CTX_MB = 414.0  # paper Table 2: context memory is arch-invariant
_DEFAULT_COMPUTE_MS = 10.0


@dataclass(frozen=True)
class FunctionSpec:
    """Declarative description of one serverless GPU function."""

    name: str
    arch: str = "qwen2.5-3b"  # model-zoo arch served by the real backend
    profile: Optional[Union[str, FunctionProfile]] = None  # paper Table 2 row
    read_only_bytes: Optional[int] = None  # override the profile's RO bytes
    writable_bytes: Optional[int] = None   # override writable working set
    context_bytes: Optional[int] = None    # override GPU context memory
    compute_ms: Optional[float] = None     # modeled kernel time (sim) / hint
    deadline_s: Optional[float] = None     # default SLO for every request
    priority: int = 0                      # default priority (orders "edf")
    # admission scheduling this function was validated under ("fifo"|"edf");
    # an undecided Gateway adopts it at register(), a gateway pinned to a
    # different scheduler refuses the spec (docs/api.md)
    scheduler: Optional[str] = None
    # cluster dispatch policy this function was validated under
    # ("random"|"locality"|"least_loaded"); same adopt/conflict semantics
    # as ``scheduler`` (docs/cluster.md)
    dispatch: Optional[str] = None
    # transfer scheduling this function was validated under
    # ("run_to_completion"|"preemptive"); same adopt/conflict semantics
    # as ``scheduler`` (docs/dataplane.md, "Transfer scheduling")
    transfer: Optional[str] = None
    # predictive autoscaling policy this function was validated under
    # (an ``AutoscaleConfig`` or its kwargs as a dict, normalized at
    # construction); same adopt/conflict semantics (docs/planner.md)
    autoscale: Optional[object] = None
    batch: int = 1                         # real backend request shape
    seq: int = 16
    seed: int = 0                          # real backend weight init
    # per-function circuit-breaker policy (docs/resilience.md); overrides
    # any gateway-wide ``breaker=`` for this function at register()
    breaker: Optional[object] = None
    # tail-tolerance policies this function was validated under
    # (docs/resilience.md, "Gray failures"): ``hedging`` is a
    # ``HedgeConfig``/kwargs dict/True, ``quarantine`` a
    # ``QuarantineConfig``/kwargs dict/True — normalized at construction;
    # same adopt-or-refuse semantics as ``scheduler``
    hedging: Optional[object] = None
    quarantine: Optional[object] = None
    # shared-compute-plane policy this function was validated under
    # (docs/compute.md): ``"shared"``/``ComputeConfig``/kwargs dict —
    # normalized at construction; same adopt-or-refuse semantics as
    # ``scheduler``. None/"exclusive" = the seed's exclusive FIFO.
    compute: Optional[object] = None
    # declared SM fraction in (0, 1] for the shared plane; None = auto,
    # derived from the function's profiled compute stage
    sm_fraction: Optional[float] = None

    def __post_init__(self):
        from repro.core.daemon import SCHEDULERS  # the authoritative lists
        from repro.core.dispatch import DISPATCH_POLICIES
        from repro.core.faults import BreakerConfig
        from repro.core.slowness import resolve_hedging, resolve_quarantine
        from repro.core.transfer import TRANSFER_MODES

        if self.hedging is not None:
            object.__setattr__(self, "hedging", resolve_hedging(self.hedging))
        if self.quarantine is not None:
            object.__setattr__(self, "quarantine",
                               resolve_quarantine(self.quarantine))
        if self.compute is not None:
            from repro.core.compute import resolve_compute

            object.__setattr__(self, "compute",
                               resolve_compute(self.compute))
        if self.sm_fraction is not None \
                and not 0.0 < self.sm_fraction <= 1.0:
            raise ValueError(
                f"spec {self.name!r}: sm_fraction must be in (0, 1], "
                f"got {self.sm_fraction}")

        if self.breaker is not None and not isinstance(self.breaker,
                                                       BreakerConfig):
            raise TypeError(
                f"spec {self.name!r}: breaker must be a BreakerConfig, "
                f"got {type(self.breaker).__name__}")

        if self.scheduler is not None and self.scheduler not in SCHEDULERS:
            raise ValueError(
                f"unknown scheduler {self.scheduler!r}; use one of {SCHEDULERS}")
        if self.dispatch is not None and self.dispatch not in DISPATCH_POLICIES:
            raise ValueError(
                f"unknown dispatch {self.dispatch!r}; "
                f"use one of {DISPATCH_POLICIES}")
        if self.transfer is not None and self.transfer not in TRANSFER_MODES:
            raise ValueError(
                f"unknown transfer mode {self.transfer!r}; "
                f"use one of {TRANSFER_MODES}")
        if self.autoscale is not None:
            from repro.core.placement import resolve_autoscale

            # normalize dict kwargs to a frozen AutoscaleConfig so the
            # gateway's adopt-or-refuse check is a plain equality test
            object.__setattr__(self, "autoscale",
                               resolve_autoscale(self.autoscale))

    # ------------------------------------------------------------------
    # lowering
    # ------------------------------------------------------------------
    def base_profile(self) -> Optional[FunctionProfile]:
        if self.profile is None:
            return None
        if isinstance(self.profile, FunctionProfile):
            return self.profile
        return PROFILES[self.profile]

    def resolved_profile(self) -> FunctionProfile:
        """The modeled profile after byte/compute overrides, renamed to the
        spec's name (this is what the simulator lowering runs on)."""
        base = self.base_profile() or FunctionProfile(
            self.name, "custom", _DEFAULT_CTX_MB, _DEFAULT_RO_MB,
            _DEFAULT_W_MB, _DEFAULT_COMPUTE_MS,
        )
        over: dict = {"name": self.name}
        if self.read_only_bytes is not None:
            over["read_only_mb"] = self.read_only_bytes / MB
        if self.writable_bytes is not None:
            over["writable_mb"] = self.writable_bytes / MB
        if self.context_bytes is not None:
            over["context_mb"] = self.context_bytes / MB
        if self.compute_ms is not None:
            over["compute_ms"] = self.compute_ms
        return dataclasses.replace(base, **over)

    def to_sim_function(self):
        from repro.core.simulator import SimFunction

        return SimFunction(self.resolved_profile(), name=self.name,
                           sm_fraction=self.sm_fraction)

    def to_gpu_function(self, db):
        """Real lowering: compile a reduced ``arch`` model and put its
        weights in ``db`` (lazy import keeps sim-only users off jax)."""
        from repro.core.functions import make_model_function

        fn = make_model_function(
            db, self.name, arch=self.arch, batch=self.batch, seq=self.seq,
            profile=self.base_profile(), declared_ro_bytes=self.read_only_bytes,
            seed=self.seed,
        )
        over: dict = {}
        if self.writable_bytes is not None:
            over["writable_hint"] = self.writable_bytes
        if self.context_bytes is not None:
            over["context_bytes"] = self.context_bytes
        if self.compute_ms is not None:
            over["compute_s_hint"] = self.compute_ms / 1e3
        if self.sm_fraction is not None:
            over["sm_fraction"] = self.sm_fraction
        return dataclasses.replace(fn, **over) if over else fn

    # ------------------------------------------------------------------
    @classmethod
    def from_profile(cls, profile_name: str, *, name: Optional[str] = None,
                     **kw) -> "FunctionSpec":
        """Spec for one paper Table-2 profile (clones pass ``name=``)."""
        return cls(name=name or profile_name, profile=profile_name, **kw)
