"""The unified serving API: FunctionSpec + Workload + Gateway.

This is the layer every benchmark, example, and test drives load through;
``core.runtime``/``core.simulator`` remain importable as the mechanism
layer underneath. See docs/api.md.
"""
from repro.api.gateway import DEFAULT_INPUT_BYTES, Gateway, Invocation  # noqa: F401
from repro.api.spec import FunctionSpec  # noqa: F401
from repro.api.workload import (  # noqa: F401
    Arrival, BurstWorkload, ChaosWorkload, DiurnalWorkload,
    FlashCrowdWorkload, MAFWorkload, MixWorkload, MultiRegionWorkload,
    PoissonWorkload, TraceWorkload, Workload, maf_like_trace,
    poisson_arrivals,
)
