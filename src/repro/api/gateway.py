"""Gateway: the single serving entry point over both drivers.

``Gateway(backend="runtime")`` wraps the real threaded ``SageRuntime``
(or a ``ClusterRuntime`` when ``n_nodes > 1``); ``backend="sim"`` wraps the
virtual-time ``Simulator`` twin. Registration takes a
:class:`~repro.api.spec.FunctionSpec`, load comes from
``invoke``/``invoke_async``/``replay(workload)``, and ``report()`` returns
the one shared :class:`~repro.core.telemetry.Telemetry` — so any workload
can be replayed against both backends and their records compared 1:1
(tests/test_api.py holds that parity contract).

The mechanism layer stays importable and unchanged: ``gateway.runtime`` /
``gateway.sim`` expose the wrapped driver for tooling that needs to peek at
daemons, engines, or brokers.
"""
from __future__ import annotations

import itertools
import time
from typing import Dict, List, Optional, Tuple, Union

from repro.api.spec import FunctionSpec
from repro.api.workload import Arrival, Workload
from repro.core.dispatch import DISPATCH_POLICIES
from repro.core.profiles import MB
from repro.core.telemetry import InvocationRecord, Telemetry
from repro.core.transfer import TRANSFER_MODES

DEFAULT_INPUT_BYTES = 4 * MB
# per-invocation completion deadline for runtime-backend replay (the
# wall-clock analogue of the old hand-rolled future.result(timeout=...))
DEFAULT_REPLAY_TIMEOUT_S = 300.0

_BACKENDS = ("runtime", "sim")


class Invocation:
    """Handle for one in-flight invocation.

    ``wait()`` blocks (real time or virtual time) and returns the
    invocation's :class:`InvocationRecord`. With ``strict=True`` (default)
    a failed invocation raises instead; with ``strict=False`` the failure
    stays in ``record.error`` / ``Telemetry.errors()`` and the record is
    returned.
    """

    def wait(self, timeout: Optional[float] = None, *,
             strict: bool = True) -> InvocationRecord:
        raise NotImplementedError

    def result(self, timeout: Optional[float] = None, *,
               strict: bool = True) -> InvocationRecord:
        return self.wait(timeout, strict=strict)


class _RuntimeInvocation(Invocation):
    def __init__(self, node, future, request_uuid: str):
        self._node = node
        self._future = future
        self._uuid = request_uuid

    def wait(self, timeout=None, *, strict=True):
        exc: Optional[BaseException] = None
        try:
            self._future.result(timeout=timeout)
        except BaseException as e:  # recorded in telemetry either way
            exc = e
        rec = self._node.telemetry.find(self._uuid)
        if exc is not None and strict:
            raise exc
        if rec is None:
            # non-strict only swallows failures that produced a record
            # (a wait timeout has nothing to return)
            if exc is not None:
                raise exc
            raise RuntimeError(f"no record for invocation {self._uuid}")
        return rec


class _SimInvocation(Invocation):
    def __init__(self, sim, request_id: str):
        self._sim = sim
        self._rid = request_id

    def wait(self, timeout=None, *, strict=True):
        # ``timeout`` is accepted for interface parity; virtual time drains
        # instantly, so there is nothing wall-clock to bound here
        rec = self._sim.telemetry.find(self._rid)
        if rec is None:
            self._sim.run()  # drain virtual time
            rec = self._sim.telemetry.find(self._rid)
        if rec is None:
            raise RuntimeError(
                f"simulated invocation {self._rid} never completed")
        if strict and rec.error is not None:
            raise RuntimeError(rec.error)
        return rec


class Gateway:
    """One serving API over the real runtime and the simulator twin."""

    def __init__(self, backend: str = "sim", policy: str = "sage", *,
                 n_nodes: int = 1, device_capacity: int = 40 << 30,
                 host_capacity: int = 125 << 30,
                 exit_ttl: float = 30.0, seed: int = 0,
                 time_scale: float = 1.0, loader_threads: int = 4,
                 load_timeout_s: Optional[float] = None,
                 max_workers: int = 32, serialize_compute: bool = True,
                 scheduler: Optional[str] = None,
                 dispatch: Optional[str] = None,
                 transfer: Optional[str] = None,
                 chunk_bytes: Optional[int] = None):
        if backend not in _BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; use one of {_BACKENDS}")
        self.backend = backend
        self.policy = policy
        self.specs: Dict[str, FunctionSpec] = {}
        self._seq = itertools.count()
        self.sim = None
        self.runtime = None
        # loader/admission scheduling ("fifo"|"edf"). None = default "fifo"
        # but adoptable: the first registered spec that declares a scheduler
        # switches the gateway (an explicit constructor choice is not
        # overridable — a conflicting spec raises at register()).
        self._scheduler_source = None if scheduler is None else "constructor"
        self.scheduler = scheduler or "fifo"
        # cluster dispatch ("random"|"locality"|"least_loaded"), same
        # adopt/conflict semantics as the scheduler knob (docs/cluster.md).
        # Stored even for single-node backends so a later spec conflict is
        # still surfaced consistently.
        self._dispatch_source = None if dispatch is None else "constructor"
        self.dispatch = dispatch or "random"
        if self.dispatch not in DISPATCH_POLICIES:
            raise ValueError(
                f"unknown dispatch {self.dispatch!r}; "
                f"use one of {DISPATCH_POLICIES}")
        # transfer scheduling ("run_to_completion"|"preemptive"), same
        # adopt/conflict semantics as the scheduler knob (docs/dataplane.md)
        self._transfer_source = None if transfer is None else "constructor"
        self.transfer = transfer or "run_to_completion"
        if self.transfer not in TRANSFER_MODES:
            raise ValueError(
                f"unknown transfer mode {self.transfer!r}; "
                f"use one of {TRANSFER_MODES}")
        if backend == "sim":
            from repro.core.simulator import Simulator

            self.sim = Simulator(
                policy, n_nodes=n_nodes, capacity=device_capacity,
                host_capacity=host_capacity,
                exit_ttl=exit_ttl, seed=seed, loader_threads=loader_threads,
                # backend-native deadline defaults: 600 virtual s (sim)
                load_timeout_s=600.0 if load_timeout_s is None else load_timeout_s,
                scheduler=self.scheduler, dispatch=self.dispatch,
                transfer=self.transfer,
                **({} if chunk_bytes is None else {"chunk_bytes": chunk_bytes}),
            )
            self._nodes: List = []
        else:
            from repro.core.runtime import ClusterRuntime, SageRuntime

            kw = dict(
                policy=policy, device_capacity=device_capacity,
                host_capacity=host_capacity,
                time_scale=time_scale, exit_ttl=exit_ttl,
                loader_threads=loader_threads,
                load_timeout_s=30.0 if load_timeout_s is None else load_timeout_s,
                max_workers=max_workers, serialize_compute=serialize_compute,
                scheduler=self.scheduler, transfer=self.transfer,
                chunk_bytes=chunk_bytes,
            )
            if n_nodes == 1:
                self.runtime = SageRuntime(**kw)
                self._nodes = [self.runtime]
            else:
                self.runtime = ClusterRuntime(n_nodes=n_nodes, seed=seed,
                                              dispatch=self.dispatch, **kw)
                self._nodes = list(self.runtime.nodes)
            self.runtime.sage_init()
            self._fns: Dict[str, List] = {}  # name -> GPUFunction per node

    # ------------------------------------------------------------------
    # registration
    # ------------------------------------------------------------------
    # knobs a spec may declare and a gateway adopts/refuses uniformly
    # ("scheduler": loader/admission ordering; "dispatch": cluster routing;
    # "transfer": run-to-completion vs preemptible chunked streams)
    _SPEC_KNOBS = ("scheduler", "dispatch", "transfer")

    def _check_knob(self, spec: FunctionSpec, knob: str) -> None:
        """Raise if the spec's declared ``knob`` value conflicts with a
        pinned gateway (constructor choice or an earlier registered spec)."""
        declared = getattr(spec, knob)
        if (declared is not None and declared != getattr(self, knob)
                and getattr(self, f"_{knob}_source") is not None):
            raise ValueError(
                f"spec {spec.name!r} declares {knob}={declared!r} "
                f"but this gateway runs {getattr(self, knob)!r} "
                f"(set by {getattr(self, f'_{knob}_source')})")

    def _adopt_knob(self, spec: FunctionSpec, knob: str) -> None:
        """A spec may declare the configuration it was validated under. An
        undecided gateway adopts it; conflicts were rejected by
        :meth:`_check_knob` before the backend registration ran. The value
        is applied through the backend's ``set_<knob>`` when it has one (a
        single-node runtime has no dispatch to switch — the knob is still
        recorded so later conflicting specs are refused)."""
        declared = getattr(spec, knob)
        if declared is None:
            return
        if declared == getattr(self, knob):
            if getattr(self, f"_{knob}_source") is None:
                setattr(self, f"_{knob}_source", f"spec {spec.name!r}")
            return
        setattr(self, knob, declared)
        setattr(self, f"_{knob}_source", f"spec {spec.name!r}")
        target = self.sim if self.sim is not None else self.runtime
        setter = getattr(target, f"set_{knob}", None)
        if setter is not None:
            setter(declared)

    def register(self, spec: FunctionSpec) -> None:
        if spec.name in self.specs:
            raise ValueError(f"function {spec.name!r} already registered")
        # knob conflicts must surface before any backend state changes
        for knob in self._SPEC_KNOBS:
            self._check_knob(spec, knob)
        if self.sim is not None:
            self.sim.register(spec.to_sim_function())
        else:
            fns = []
            for node in self._nodes:  # each node compiles its own context
                fn = spec.to_gpu_function(node.db)
                node.register_function(fn)
                fns.append(fn)
            self._fns[spec.name] = fns
        # adopt/record only once the backend registration succeeded: a spec
        # that failed to lower must not pin the gateway's knobs
        for knob in self._SPEC_KNOBS:
            self._adopt_knob(spec, knob)
        self.specs[spec.name] = spec

    # ------------------------------------------------------------------
    # invocation
    # ------------------------------------------------------------------
    def _effective_slo(self, name: str, deadline_s, priority):
        spec = self.specs[name]
        return (spec.deadline_s if deadline_s is None else deadline_s,
                spec.priority if priority is None else priority)

    def _pick_node(self, name: str) -> Tuple[int, Optional[str]]:
        """(node index, residency tier at dispatch) for the runtime
        backend. Multi-node gateways delegate to the cluster's dispatch
        policy (the request must be BUILT for the chosen node — each node
        has its own database and compiled functions — so selection happens
        here, not inside ``ClusterRuntime.submit``)."""
        if len(self._nodes) == 1:
            return 0, None
        return self.runtime.select_node(name)

    def _build_request(self, name: str, node_idx: int, *, seed: int,
                       input_bytes: int, deadline_s, priority,
                       max_retries=None, dispatch_tier=None):
        from repro.core.functions import make_request

        spec = self.specs[name]
        req = make_request(
            self._nodes[node_idx].db, self._fns[name][node_idx],
            batch=spec.batch, seq=spec.seq, input_bytes=input_bytes, seed=seed,
        )
        req.deadline_s, req.priority = self._effective_slo(name, deadline_s, priority)
        req.max_retries = max_retries
        req.dispatch_tier = dispatch_tier
        return req

    def invoke_async(self, name: str, *, seed: int = 0,
                     at: Optional[float] = None,
                     deadline_s: Optional[float] = None,
                     priority: Optional[int] = None,
                     max_retries: Optional[int] = None,
                     input_bytes: int = DEFAULT_INPUT_BYTES) -> Invocation:
        """Submit one invocation; returns an :class:`Invocation` handle.
        ``at`` is a virtual arrival time (sim backend only — the real
        runtime always arrives now). ``max_retries`` is the per-request
        OOM-admission retry budget (None = the flat ``load_timeout_s``)."""
        if name not in self.specs:
            raise KeyError(f"unregistered function {name!r}")
        if self.sim is not None:
            t = self.sim.clock.now() if at is None else at
            dl, pr = self._effective_slo(name, deadline_s, priority)
            rid = f"gw-{next(self._seq)}-{name}"
            self.sim.submit(name, t, deadline_s=dl, priority=pr,
                            request_id=rid, max_retries=max_retries)
            return _SimInvocation(self.sim, rid)
        node_idx, tier = self._pick_node(name)
        req = self._build_request(name, node_idx, seed=seed,
                                  input_bytes=input_bytes,
                                  deadline_s=deadline_s, priority=priority,
                                  max_retries=max_retries, dispatch_tier=tier)
        node = self._nodes[node_idx]
        return _RuntimeInvocation(node, node.submit(req), req.uuid)

    def invoke(self, name: str, **kw) -> InvocationRecord:
        """Blocking invocation; returns the finished record (the handler's
        return value rides on ``record.result`` for the real backend)."""
        return self.invoke_async(name, **kw).wait()

    # ------------------------------------------------------------------
    # workload replay
    # ------------------------------------------------------------------
    def replay(self, workload: Union[Workload, List[Arrival]], *,
               until: Optional[float] = None, until_pad: float = 300.0,
               pace: float = 1.0, seed: int = 0,
               timeout: Optional[float] = DEFAULT_REPLAY_TIMEOUT_S,
               input_bytes: int = DEFAULT_INPUT_BYTES) -> Telemetry:
        """Drive every arrival of ``workload`` through the backend.

        Simulator: arrivals land at their virtual times and the clock runs
        to ``until`` (default: last arrival + ``until_pad``); ``pace``/
        ``seed``/``input_bytes``/``timeout`` don't apply (no wall clock, no
        real payloads). Real runtime: arrivals are paced open-loop in
        wall-clock time (``pace`` seconds of wall time per workload second)
        and every completion is awaited up to ``timeout`` wall seconds;
        failures stay in ``Telemetry.errors()``. ``until`` cannot cut a
        wall clock short, so passing it on this backend raises rather than
        silently skewing a windowed measurement. Returns ``report()``.
        """
        events = workload.events() if isinstance(workload, Workload) \
            else sorted(workload, key=lambda a: a.t)
        if self.sim is not None:
            for a in events:
                dl, pr = self._effective_slo(a.function, a.deadline_s, a.priority)
                # unique ids: simultaneous arrivals of one function would
                # otherwise collide on the simulator's default "name@t" id
                self.sim.submit(a.function, a.t, deadline_s=dl, priority=pr,
                                request_id=f"gw-{next(self._seq)}-{a.function}")
            horizon = until if until is not None else \
                ((events[-1].t if events else 0.0) + until_pad)
            self.sim.run(until=horizon)
            return self.report()
        if until is not None:
            raise ValueError("replay(until=...) is a virtual-time cutoff; "
                             "the runtime backend always drains — filter "
                             "records by end_t instead")
        handles = []
        t0 = time.monotonic()
        for i, a in enumerate(events):
            lag = t0 + a.t * pace - time.monotonic()
            if lag > 0:
                time.sleep(lag)
            node_idx, tier = self._pick_node(a.function)
            req = self._build_request(a.function, node_idx, seed=seed + i,
                                      input_bytes=input_bytes,
                                      deadline_s=a.deadline_s,
                                      priority=a.priority,
                                      dispatch_tier=tier)
            node = self._nodes[node_idx]
            handles.append(_RuntimeInvocation(node, node.submit(req), req.uuid))
        for h in handles:
            h.wait(timeout, strict=False)
        return self.report()

    # ------------------------------------------------------------------
    # observability
    # ------------------------------------------------------------------
    def report(self) -> Telemetry:
        """The unified per-invocation telemetry for this gateway."""
        if self.sim is not None:
            return self.sim.telemetry
        return self.runtime.telemetry  # ClusterRuntime merges its nodes

    @property
    def telemetry(self) -> Telemetry:
        return self.report()

    def memory_usage(self) -> Dict[str, int]:
        """Current memory footprint, same keys on both backends (the sim's
        context/host numbers are modeled from live instance state)."""
        if self.sim is not None:
            ctx = 0
            for node in self.sim.nodes:
                for insts in node.instances.values():
                    ctx += sum(i.fn.ctx_bytes for i in insts
                               if i.has_ctx and not i.dead)
            return {"device_used": sum(n.used for n in self.sim.nodes),
                    "context_bytes": ctx,
                    # the node's host-tier admission accounting (resident
                    # shared-RO copies + in-flight private bytes) — the
                    # same definition daemon.host_used reports
                    "host_used": sum(n.host_used for n in self.sim.nodes)}
        usages = [n.memory_usage() for n in self._nodes]
        return {k: sum(u[k] for u in usages) for k in usages[0]}

    def mean_memory_bytes(self) -> float:
        if self.sim is None:
            raise RuntimeError("time-weighted memory traces exist only on "
                               "the sim backend; use memory_usage() instead")
        return self.sim.mean_memory_bytes()

    # ------------------------------------------------------------------
    def shutdown(self) -> None:
        if self.runtime is not None:
            self.runtime.shutdown()

    def __enter__(self) -> "Gateway":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()
